"""The observability subsystem (PR 7): event-log <-> ledger
reconciliation, zero-cost-when-disabled goldens, the recorder, the
burn-rate monitors, the exporters, and the JAX trajectory surface.

The load-bearing property is **bit-exact reconciliation**: every ledger
delta the engine posts must be explained by the structured event log —
``reconcile_events`` replays the ledger's exact posting order from the
events alone and the totals compare ``==`` (not merely close) against
the run's :class:`SimResult`, per arch included.  The second hard
property is that a telemetry-less run is *bit-identical* to the
pre-telemetry engine (goldens hardcoded below from the PR 6 tree).
"""
from __future__ import annotations

import json
import warnings

import dataclasses
import numpy as np
import pytest

from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import (
    EVENT_TYPES,
    SUMMARY_KEY_DOCS,
    MonitorConfig,
    ServingSim,
    Telemetry,
    TimeSeriesRecorder,
    VariantCatalog,
    detect_incidents,
    incidents_table,
    reconcile_events,
    simulate,
    uniform_pool_workload,
)
from repro.core.sim.telemetry import (
    _mask_to_incidents,
    _rolling_sum,
    events_from_jsonl,
)
from repro.core.workloads import SCENARIO_ZOO

POOL = [
    "llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
    "whisper-small", "llava-next-mistral-7b", "recurrentgemma-9b",
    "phi3.5-moe-42b-a6.6b",
]

LEDGER_SCALARS = (
    "total_requests", "served_vm", "served_burst", "violations",
    "violations_strict", "cost_reserved", "cost_spot", "cost_burst",
    "accuracy_weighted", "accuracy_served", "acc_violations",
    "chip_seconds", "chip_seconds_needed", "chip_seconds_over",
)


def _run(scenario: str, policy: str, ticks: int = 300, *,
         telemetry=None, catalog=None, wl=None, mean_rps: float = 300.0):
    wl = wl if wl is not None else uniform_pool_workload(POOL, strict_frac=0.25)
    arr = SCENARIO_ZOO[scenario].build(len(wl), duration_s=ticks,
                                       mean_rps=mean_rps)
    sim = ServingSim(arr, wl, seed=0, catalog=catalog, telemetry=telemetry)
    pol = VECTOR_SCHEDULERS[policy]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
    return sim


def _assert_reconciles(sim, tel, ticks: int) -> None:
    rec = reconcile_events(tel.events, len(sim.keys), ticks)
    res = sim.res
    for k in LEDGER_SCALARS:
        assert rec[k] == getattr(res, k), (
            f"{k}: events rebuild {rec[k]!r} != ledger {getattr(res, k)!r}"
        )
    assert rec["preemptions"] == res.preemptions
    assert rec["variant_swaps"] == res.variant_swaps
    assert rec["cost_other"] == res.cost_other       # values AND key order
    assert list(rec["cost_other"]) == list(res.cost_other)
    assert rec["cost_total"] == res.cost_total
    counts = sim.per_arch_counts()
    for k, v in rec["per_arch"].items():
        if k == "violations":
            # the engine folds still-queued mass into its running per-arch
            # violations view only at finalize; both sides include it here
            pass
        np.testing.assert_array_equal(v, counts[k], err_msg=k)


# ---------------------------------------------------------------------------
# Tentpole property 1: the event log explains the ledger, bit-exactly,
# on every zoo scenario.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scenario", sorted(SCENARIO_ZOO))
def test_reconciliation_zoo_smoke(scenario):
    ticks = 300
    tel = Telemetry()
    sim = _run(scenario, "portfolio", ticks, telemetry=tel)
    assert len(tel.events) > ticks          # emitted something every tick
    _assert_reconciles(sim, tel, ticks)


@pytest.mark.parametrize("policy", ["spot_paragon", "reactive"])
def test_reconciliation_other_policies(policy):
    ticks = 240
    tel = Telemetry()
    sim = _run("mmpp_bursts", policy, ticks, telemetry=tel)
    _assert_reconciles(sim, tel, ticks)


def test_reconciliation_variant_catalog():
    """Accuracy mass, accuracy violations and swap events reconcile on a
    variant-aware run (the trending_hotswap scenario forces swaps)."""
    ticks = 240
    wl = [dataclasses.replace(w, min_accuracy=0.6)
          for w in uniform_pool_workload(POOL, strict_frac=0.25)]
    catalog = VariantCatalog.for_workload(wl)
    tel = Telemetry()
    sim = _run("trending_hotswap", "infaas_variant", ticks,
               telemetry=tel, catalog=catalog, wl=wl)
    assert sim.res.accuracy_served > 0
    _assert_reconciles(sim, tel, ticks)


# ---------------------------------------------------------------------------
# Tentpole property 2: telemetry off == the pre-telemetry engine, bit
# for bit.  Goldens recorded from the PR 6 tree (A=8 uniform pool,
# strict_frac=0.25, duration 600, mean_rps 300, default build seed).
# ---------------------------------------------------------------------------
GOLDENS = {
    ("flash_correlated", "portfolio"): dict(
        violations=4650.577013700305, cost_total=3.4240622414251773,
        served_vm=179963.98193845653, preemptions=1),
    ("mmpp_bursts", "paragon"): dict(
        violations=18461.562900661895, cost_total=3.609333333333281,
        served_vm=179995.47008110004, preemptions=0),
    ("diurnal_phases", "spot_paragon"): dict(
        violations=1223.2627715401238, cost_total=3.448999999999966,
        served_vm=179999.99999999994, preemptions=0),
}


@pytest.mark.parametrize("scenario,policy", sorted(GOLDENS))
def test_disabled_matches_pre_telemetry_goldens(scenario, policy):
    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    arr = SCENARIO_ZOO[scenario].build(len(wl), duration_s=600, mean_rps=300.0)
    res = simulate(arr, wl, VECTOR_SCHEDULERS[policy]())
    g = GOLDENS[(scenario, policy)]
    assert res.violations == g["violations"]
    assert res.cost_total == g["cost_total"]
    assert res.served_vm == g["served_vm"]
    assert res.preemptions == g["preemptions"]


def test_enabled_equals_disabled_bitwise():
    """Attaching telemetry must not perturb a single ledger bit."""
    ticks = 300
    on = _run("flash_correlated", "portfolio", ticks, telemetry=Telemetry())
    off = _run("flash_correlated", "portfolio", ticks)
    for k in LEDGER_SCALARS:
        assert getattr(on.res, k) == getattr(off.res, k), k
    assert on.res.cost_other == off.res.cost_other
    assert on.res.preemptions == off.res.preemptions
    for k, v in on.per_arch_counts().items():
        np.testing.assert_array_equal(v, off.per_arch_counts()[k], err_msg=k)


# ---------------------------------------------------------------------------
# The recorder: stride semantics, flow conservation, gauges.
# ---------------------------------------------------------------------------
def test_recorder_stride_buckets():
    ticks = 120
    t1 = Telemetry(stride=1)
    _run("mmpp_bursts", "paragon", ticks, telemetry=t1)
    t10 = Telemetry(stride=10)
    _run("mmpp_bursts", "paragon", ticks, telemetry=t10)

    assert t1.recorder.n_rows == ticks
    assert t10.recorder.n_rows == ticks // 10
    # flows accumulate within a bucket: totals survive downsampling
    for name in TimeSeriesRecorder.FLOW_NAMES:
        np.testing.assert_allclose(
            t10.recorder.flows[name].sum(axis=0),
            t1.recorder.flows[name].sum(axis=0), rtol=1e-12)
    np.testing.assert_allclose(t10.recorder.tier_cost.sum(),
                               t1.recorder.tier_cost.sum(), rtol=1e-12)
    # gauges are last-write-wins: each bucket reports its final tick
    np.testing.assert_array_equal(
        t10.recorder.tick[:12], np.arange(12) * 10 + 9)
    np.testing.assert_array_equal(
        t10.recorder.tier_active["reserved"][:12],
        t1.recorder.tier_active["reserved"][9::10])


def test_recorder_allocation_stride_and_dtypes():
    """The recorder honors the stride at allocation (R = ceil(T/stride)
    rows, not T) and keeps gauge buffers narrow — observability series
    are float32/int32 while the reconciliation-bearing flow and cost
    series stay float64."""
    rec = TimeSeriesRecorder(256, ticks=3600, stride=60)
    assert rec.rows == 60
    for t in rec.tier_names:
        assert rec.tier_active[t].shape == (60, 256)
        assert rec.tier_active[t].dtype == np.int32
        assert rec.tier_pending[t].dtype == np.int32
    for c in ("strict", "relaxed"):
        assert rec.queue_depth[c].dtype == np.float32
        assert rec.queue_age_p99[c].dtype == np.int32
    assert rec.active_variant.dtype == np.int32
    assert rec.utilization.dtype == np.float32
    assert rec.harvest_level.dtype == np.float32
    # the exactness-bearing series keep full precision
    for name in TimeSeriesRecorder.FLOW_NAMES:
        assert rec.flows[name].dtype == np.float64
    assert rec.tier_cost.dtype == np.float64
    assert rec.tick.dtype == np.int64


def test_recorder_direct_flow_accumulation():
    rec = TimeSeriesRecorder(2, ticks=10, stride=5)
    rec.add_flow(0, "arrived", np.array([1.0, 2.0]))
    rec.add_flow(4, "arrived", np.array([3.0, 4.0]))
    rec.add_flow(5, "arrived", np.array([10.0, 0.0]))
    assert rec.rows == 2
    np.testing.assert_array_equal(rec.flows["arrived"][0], [4.0, 6.0])
    np.testing.assert_array_equal(rec.flows["arrived"][1], [10.0, 0.0])
    assert rec.n_rows == 2
    np.testing.assert_array_equal(rec.pool_flow("arrived"), [10.0, 10.0])
    assert set(rec.as_dict()) >= {"tick", "arrived", "tier_cost",
                                  "utilization", "harvest_level"}


def test_telemetry_rebinds_fresh_per_run():
    """RL envs reuse one Telemetry across episodes: bind() must reset."""
    tel = Telemetry()
    _run("mmpp_bursts", "paragon", 60, telemetry=tel)
    n1 = len(tel.events)
    sim = _run("mmpp_bursts", "paragon", 60, telemetry=tel)
    assert len(tel.events) == n1            # fresh log, not doubled
    _assert_reconciles(sim, tel, 60)


# ---------------------------------------------------------------------------
# Monitors.
# ---------------------------------------------------------------------------
def test_rolling_sum_matches_naive():
    rng = np.random.default_rng(0)
    x = rng.random(200)
    for w in (1, 7, 60, 500):
        naive = np.array([x[max(0, i - w + 1): i + 1].sum()
                          for i in range(len(x))])
        np.testing.assert_allclose(_rolling_sum(x, w), naive, atol=1e-9)


def test_mask_to_incidents_merges_runs():
    ticks = np.arange(10)
    mask = np.array([0, 1, 1, 0, 0, 1, 0, 0, 1, 1], dtype=bool)
    peak = np.arange(10, dtype=float)
    out = _mask_to_incidents(mask, ticks, peak, "slo_burn", "strict", "d")
    assert [(i.start_tick, i.end_tick, i.peak) for i in out] == [
        (1, 2, 2.0), (5, 5, 5.0), (8, 9, 9.0)]
    assert _mask_to_incidents(np.zeros(4, bool), ticks[:4], peak[:4],
                              "slo_burn", "strict", "d") == []


def _synthetic_recorder(ticks: int = 600) -> TimeSeriesRecorder:
    rec = TimeSeriesRecorder(2, ticks)
    rec.tick[:] = np.arange(ticks)
    rec._touched = ticks
    rec.flows["arrived"][:] = 50.0          # per arch, per tick
    rec.flows["served_vm"][:] = 50.0
    rec.tier_cost[:, 0] = 1.0               # $1/tick reserved baseline
    return rec


def test_monitor_detects_slo_burn():
    rec = _synthetic_recorder()
    rec.flows["viol_strict"][200:330, 0] = 60.0   # 60% of pool arrivals
    inc = detect_incidents(rec)
    burns = [i for i in inc if i.kind == "slo_burn"]
    assert burns and burns[0].label == "strict"
    # pages only once the slow window confirms, inside the burst
    assert 200 <= burns[0].start_tick <= 330
    assert burns[0].peak > MonitorConfig().burn_threshold
    # quiet series -> quiet monitors
    assert detect_incidents(_synthetic_recorder()) == []


def test_monitor_detects_queue_age():
    rec = _synthetic_recorder()
    rec.queue_age_p99["relaxed"][300:340, 1] = 99
    inc = [i for i in detect_incidents(rec) if i.kind == "queue_age"]
    assert len(inc) == 1 and inc[0].label == "relaxed"
    assert (inc[0].start_tick, inc[0].end_tick) == (300, 339)
    assert inc[0].peak == 99.0


def test_monitor_detects_cost_drift():
    rec = _synthetic_recorder()
    rec.tier_cost[400:, 0] = 30.0           # 30x the $/request baseline
    inc = [i for i in detect_incidents(rec) if i.kind == "cost_drift"]
    assert inc and inc[0].start_tick >= 400
    table = incidents_table(inc)
    assert "cost_drift" in table and "cost_per_request" in table
    assert incidents_table([]) == "no incidents detected\n"


def test_dashboard_scenario_yields_incident():
    """The acceptance path: a zoo scenario must page >= 1 incident with
    default monitor thresholds (what --require-incident exercises)."""
    tel = Telemetry()
    _run("flash_correlated", "portfolio", 600, telemetry=tel)
    assert len(detect_incidents(tel.recorder)) >= 1


# ---------------------------------------------------------------------------
# Exporters.
# ---------------------------------------------------------------------------
def test_jsonl_round_trip(tmp_path):
    tel = Telemetry()
    _run("mmpp_bursts", "paragon", 90, telemetry=tel)
    path = str(tmp_path / "events.jsonl")
    n = tel.to_jsonl(path)
    assert n == len(tel.events) > 0
    back = events_from_jsonl(path)
    assert back == tel.events               # NamedTuple equality, exact
    rec = reconcile_events(back, 8, 90)
    assert rec["total_requests"] > 0


def test_prometheus_text_format():
    tel = Telemetry()
    sim = _run("flash_correlated", "portfolio", 120, telemetry=tel)
    text = tel.prometheus_text(sim.res)
    lines = text.splitlines()
    assert any(l.startswith("# TYPE repro_sim_events_total") for l in lines)
    assert any(l.startswith('repro_sim_events_total{etype="arrival"}')
               for l in lines)
    assert any(l.startswith('repro_sim_result{metric="cost_total"}')
               for l in lines)
    # every sample line is "name{labels} value" with a float value
    for l in lines:
        if l and not l.startswith("#"):
            float(l.rsplit(" ", 1)[1])


def test_event_types_documented():
    tel = Telemetry()
    _run("flash_correlated", "portfolio", 200, telemetry=tel)
    seen = {e.etype for e in tel.events}
    assert seen <= set(EVENT_TYPES)
    assert all(isinstance(v, str) and v for v in EVENT_TYPES.values())
    d = tel.events_as_dicts()[0]
    assert set(d) == {"tick", "etype", "arch", "tier", "cls",
                      "magnitude", "cost"}


def test_summary_key_docs_cover_every_key():
    wl = [dataclasses.replace(w, min_accuracy=0.6)
          for w in uniform_pool_workload(POOL, strict_frac=0.25)]
    catalog = VariantCatalog.for_workload(wl)
    sim = _run("flash_correlated", "portfolio", 200, catalog=catalog, wl=wl)
    for key in sim.res.summary():
        doc_key = key if key in SUMMARY_KEY_DOCS else "cost_<tier>"
        assert doc_key in SUMMARY_KEY_DOCS, f"undocumented summary key {key}"
        assert key.startswith("cost_") or key in SUMMARY_KEY_DOCS


# ---------------------------------------------------------------------------
# JAX engine surface: trajectories + the retrace counter/warning.
# ---------------------------------------------------------------------------
def test_jax_trajectory_matches_sum_mode():
    from repro.core.sim import jax_engine as je
    from repro.core.sim.telemetry import global_counters

    wl = uniform_pool_workload(POOL[:4], strict_frac=0.25)
    arr = SCENARIO_ZOO["mmpp_bursts"].build(4, duration_s=200, mean_rps=120.0)
    base = je.run_scenario(arr, wl, "portfolio")
    traj = je.run_scenario(arr, wl, "portfolio", record_trajectory=True)

    assert set(base["summary"]) == set(traj["summary"])
    for k, v in base["summary"].items():
        np.testing.assert_allclose(traj["summary"][k], v, rtol=1e-6,
                                   err_msg=k)
    series = traj["trajectory"]
    for k in ("served", "viol", "cost_arch", "n_res", "queue_strict",
              "queue_relaxed"):
        assert series[k].shape[0] == 200, k
    # the per-tick fleet gauge is a level series, not all-zero
    assert np.asarray(series["n_res"]).sum() > 0
    # both runner modes surfaced their trace counts as global counters
    keys = [k for k in global_counters() if "jax_runner_traces_total" in k]
    assert any('mode="sum"' in k for k in keys)
    assert any('mode="stack"' in k for k in keys)


def test_retrace_warns_once_per_key():
    from repro.core.sim import jax_engine as je

    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    arr = SCENARIO_ZOO["mmpp_bursts"].build(2, duration_s=60, mean_rps=60.0)
    je.run_scenario(arr, wl, "reactive")
    key = ("reactive", "sum", False, "opt", False)
    n = je.runner_trace_count(*key)
    assert n >= 1
    # pretend the key was seen at a lower trace count: the next use must
    # warn exactly once, then stay quiet
    je._TRACE_SEEN[key] = n - 1
    je._TRACE_WARNED.discard(key)
    with pytest.warns(RuntimeWarning, match="retraced"):
        je.note_runner_use(*key)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert je.note_runner_use(*key) == n


def test_jax_trajectory_variant_gauges():
    """Variant-catalog trajectory runs expose the per-tick variant gauges
    (active index, swap-in-flight flag, delivered-accuracy rate) and stay
    summary-identical to sum mode — the gauge channels must not perturb
    the reduction."""
    from repro.core.sim import jax_engine as je

    wl = [dataclasses.replace(w, min_accuracy=0.55)
          for w in uniform_pool_workload(POOL[:4], strict_frac=0.25)]
    catalog = VariantCatalog.for_workload(wl)
    arr = SCENARIO_ZOO["trending_hotswap"].build(4, duration_s=300,
                                                 mean_rps=300.0)
    base = je.run_scenario(arr, wl, "infaas_variant", catalog=catalog)
    traj = je.run_scenario(arr, wl, "infaas_variant", catalog=catalog,
                           record_trajectory=True)

    assert set(base["summary"]) == set(traj["summary"])
    for k, v in base["summary"].items():
        np.testing.assert_allclose(traj["summary"][k], v, rtol=1e-6,
                                   err_msg=k)
    series = traj["trajectory"]
    for k in ("active_variant", "swap_in_flight", "acc_rate", "swaps"):
        assert k in series, k
    for k in ("active_variant", "swap_in_flight", "acc_rate"):
        assert np.asarray(series[k]).shape == (300, 4), k
    # flows still sum to the ledger; gauges describe states
    assert int(np.asarray(series["swaps"]).sum()) == base["summary"][
        "variant_swaps"]
    assert base["summary"]["variant_swaps"] > 0
    # the gauge channels are consistent with each other: while a swap is
    # in flight the delivered accuracy still reflects the OLD variant
    acc = np.asarray(series["acc_rate"])
    active = np.asarray(series["active_variant"])
    assert (acc > 0).all()
    vmax = max(len(vs) for vs in catalog.per_arch.values())
    assert active.min() >= 0 and (active < vmax).all()


def test_recorder_acc_rate_on_catalog_run():
    """The NumPy recorder's delivered-accuracy gauge mirrors the JAX
    ``acc_rate`` trajectory channel: populated on catalog runs and
    exported by ``as_dict``."""
    wl = [dataclasses.replace(w, min_accuracy=0.55)
          for w in uniform_pool_workload(POOL[:4], strict_frac=0.25)]
    catalog = VariantCatalog.for_workload(wl)
    tel = Telemetry(events=False)
    _run("trending_hotswap", "infaas_variant", 300, telemetry=tel,
         catalog=catalog, wl=wl)
    rec = tel.recorder
    d = rec.as_dict()
    assert "acc_rate" in d and "active_variant" in d
    assert d["acc_rate"].shape == (300, 4)
    assert (d["acc_rate"] > 0).all()
    # the gauge tracks the post-swap effective accuracy, so any tick
    # after a swap lands must show the new variant's accuracy
    assert d["active_variant"].shape == (300, 4)


# ---------------------------------------------------------------------------
# PPO training-curve stream.
# ---------------------------------------------------------------------------
def test_ppo_training_log(tmp_path):
    from repro.core.rl import EnvConfig, PPOConfig, PoolServingEnv, train_ppo_pool
    from repro.core.workloads import get_scenario

    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    env = PoolServingEnv(wl, EnvConfig(mean_rps=30, duration_s=60),
                         scenarios=[get_scenario("mmpp_bursts")])
    path = str(tmp_path / "curve.jsonl")
    state = train_ppo_pool(
        env, PPOConfig(iterations=2, rollout_len=60, hidden=16),
        log_path=path)
    rows = [json.loads(l) for l in open(path)]
    assert len(rows) == 2 == len(state.history)
    for row in rows:
        assert {"iter", "rollout_reward", "loss_mean", "pi_loss", "v_loss",
                "entropy_mean", "approx_kl"} <= set(row)
        assert np.isfinite([row["loss_mean"], row["entropy_mean"],
                            row["approx_kl"]]).all()
    assert rows == state.history            # the stream IS the history
