"""End-to-end behaviour: the paper's headline claims, in miniature.

Full-scale validations live in benchmarks/ (one per paper figure); these
run the same pipelines at reduced scale so the whole claim chain is
covered by ``pytest`` alone.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (
    Constraint,
    get_trace,
    model_pool,
    selection_cost,
    simulate,
    uniform_pool_workload,
)
from repro.core.hardware import PRICING
from repro.core.schedulers import SCHEDULERS

POOL = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
        "whisper-small", "recurrentgemma-9b"]
PREMIUM = dataclasses.replace(PRICING, burst_premium=8.0)


@pytest.fixture(scope="module")
def results():
    out = {}
    for tr in ("berkeley", "wiki"):
        trace = get_trace(tr, 1800, mean_rps=300)
        wl = uniform_pool_workload(POOL, strict_frac=0.25)
        out[tr] = {
            n: simulate(trace, wl, cls(), pricing=PREMIUM)
            for n, cls in SCHEDULERS.items()
        }
    return out


def test_fig4_vm_cheaper_at_constant_load():
    """Fig 4: at constant arrival rates that keep slices utilized (the
    paper's regime — its CNN VMs served ~10 req/s each), reserved slices
    always beat burst.  Our LLM slices serve 10-400 req/s, so 'constant
    load' scales with per-slice throughput."""
    pool = model_pool()
    for mult in (1.0, 2.0, 4.0):
        for arch, e in pool.items():
            rate = mult * e["throughput_rps"]
            n_slices = np.ceil(rate / e["throughput_rps"])
            vm_hourly = n_slices * e["chips"] * PRICING.reserved_chip_hour
            burst_hourly = rate * 3600 * e["burst_cost_per_req"]
            assert vm_hourly < burst_hourly, (arch, mult)


def test_fig4_crossover_at_tiny_load():
    """Beyond-paper corollary: at deep under-utilization the per-request
    burst pool is cheaper — the crossover the paper's CNN-scale VMs never
    see (EXPERIMENTS.md discusses this delta)."""
    e = model_pool()["rwkv6-1.6b"]
    rate = 0.02 * e["throughput_rps"]
    vm_hourly = e["chips"] * PRICING.reserved_chip_hour
    burst_hourly = rate * 3600 * e["burst_cost_per_req"]
    assert burst_hourly < vm_hourly


def test_fig5_overprovisioning_band(results):
    """Fig 5: util_aware / exascale hold 15-50% more capacity on the
    dynamic trace (paper: 20-30%)."""
    r = results["berkeley"]
    for name in ("util_aware", "exascale"):
        ratio = r[name].chip_seconds / r["reactive"].chip_seconds
        assert 1.10 < ratio < 1.65, (name, ratio)


def test_fig6_mixed_cost_and_slo(results):
    """Fig 6: mixed ~ reactive cost, violations cut by >= 60%."""
    r = results["berkeley"]
    cost_ratio = r["mixed"].cost_total / r["reactive"].cost_total
    assert cost_ratio < 1.30
    # and mixed is cheaper than holding spare VMs (util_aware/exascale)
    assert r["mixed"].cost_total < r["util_aware"].cost_total
    assert r["mixed"].violation_rate < 0.4 * r["reactive"].violation_rate


def test_fig6_wiki_mixed_no_benefit(results):
    """Observation 4: flat trace -> mixed burns almost no burst."""
    r = results["wiki"]
    assert r["mixed"].served_burst < 0.02 * r["mixed"].total_requests


def test_fig9a_paragon_cheaper_than_mixed_same_slo(results):
    """Fig 9a/b: Paragon >= ~5% cheaper than mixed, SLO far below reactive."""
    for tr in ("berkeley",):
        r = results[tr]
        saving = 1 - r["paragon"].cost_total / r["mixed"].cost_total
        assert saving > 0.04, (tr, saving)
        assert r["paragon"].violation_rate < 0.5 * r["reactive"].violation_rate


def test_fig9c_paragon_selection_cheaper_than_naive():
    """Fig 9c: constraint-aware selection >= 20% cheaper than naive."""
    rng = np.random.default_rng(0)
    cons = [
        Constraint(float(rng.uniform(0.3, 0.85)), float(rng.uniform(0.3, 2.0)))
        for _ in range(100)
    ]
    n = selection_cost(cons, "naive")
    p = selection_cost(cons, "paragon")
    assert p["cost"] < 0.8 * n["cost"]
    # and paragon still delivers the requested accuracy on average
    assert p["mean_accuracy"] >= 0.55


def test_fig9c_dynamic_fleet_routing():
    """Workload-2 as a dynamic simulation: routing the constraint stream
    through Paragon selection yields a cheaper FLEET than naive routing,
    in the paper's 'up to 20%' band.

    Scale matters: at low rates the per-arch instance floor quantizes the
    saving away (spreading over 6 archs pays 6 idle floors while naive's
    single big slice is fully amortized) — so this runs at the benchmark's
    fleet scale (400 req/s, 1 h)."""
    from repro.core.model_selection import selection_workload

    rng = np.random.default_rng(0)
    cons = [
        Constraint(float(rng.uniform(0.3, 0.85)), float(rng.uniform(0.3, 2.0)))
        for _ in range(500)
    ]
    trace = get_trace("berkeley", 3600, mean_rps=400)
    costs = {}
    for sel in ("naive", "paragon"):
        wl, skipped = selection_workload(cons, sel)
        assert skipped == 0
        costs[sel] = simulate(trace, wl, SCHEDULERS["paragon"](),
                              pricing=PREMIUM).cost_total
    saving = 1 - costs["paragon"] / costs["naive"]
    assert 0.08 <= saving <= 0.35, saving
