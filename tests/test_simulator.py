"""Simulator + scheduler invariants (deterministic; the hypothesis
property tests live in test_properties.py)."""
import dataclasses

import pytest

from repro.core.hardware import PRICING
from repro.core.sim import (
    Action,
    ArchLoad,
    ServingSim,
    simulate,
    uniform_pool_workload,
)
from repro.core.schedulers import SCHEDULERS
from repro.core.traces import get_trace

# low per-instance throughput -> flash crowds actually produce shortfalls
SMALL_ARCHS = ["llama3-8b", "minicpm-2b"]


# ---------------------------------------------------------------------------
# Conservation + determinism.
# ---------------------------------------------------------------------------
def _run(policy_name, trace_name="berkeley", secs=400, rps=60):
    trace = get_trace(trace_name, secs, mean_rps=rps)
    wl = uniform_pool_workload(SMALL_ARCHS, strict_frac=0.25)
    return simulate(trace, wl, SCHEDULERS[policy_name]())


@pytest.mark.parametrize("policy", sorted(SCHEDULERS))
def test_request_conservation(policy):
    res = _run(policy)
    queued_tail = res.total_requests - res.served_vm - res.served_burst
    assert queued_tail >= -1e-6, "served more than arrived"
    # whatever remains queued at the horizon is bounded by the abandon
    # window (3 x the relaxed SLO) of arrivals
    assert queued_tail <= 3 * 20.0 * 60 + 1e-6


@pytest.mark.parametrize("policy", sorted(SCHEDULERS))
def test_violations_bounded(policy):
    res = _run(policy)
    assert 0.0 <= res.violation_rate <= 1.0
    assert res.cost_total >= 0.0


def test_determinism():
    a = _run("paragon").summary()
    b = _run("paragon").summary()
    assert a == b


# ---------------------------------------------------------------------------
# The paper's structural claims, in miniature.
# ---------------------------------------------------------------------------
def test_overprovisioners_cost_more_than_reactive():
    base = _run("reactive")
    for name in ("util_aware", "exascale"):
        r = _run(name)
        assert r.cost_total >= base.cost_total * 0.99, name
        assert r.violation_rate <= base.violation_rate + 1e-9, name


def test_mixed_kills_violations_with_burst():
    base = _run("reactive")
    mixed = _run("mixed")
    assert mixed.violation_rate < base.violation_rate * 0.5
    assert mixed.served_burst > 0
    assert mixed.cost_burst > 0


def test_paragon_cheaper_than_mixed():
    mixed = _run("mixed")
    paragon = _run("paragon")
    assert paragon.cost_total <= mixed.cost_total
    # paragon never pays the burst premium for relaxed traffic
    assert paragon.cost_burst <= mixed.cost_burst


def test_flat_trace_needs_no_burst():
    """Observation 4: on the wiki-like trace, offload volume ~ 0."""
    trace = get_trace("wiki", 400, mean_rps=60)
    wl = uniform_pool_workload(SMALL_ARCHS, strict_frac=0.25)
    mixed = simulate(trace, wl, SCHEDULERS["mixed"]())
    assert mixed.served_burst < 0.02 * mixed.total_requests


def test_provisioning_latency_causes_reactive_violations():
    """With instant provisioning, reactive violations collapse."""
    fast = dataclasses.replace(PRICING, reserved_provision_s=1.0)
    trace = get_trace("berkeley", 400, mean_rps=60)
    wl = uniform_pool_workload(SMALL_ARCHS, strict_frac=0.25)
    slow_res = simulate(trace, wl, SCHEDULERS["reactive"]())
    fast_res = simulate(trace, wl, SCHEDULERS["reactive"](), pricing=fast)
    assert fast_res.violation_rate < slow_res.violation_rate


# ---------------------------------------------------------------------------
# Stepwise API.
# ---------------------------------------------------------------------------
def test_stepwise_equals_closed_loop():
    trace = get_trace("berkeley", 200, mean_rps=40)
    wl = [ArchLoad("qwen1.5-0.5b", 1.0, 0.25)]
    policy = SCHEDULERS["paragon"]()
    closed = simulate(trace, wl, policy)

    sim = ServingSim(trace, wl)
    policy2 = SCHEDULERS["paragon"]()
    while not sim.done:
        obs = sim.observe()
        sim.apply(policy2(sim.tick, obs))
    assert sim.res.summary() == closed.summary()


def test_apply_returns_marginal_metrics():
    trace = get_trace("wiki", 50, mean_rps=40)
    sim = ServingSim(trace, [ArchLoad("qwen1.5-0.5b", 1.0, 0.5)])
    total_cost = 0.0
    while not sim.done:
        sim.observe()
        m = sim.apply({"qwen1.5-0.5b": Action(target=1)})
        assert m["cost"] >= 0.0
        total_cost += m["cost"]
    assert abs(total_cost - sim.res.cost_total) < 1e-9


# ---------------------------------------------------------------------------
# Spot tier (beyond-paper, paper §VI future work).
# ---------------------------------------------------------------------------
def test_spot_policy_cheaper_at_fleet_scale():
    trace = get_trace("wiki", 1200, mean_rps=400)
    wl = [ArchLoad("llama3-8b", 0.6, 0.25), ArchLoad("minicpm-2b", 0.4, 0.25)]
    paragon = simulate(trace, wl, SCHEDULERS["paragon"]())
    spot = simulate(trace, wl, SCHEDULERS["spot_paragon"]())
    assert spot.cost_total < 0.75 * paragon.cost_total
    assert spot.cost_spot > 0
    assert spot.violations_strict == 0          # the on-demand floor holds
    assert spot.preemptions > 0                  # risk actually exercised


def test_spot_preemption_determinism():
    trace = get_trace("wiki", 600, mean_rps=300)
    wl = [ArchLoad("llama3-8b", 1.0, 0.25)]
    a = simulate(trace, wl, SCHEDULERS["spot_paragon"]()).summary()
    b = simulate(trace, wl, SCHEDULERS["spot_paragon"]()).summary()
    assert a == b


def test_spot_unused_by_default_policies():
    res = _run("paragon")
    assert res.cost_spot == 0.0 and res.preemptions == 0
