"""The tier portfolio (PR 5): the generic ResourceTier contract over
every tier, the harvest / multi-region tiers, the burst cold-batch and
spot in-flight-preemption bugfixes, the portfolio scheduler, and the RL
spot head."""
import dataclasses

import numpy as np
import pytest

from repro.core.hardware import PRICING
from repro.core.schedulers import SCHEDULERS, VECTOR_SCHEDULERS
from repro.core.sim import (
    BurstTier,
    HarvestVMTier,
    Ledger,
    MultiRegionReservedTier,
    PoolAction,
    ResourceTier,
    ServingSim,
    SpotTier,
    simulate,
    uniform_pool_workload,
)
from repro.core.workloads import get_scenario

POOL = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]

#: every policy-targetable held-capacity tier (the contract surface)
TIERS = {
    "reserved": ResourceTier,
    "spot": SpotTier,
    "harvest": HarvestVMTier,
    "remote": MultiRegionReservedTier,
}


def _mk(cls, n=3):
    return cls(n, PRICING)


# ---------------------------------------------------------------------------
# The generic ResourceTier contract, parametrized over every tier.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cls", TIERS.values(), ids=TIERS.keys())
def test_tier_pop_ready_latency_exact(cls):
    """Launches come online exactly provision_latency_s ticks later."""
    tier = _mk(cls)
    lat = int(tier.provision_latency_s())
    target = np.array([2, 0, 1])
    tier.set_target(0, target)
    assert (tier.active == 0).all()
    np.testing.assert_array_equal(tier.pending_total, target)
    for t in range(1, lat):
        tier.set_target(t, target)
        assert (tier.active == 0).all(), f"came online early at {t}"
    tier.set_target(lat, target)
    np.testing.assert_array_equal(tier.active, target)
    assert (tier.pending_total == 0).all()


@pytest.mark.parametrize("cls", TIERS.values(), ids=TIERS.keys())
def test_tier_cancel_newest_ordering(cls):
    """A shrink cancels the NEWEST in-flight launches first: the oldest
    batch still arrives on schedule."""
    tier = _mk(cls)
    lat = int(tier.provision_latency_s())
    tier.set_target(0, np.array([3, 0, 0]))       # batch 1: ready at lat
    tier.set_target(2, np.array([5, 0, 0]))       # batch 2 (+2): ready at lat+2
    tier.set_target(3, np.array([3, 0, 0]))       # cancels batch 2 only
    assert tier.pending_total[0] == 3
    tier.set_target(lat, np.array([3, 0, 0]))
    assert tier.active[0] == 3                    # batch 1 arrived intact
    tier.set_target(lat + 2, np.array([3, 0, 0]))
    assert tier.active[0] == 3                    # batch 2 never does


@pytest.mark.parametrize("cls", TIERS.values(), ids=TIERS.keys())
def test_tier_grow_shrink_idempotent(cls):
    """Re-applying the same target tick after tick launches nothing new
    (in-flight counts toward the target); shrinking below active
    releases immediately and never goes negative."""
    tier = _mk(cls)
    lat = int(tier.provision_latency_s())
    target = np.array([4, 1, 2])
    tier.set_target(0, target)
    np.testing.assert_array_equal(tier.pending_total, target)
    for t in range(1, lat + 1):
        tier.set_target(t, target)
        np.testing.assert_array_equal(
            tier.active + tier.pending_total, target
        )
    np.testing.assert_array_equal(tier.active, target)
    tier.set_target(lat + 1, target)              # steady state: no-op
    np.testing.assert_array_equal(tier.active, target)
    assert (tier.pending_total == 0).all()
    tier.set_target(lat + 2, np.array([1, 0, 2]))
    np.testing.assert_array_equal(tier.active, [1, 0, 2])
    tier.set_target(lat + 3, np.zeros(3, dtype=np.int64))
    assert (tier.active == 0).all()


@pytest.mark.parametrize("cls", TIERS.values(), ids=TIERS.keys())
def test_tier_billing_is_active_x_chips_x_price(cls):
    """Every tick, account() posts active x chips x price_per_chip_s
    into the ledger under the tier's name."""
    tier = _mk(cls)
    tier.active = np.array([2, 0, 3])
    chips = np.array([1.0, 2.0, 4.0])
    led = Ledger()
    for _ in range(5):
        chip_s = tier.account(led, chips)
    np.testing.assert_array_equal(chip_s, tier.active * chips)
    expected = 5 * float((tier.active * chips).sum()) * tier.price_per_chip_s()
    res = led.res
    posted = {
        "reserved": res.cost_reserved, "spot": res.cost_spot,
        "harvest": res.cost_other.get("harvest", 0.0),
        "remote": res.cost_other.get("remote", 0.0),
    }[tier.name]
    assert posted == pytest.approx(expected, rel=1e-12)
    assert res.cost_total == pytest.approx(expected, rel=1e-12)


def test_tier_prices_are_ordered():
    """The portfolio's price ladder: harvest < spot < remote < reserved."""
    tiers = {name: _mk(cls) for name, cls in TIERS.items()}
    p = {n: t.price_per_chip_s() for n, t in tiers.items()}
    assert p["harvest"] < p["spot"] < p["remote"] < p["reserved"]
    assert tiers["remote"].egress_latency_s() > 0
    for n in ("reserved", "spot", "harvest"):
        assert tiers[n].egress_latency_s() == 0


# ---------------------------------------------------------------------------
# Spot: in-flight launches are NOT immune to reclaim waves.
# ---------------------------------------------------------------------------
def test_spot_pipeline_not_immune_to_preemption():
    """With a certain-reclaim rate, capacity parked in the provisioning
    pipeline dies there: a policy cannot hide instances from a reclaim
    wave by keeping them perpetually in flight."""
    pricing = dataclasses.replace(PRICING, spot_preempt_rate=float("inf"))
    tier = SpotTier(2, pricing)
    assert tier.reclaim_probability() == 1.0
    rng = np.random.default_rng(0)
    led = Ledger()
    target = np.array([3, 2])
    tier.set_target(0, target)
    for t in range(1, 20):
        tier.begin_tick(t, rng, led)
        assert (tier.pipeline.total == 0).all()   # the wave got them all
        tier.set_target(t, target)                # relaunch...
    assert (tier.active == 0).all()               # ...nothing ever lands
    assert led.res.preemptions == 19 * int(target.sum())


def test_spot_pipeline_reclaim_probabilistic_and_ledgered():
    """At an intermediate rate both active instances and in-flight
    launches are reclaimed, and every loss is ledgered."""
    pricing = dataclasses.replace(PRICING, spot_preempt_rate=0.05,
                                  spot_provision_s=10)
    tier = SpotTier(4, pricing)
    rng = np.random.default_rng(7)
    led = Ledger()
    target = np.full(4, 50, dtype=np.int64)
    held = 0
    for t in range(200):
        tier.begin_tick(t, rng, led)
        tier.set_target(t, target)
        held = int(tier.active.sum())
        assert (tier.active >= 0).all() and (tier.pipeline.buf >= 0).all()
        total = tier.pipeline.total
        np.testing.assert_array_equal(total, tier.pipeline.buf.sum(axis=1))
    assert led.res.preemptions > 0
    assert held < 200                              # churn keeps it below target


# ---------------------------------------------------------------------------
# Harvest: pool-correlated eviction under the availability signal.
# ---------------------------------------------------------------------------
def test_harvest_eviction_is_correlated_and_ledgered():
    tier = HarvestVMTier(3, PRICING, seed=1)
    tier._advance = lambda: None                  # pin the signal
    tier.level = 1.0
    cap = PRICING.harvest_cap_per_arch
    rng = np.random.default_rng(0)
    led = Ledger()
    target = np.full(3, cap, dtype=np.int64)
    lat = int(tier.provision_latency_s())
    for t in range(lat + 1):
        tier.begin_tick(t, rng, led)
        tier.set_target(t, target)
    np.testing.assert_array_equal(tier.active, target)
    assert led.res.preemptions == 0
    # the signal sags: every arch is clipped to the SAME new ceiling in
    # the same tick (one correlated wave, not i.i.d. draws)
    tier.level = 0.5
    tier.begin_tick(lat + 1, rng, led)
    ceiling = int(0.5 * cap)
    np.testing.assert_array_equal(tier.active, np.full(3, ceiling))
    assert led.res.preemptions == 3 * (cap - ceiling)


def test_harvest_ceiling_caps_grants_and_inflight():
    """Requests above the harvested ceiling are never granted, and a
    ceiling drop also flushes the in-flight overflow."""
    tier = HarvestVMTier(2, PRICING, seed=1)
    tier._advance = lambda: None
    tier.level = 1.0
    cap = PRICING.harvest_cap_per_arch
    rng = np.random.default_rng(0)
    led = Ledger()
    want = np.full(2, 10 * cap, dtype=np.int64)
    tier.set_target(0, want)
    np.testing.assert_array_equal(tier.pending_total, np.full(2, cap))
    tier.level = 0.25
    tier.begin_tick(1, rng, led)
    assert (tier.pending_total <= tier.ceiling()).all()
    assert led.res.preemptions == 0               # cancelled, never ran
    lat = int(tier.provision_latency_s())
    for t in range(1, lat + 2):
        tier.begin_tick(t, rng, led) if t > 1 else None
        tier.set_target(t, want)
    assert (tier.active <= tier.ceiling()).all()


def test_harvest_signal_advances_while_idle():
    """The availability signal is provider-side state: it must evolve
    with TIME, not with usage — the trajectory a policy observes cannot
    depend on whether it (or any other policy) held harvest capacity."""
    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    arr = np.full((2, 120), 10.0)
    idle = PoolAction(target=np.array([1, 1]))
    sim = ServingSim(arr, wl)                      # never touches harvest
    levels = []
    while not sim.done:
        obs = sim.observe_pool()
        levels.append(float(obs.harvest_level[0]))
        sim.apply_pool(idle)
    assert len(set(levels)) > 10                   # it moves every tick
    # and the trajectory is the same whether or not harvest was used
    sim2 = ServingSim(arr, wl)
    grow = PoolAction(target=np.array([1, 1]),
                      harvest_target=np.array([2, 2]))
    levels2 = []
    while not sim2.done:
        obs = sim2.observe_pool()
        levels2.append(float(obs.harvest_level[0]))
        sim2.apply_pool(grow)
    assert levels2 == levels


def test_harvest_obs_tracks_signal_when_idle():
    """After the harvest tier drains to idle, observations must keep
    reporting the signal's current level and ceiling — not init-time
    statics — or a reactivating policy over-bets on phantom harvest
    capacity."""
    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    arr = np.full((2, 400), 20.0)
    sim = ServingSim(arr, wl)
    sim.harvest._advance = lambda: None            # pin the signal
    grow = PoolAction(target=np.array([1, 1]),
                      harvest_target=np.array([2, 2]))
    idle = PoolAction(target=np.array([1, 1]))
    sim.observe_pool()
    sim.apply_pool(grow)                           # tier goes live
    sim.harvest.level = 0.5                        # availability sagged...
    sim.observe_pool()
    sim.apply_pool(idle)                           # ...and the policy lets
    while sim.harvest.active.any() or sim.harvest.pipeline.total.any():
        sim.observe_pool()                         # the fleet drain out
        sim.apply_pool(idle)
    obs = sim.observe_pool()
    sim.apply_pool(idle)
    assert not sim._tier_live["harvest"]
    np.testing.assert_allclose(obs.harvest_level, 0.5)
    np.testing.assert_array_equal(
        obs.harvest_ceiling,
        int(0.5 * PRICING.harvest_cap_per_arch),
    )


def test_harvest_signal_is_seeded_and_bounded():
    a = HarvestVMTier(1, PRICING, seed=9)
    b = HarvestVMTier(1, PRICING, seed=9)
    c = HarvestVMTier(1, PRICING, seed=10)
    la, lb, lc = [], [], []
    for _ in range(500):
        a._advance(); b._advance(); c._advance()
        la.append(a.level); lb.append(b.level); lc.append(c.level)
    assert la == lb                               # same seed, same signal
    assert la != lc
    assert min(la) >= HarvestVMTier.LEVEL_MIN and max(la) <= 1.0
    assert np.std(la) > 0.01                      # it actually moves


# ---------------------------------------------------------------------------
# Burst: only the pool-warming first invocation of a cold batch pays
# the cold start (satellite bugfix regression).
# ---------------------------------------------------------------------------
def _mk_burst(prewarm=False):
    # warm latency (spinup 1 + lat_b1 0.5 = 1.5) meets the 2 s strict
    # SLO; the cold start (+30) blows it
    return BurstTier(
        PRICING,
        lat_b1=np.array([0.5, 0.5]),
        cold_start_s=np.array([30.0, 30.0]),
        cost_per_request=np.array([1e-4, 1e-4]),
        prewarm=prewarm,
    )


def test_burst_cold_batch_violates_exactly_once():
    burst = _mk_burst(prewarm=False)
    led = Ledger()
    viol = burst.offload(1000, np.array([7.0, 0.0]), 2.0, True, led)
    # the first invocation warmed the pool; the other 6 rode it warm
    np.testing.assert_allclose(viol, [1.0, 0.0])
    assert led.res.violations == 1.0
    assert led.res.violations_strict == 1.0
    assert led.res.served_burst == 7.0
    # same tick, the pool is warm for the next batch of the same arch
    viol2 = burst.offload(1000, np.array([4.0, 0.0]), 2.0, True, led)
    np.testing.assert_allclose(viol2, [0.0, 0.0])
    assert led.res.violations == 1.0


def test_burst_cold_subunit_mass_and_warm_batches():
    burst = _mk_burst(prewarm=False)
    led = Ledger()
    # a fluid sub-unit cold batch cannot violate more than its own mass
    viol = burst.offload(50, np.array([0.25, 0.0]), 2.0, False, led)
    np.testing.assert_allclose(viol, [0.25, 0.0])
    assert led.res.violations_strict == 0.0
    # a warm batch (within the idle timeout) violates nothing
    viol = burst.offload(51, np.array([9.0, 0.0]), 2.0, False, led)
    np.testing.assert_allclose(viol, [0.0, 0.0])
    # ...but the second arch's pool is still cold
    viol = burst.offload(51, np.array([0.0, 3.0]), 2.0, False, led)
    np.testing.assert_allclose(viol, [0.0, 1.0])


def test_burst_warm_latency_over_slo_still_violates_whole_batch():
    """When even the WARM path misses the SLO, the whole batch is late —
    the fix only exempts warm followers, not slow models."""
    burst = BurstTier(
        PRICING,
        lat_b1=np.array([5.0]),                    # warm 6.0 > slo 2.0
        cold_start_s=np.array([30.0]),
        cost_per_request=np.array([1e-4]),
        prewarm=True,
    )
    led = Ledger()
    viol = burst.offload(0, np.array([8.0]), 2.0, True, led)
    np.testing.assert_allclose(viol, [8.0])


# ---------------------------------------------------------------------------
# Burst follows the active variant (satellite bugfix: variant-aware
# burst latency on swap completion).
# ---------------------------------------------------------------------------
def test_burst_latency_refreshed_on_swap_completion():
    from repro.core.sim import VariantCatalog

    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    catalog = VariantCatalog.for_workload(wl)
    arr = np.full((len(POOL), 200), 5.0)
    sim = ServingSim(arr, wl, catalog=catalog)
    base_lat = sim.burst.lat_b1.copy()
    np.testing.assert_array_equal(base_lat, sim.lat_b1)   # base = itself
    base_var = sim.swap.current.copy()
    target = np.where(base_var + 1 < sim.var_n, base_var + 1,
                      base_var - 1).astype(np.int64)
    sim.observe_pool()
    sim.apply_pool(PoolAction(target=np.ones(len(POOL), dtype=np.int64),
                              variant_target=target))
    hold = PoolAction(target=np.ones(len(POOL), dtype=np.int64))
    for _ in range(int(sim.pricing.variant_swap_s)):
        # the reload has not landed: burst still serves the OLD weights
        np.testing.assert_array_equal(sim.burst.lat_b1, base_lat)
        sim.observe_pool()
        sim.apply_pool(hold)
    # swap landed: burst latency now tracks the active variant's batch-1
    lmult = np.take_along_axis(sim.var_lmult, sim.swap.current[:, None], 1)[:, 0]
    np.testing.assert_allclose(sim.burst.lat_b1, sim.lat_b1 * lmult)
    assert (sim.burst.lat_b1 != base_lat).any()
    # ...while queue slack geometry stays pinned to the base variant
    np.testing.assert_array_equal(
        sim.q_strict.slack,
        np.maximum(0, (2.0 - sim.lat_b1).astype(np.int64)),
    )


# ---------------------------------------------------------------------------
# Multi-region tier through the engine: strict prefers local.
# ---------------------------------------------------------------------------
def _drive(sim, action):
    while not sim.done:
        sim.observe_pool()
        sim.apply_pool(action)
    return sim.res


def test_remote_serves_but_strict_prefers_local():
    """With local capacity sized for the strict class and remote for the
    rest, strict traffic never pays the egress adder — zero strict
    violations even when egress alone would blow the strict SLO."""
    pricing = dataclasses.replace(PRICING, remote_egress_s=3.0)  # > strict slo
    wl = uniform_pool_workload(["llama3-8b"], strict_frac=0.5)
    arr = np.full((1, 900), 150.0)
    sim = ServingSim(arr, wl, pricing=pricing)
    res = _drive(sim, PoolAction(
        target=np.array([1]),                     # local: 104 rps > strict 75
        remote_target=np.array([1]),              # remote absorbs the rest
    ))
    assert res.violations_strict == 0.0
    assert res.cost_other["remote"] > 0.0
    assert sim.remote.active[0] == 1
    # the pool conserves: everything arrived was served or swept late
    counts = sim.per_arch_counts()
    accounted = (counts["served_vm"] + counts["served_burst"]
                 + counts["dropped"] + counts["expired_end"] + counts["queued"])
    np.testing.assert_allclose(counts["arrived"], accounted, atol=1e-6)


def test_remote_egress_makes_remote_served_strict_late():
    """Strict mass that can only be served remotely books the egress
    adder: with egress > strict SLO it is late even served at age 0."""
    pricing = dataclasses.replace(PRICING, remote_egress_s=3.0)
    wl = uniform_pool_workload(["llama3-8b"], strict_frac=0.5)
    arr = np.full((1, 900), 150.0)
    sim = ServingSim(arr, wl, pricing=pricing, warm_start=False)
    res = _drive(sim, PoolAction(
        target=np.array([0]),                     # no local capacity at all
        remote_target=np.array([2]),
    ))
    late = sim.violations_arch[0]
    # EVERY strict request is late: dropped while the remote pipeline
    # provisions, then served remotely with egress > SLO forever after
    assert res.violations_strict == pytest.approx(900 * 75.0)
    # with the default (sub-SLO) egress only the provisioning window's
    # drops violate; remote-served strict traffic at age 0 is on time
    sim2 = ServingSim(arr, wl, warm_start=False)
    res2 = _drive(sim2, PoolAction(
        target=np.array([0]), remote_target=np.array([2]),
    ))
    assert res2.violations_strict < res.violations_strict * 0.5
    assert late >= res.violations_strict


# ---------------------------------------------------------------------------
# The portfolio scheduler.
# ---------------------------------------------------------------------------
def test_portfolio_dict_vector_parity_and_tier_mix():
    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    arr = get_scenario("mmpp_bursts").build(len(POOL), duration_s=500,
                                            mean_rps=300)
    d = simulate(arr, wl, SCHEDULERS["portfolio"]()).summary()
    v = simulate(arr, wl, VECTOR_SCHEDULERS["portfolio"]()).summary()
    assert d == v
    assert d["cost_harvest"] > 0                  # the portfolio actually
    assert d["cost_reserved"] > 0                 # spreads across tiers


def test_portfolio_per_arch_flow_conservation():
    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    arr = get_scenario("flash_anti").build(len(POOL), duration_s=400,
                                           mean_rps=240)
    sim = ServingSim(arr, wl)
    pol = VECTOR_SCHEDULERS["portfolio"]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
        counts = sim.per_arch_counts()
        accounted = (
            counts["served_vm"] + counts["served_burst"] + counts["dropped"]
            + counts["expired_end"] + counts["queued"]
        )
        np.testing.assert_allclose(counts["arrived"], accounted, atol=1e-6)
    assert sim.res.cost_total > 0


def test_portfolio_cheaper_than_reserved_only_at_fleet_scale():
    """The headline: splitting the base load across the discounted tiers
    undercuts all-reserved reactive provisioning at equal-or-better
    violations on a fleet-scale steady load."""
    wl = uniform_pool_workload(["llama3-8b", "minicpm-2b"], strict_frac=0.25)
    arr = np.full((2, 1200), 300.0)
    portfolio = simulate(arr, wl, VECTOR_SCHEDULERS["portfolio"]())
    reactive = simulate(arr, wl, VECTOR_SCHEDULERS["reactive"]())
    assert portfolio.cost_total < reactive.cost_total
    assert portfolio.violations_strict <= reactive.violations_strict
    # decomposition: per-tier costs sum to the ledger total
    s = portfolio.summary()
    parts = (s["cost_reserved"] + s["cost_spot"] + s["cost_burst"]
             + s.get("cost_harvest", 0.0) + s.get("cost_remote", 0.0))
    assert parts == pytest.approx(s["cost_total"], abs=5e-4)


# ---------------------------------------------------------------------------
# The RL spot head.
# ---------------------------------------------------------------------------
def test_procurement_action_spot_head():
    from repro.core.rl.obs import (
        N_PROCURE,
        N_VARIANT_SPACE,
        procurement_action,
    )

    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    arr = np.full((len(POOL), 10), 5.0)
    sim = ServingSim(arr, wl)
    obs = sim.observe_pool()
    n = len(POOL)
    # hold-first: every pre-spot action index decodes spot_target == 0
    # and the reserved sizing is exactly the legacy rule
    legacy = np.maximum(1, np.ceil(
        0.85 * (obs.ewma_rate + (obs.queue_strict + obs.queue_relaxed) / 5.0)
        / obs.throughput
    )).astype(np.int64)
    for a in (0, N_PROCURE, N_VARIANT_SPACE - 1):
        act = procurement_action(obs, np.full(n, a))
        assert (act.spot_target == 0).all()
    np.testing.assert_array_equal(
        procurement_action(obs, np.zeros(n, dtype=np.int64)).target, legacy
    )
    # grow steps the fleet by one; shrink clips at zero
    grow = procurement_action(obs, np.full(n, N_VARIANT_SPACE))
    np.testing.assert_array_equal(grow.spot_target, np.ones(n))
    shrink = procurement_action(obs, np.full(n, 2 * N_VARIANT_SPACE))
    np.testing.assert_array_equal(shrink.spot_target, np.zeros(n))
    # spot capacity offsets the reserved sizing (floor at 1 instance)
    assert (grow.target <= procurement_action(
        obs, np.zeros(n, dtype=np.int64)).target).all()


def test_spot_head_holds_and_drains_through_engine():
    """Driving grow for a while then hold: the engine fleet follows, and
    hold keeps (not drops) the in-flight fleet."""
    from repro.core.rl.obs import N_VARIANT_SPACE, procurement_action

    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    arr = np.full((2, 300), 40.0)
    sim = ServingSim(arr, wl)
    grow = np.full(2, N_VARIANT_SPACE)            # smove = grow, proc 0
    hold = np.zeros(2, dtype=np.int64)
    for _ in range(10):
        obs = sim.observe_pool()
        sim.apply_pool(procurement_action(obs, grow))
    obs = sim.observe_pool()
    in_flight = obs.n_spot + obs.n_spot_pending
    np.testing.assert_array_equal(in_flight, np.full(2, 10))
    sim.apply_pool(procurement_action(obs, hold))
    obs = sim.observe_pool()
    np.testing.assert_array_equal(obs.n_spot + obs.n_spot_pending,
                                  np.full(2, 10))
    # reward attribution: held spot capacity costs money per arch
    m = sim.apply_pool(procurement_action(obs, hold))
    while not sim.done:
        obs = sim.observe_pool()
        m = sim.apply_pool(procurement_action(obs, hold))
        if (obs.n_spot > 0).any():
            break
    assert (m["cost_arch"] > 0).all()


def test_pool_features_spot_state():
    from repro.core.rl.obs import OBS_DIM, RISK_SCALE, pool_features

    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    arr = np.full((len(POOL), 10), 5.0)
    sim = ServingSim(arr, wl)
    obs = sim.observe_pool()
    f = pool_features(obs, obs.rate, rate_scale=100.0, fleet_scale=10.0)
    assert f.shape == (len(POOL), OBS_DIM)
    np.testing.assert_allclose(f[:, 12], 0.0)     # no spot fleet yet
    np.testing.assert_allclose(f[:, 13], 0.0)
    np.testing.assert_allclose(
        f[:, 14],
        np.float32(min(1.0, sim.spot.reclaim_probability() * RISK_SCALE)),
    )
    np.testing.assert_allclose(f[:, 15], 1.0)     # full harvest signal


def test_pool_action_tier_defaults():
    a = PoolAction(target=np.array([1, 2]))
    assert (a.harvest_targets(2) == 0).all()
    assert (a.remote_targets(2) == 0).all()
