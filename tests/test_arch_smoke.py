"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates its REDUCED family variant and
runs one forward + one train step on CPU, asserting output shapes and the
absence of NaNs.  Decode-capable archs also run one serve step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_architectures
from repro.models import model as model_lib
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update

ARCHS = list_architectures()


def _batch_for(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.frontend == "vision":
        inputs = rng.standard_normal((b, s, cfg.d_model)).astype(np.float32)
    else:
        inputs = rng.integers(0, cfg.vocab_size, size=(b, s)).astype(np.int32)
    batch = {
        "inputs": jnp.asarray(inputs),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(b, s)).astype(np.int32)
        ),
    }
    if cfg.is_encoder_decoder:
        batch["enc_inputs"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = model_lib.init_params(cfg, jax.random.key(0))
    batch = _batch_for(cfg)
    logits, aux = model_lib.forward(
        cfg, params, batch["inputs"], enc_inputs=batch.get("enc_inputs")
    )
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert bool(jnp.isfinite(jnp.asarray(aux)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nan(arch):
    cfg = get_config(arch).reduced()
    params = model_lib.init_params(cfg, jax.random.key(1))
    batch = _batch_for(cfg, seed=1)
    ocfg = OptimizerConfig(lr=1e-3)
    opt = adamw_init(params, ocfg)

    (loss, parts), grads = jax.value_and_grad(
        lambda p: model_lib.loss_fn(cfg, p, batch), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss)), arch
    new_params, opt, metrics = adamw_update(params, grads, opt, ocfg)
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step(arch):
    cfg = get_config(arch).reduced()
    if cfg.frontend == "vision":
        pytest.skip("vision serving exercised via embeddings in test_serving")
    params = model_lib.init_params(cfg, jax.random.key(2))
    batch = _batch_for(cfg, seed=2)
    cache = model_lib.init_cache(cfg, 2, 32)
    last, cache = model_lib.prefill(
        cfg, params, batch["inputs"], cache, enc_inputs=batch.get("enc_inputs")
    )
    assert last.shape == (2, cfg.vocab_size)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    logits, cache = model_lib.decode_step(cfg, params, nxt, cache)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
