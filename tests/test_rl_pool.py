"""The pool-wide RL control path: PoolServingEnv contract, per-arch
reward decomposition, single-arch wrapper regression pins, the batched
PPO trainer, and the deployable RLPoolPolicy scheduler."""
import numpy as np
import pytest

from repro.core.rl import (
    EnvConfig,
    N_ACTIONS,
    OBS_DIM,
    PPOConfig,
    PoolServingEnv,
    RLPoolPolicy,
    ServingEnv,
    SPOT_MOVES,
    evaluate_pool_policy,
    save_policy_params,
    train_ppo_pool,
)
from repro.core.hardware import PRICING
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import ArchLoad, simulate, uniform_pool_workload
from repro.core.traces import get_trace
from repro.core.workloads import get_scenario

POOL = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]

#: the pre-spot-head action space: the spot head (PR 5) is hold-first
#: (outermost factor), so indices below this decode exactly as before —
#: the regression pins below drive this legacy subspace
N_LEGACY = N_ACTIONS // len(SPOT_MOVES)


@pytest.fixture(scope="module")
def pool_env():
    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    cfg = EnvConfig(mean_rps=60, duration_s=150)
    scs = [get_scenario("mmpp_bursts"), get_scenario("flash_anti")]
    return PoolServingEnv(wl, cfg, scenarios=scs, scenario_seed=3)


# ---------------------------------------------------------------------------
# Regression pins: the single-arch wrapper must reproduce the
# pre-refactor ServingEnv episode results on fixed traces.
# ---------------------------------------------------------------------------
def test_single_arch_wrapper_reproduces_prerefactor_episode():
    """Golden values recorded from the dict-interface ServingEnv at the
    PR 2 tree (cyclic action sequence over a fixed twitter trace).

    The variant axis (PR 4) appended two observation features (variant
    position = 0.0 on the default single-variant catalog, accuracy
    headroom = the arch's quality over a 0.0 floor) and tripled
    N_ACTIONS with a hold-first variant head.  The tier portfolio
    (PR 5) appended the spot/harvest features (spot fleet and pipeline
    = 0.0, reclaim risk constant, harvest level = 1.0) and tripled the
    space again with a hold-first spot head; the action stream cycles
    the LEGACY subspace ``t % N_LEGACY`` — which PR 4's ``t %
    N_ACTIONS`` stream decoded to — so every episode total is
    unchanged.
    """
    trace = get_trace("twitter", 300, mean_rps=40)
    env = ServingEnv(EnvConfig(arch="qwen1.5-0.5b", mean_rps=40), trace)
    obs = env.reset()
    risk = np.float32(min(1.0, (1.0 - np.exp(-PRICING.spot_preempt_rate)) * 600.0))
    np.testing.assert_allclose(
        obs,
        [0.1769973784685135, 0.1769973784685135, 0.20000000298023224,
         0.04424934461712837, 0.13274803757667542, 0.10000000149011612,
         0.0, 0.0, 0.0, 0.0,
         0.0, 0.3930000066757202,
         0.0, 0.0, float(risk), 1.0],
        rtol=0, atol=1e-12,
    )
    total, done, t = 0.0, False, 0
    while not done:
        obs, r, done, _ = env.step(t % N_LEGACY)
        total += r
        t += 1
    res = env.episode_result()
    assert t == 300
    assert total == pytest.approx(-10.0, abs=1e-9)
    assert res.cost_total == pytest.approx(0.1, abs=1e-12)
    assert res.violations == 0.0
    assert res.served_vm == pytest.approx(12000.0)


def test_single_arch_wrapper_golden_with_offload():
    """Second pin on a demanding trace that exercises burst offload
    (legacy action subspace — see the docstring above)."""
    trace = get_trace("berkeley", 400, mean_rps=80, seed=5)
    env = ServingEnv(EnvConfig(arch="llama3-8b", mean_rps=80), trace)
    env.reset()
    total, done, t = 0.0, False, 0
    while not done:
        _, r, done, _ = env.step((7 * t + 3) % N_LEGACY)
        total += r
        t += 1
    res = env.episode_result()
    assert total == pytest.approx(-32.6645504766, abs=1e-6)
    assert res.cost_total == pytest.approx(0.3266455048, abs=1e-8)
    assert res.served_burst == pytest.approx(1770.9989036054, abs=1e-6)


# ---------------------------------------------------------------------------
# Pool env contract.
# ---------------------------------------------------------------------------
def test_pool_env_reset_determinism():
    """Same scenario_seed -> identical episode sequences (arrivals AND
    observations); consecutive episodes differ (fresh realizations)."""
    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    cfg = EnvConfig(mean_rps=60, duration_s=120)
    scs = [get_scenario("mmpp_bursts"), get_scenario("diurnal_phases")]
    e1 = PoolServingEnv(wl, cfg, scenarios=scs, scenario_seed=5)
    e2 = PoolServingEnv(wl, cfg, scenarios=scs, scenario_seed=5)
    o1, o2 = e1.reset(), e2.reset()
    np.testing.assert_array_equal(o1, o2)
    np.testing.assert_array_equal(e1.sim.arrivals, e2.sim.arrivals)
    assert e1.last_scenario.name == e2.last_scenario.name
    ep1 = e1.sim.arrivals.copy()
    e1.reset()
    assert not np.array_equal(e1.sim.arrivals, ep1)   # fresh realization
    assert e1.sim.arrivals.shape == (len(wl), 120)


def test_pool_env_obs_parity_with_single_arch_wrapper():
    """At A=1 the pool env's [1, OBS_DIM] rows equal the wrapper's flat
    observation, tick for tick, under the same action stream."""
    cfg = EnvConfig(arch="qwen1.5-0.5b", mean_rps=40, duration_s=200)
    trace = get_trace("twitter", 200, mean_rps=40)
    pool = PoolServingEnv([ArchLoad(cfg.arch, 1.0, cfg.strict_frac)], cfg,
                          arrivals=trace)
    single = ServingEnv(cfg, trace)
    op, os_ = pool.reset(), single.reset()
    assert op.shape == (1, OBS_DIM)
    np.testing.assert_array_equal(op[0], os_)
    done = False
    t = 0
    while not done:
        a = (5 * t + 1) % N_ACTIONS
        op, rp, done, _ = pool.step(np.array([a]))
        os_, rs, done_s, _ = single.step(a)
        assert done == done_s
        np.testing.assert_array_equal(op[0], os_)
        assert float(rp.sum()) == pytest.approx(rs, abs=1e-12)
        t += 1
    assert pool.episode_result().summary() == single.episode_result().summary()


def test_pool_reward_decomposition_sums_to_pool_reward(pool_env):
    """The [A] reward vector must sum to the scalar pool reward computed
    from the ledger's marginal cost/violations, every tick."""
    cfg = pool_env.cfg
    pool_env.reset()
    rng = np.random.default_rng(0)
    done = False
    while not done:
        a = rng.integers(0, N_ACTIONS, size=pool_env.n_archs)
        _, r_arch, done, m = pool_env.step(a)
        assert r_arch.shape == (pool_env.n_archs,)
        scalar = -cfg.reward_scale * (
            m["cost"] + cfg.violation_penalty * m["violations"]
        )
        assert float(r_arch.sum()) == pytest.approx(scalar, abs=1e-9)
        # and the engine's per-arch marginals sum to the ledger marginals
        assert float(m["cost_arch"].sum()) == pytest.approx(m["cost"], abs=1e-12)
        assert float(m["violations_arch"].sum()) == pytest.approx(
            m["violations"], abs=1e-9
        )


def test_pool_env_runs_all_zoo_scenarios():
    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    cfg = EnvConfig(mean_rps=30, duration_s=60)
    env = PoolServingEnv(wl, cfg, scenarios=[get_scenario("diurnal_flash_splice")])
    env.reset()
    done, steps = False, 0
    while not done:
        _, r, done, _ = env.step(np.full(2, steps % N_ACTIONS))
        assert np.isfinite(r).all()
        steps += 1
    assert steps == 60


# ---------------------------------------------------------------------------
# Batched PPO on a tiny pool.
# ---------------------------------------------------------------------------
def test_ppo_pool_smoke_three_iterations():
    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    cfg = EnvConfig(mean_rps=30, duration_s=80)
    env = PoolServingEnv(wl, cfg, scenarios=[get_scenario("mmpp_bursts")],
                         scenario_seed=2)
    state = train_ppo_pool(env, PPOConfig(iterations=3, rollout_len=80,
                                          hidden=16, seed=1))
    assert len(state.history) == 3
    assert np.isfinite(state.best_reward)
    assert state.best_reward >= state.history[0]["rollout_reward"]
    res = evaluate_pool_policy(env, state.params, seed=3)
    assert res.total_requests > 0
    assert res.violation_rate < 0.5


# ---------------------------------------------------------------------------
# The deployable scheduler.
# ---------------------------------------------------------------------------
def test_rl_pool_registered_in_vector_schedulers():
    assert VECTOR_SCHEDULERS["rl_pool"] is RLPoolPolicy
    assert getattr(RLPoolPolicy, "vectorized", False)


def test_rl_pool_policy_runs_and_is_deterministic(tmp_path):
    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    arrivals = get_scenario("flash_anti").build(len(wl), duration_s=120,
                                                mean_rps=50)
    missing = str(tmp_path / "nope.json")
    with pytest.warns(UserWarning, match="UNTRAINED"):
        p1 = RLPoolPolicy(checkpoint=missing, seed=7)
    with pytest.warns(UserWarning, match="UNTRAINED"):
        p2 = RLPoolPolicy(checkpoint=missing, seed=7)
    assert not p1.trained
    r1 = simulate(arrivals, wl, p1)
    r2 = simulate(arrivals, wl, p2)
    assert r1.summary() == r2.summary()
    assert r1.total_requests == pytest.approx(float(arrivals.sum()))


def test_policy_checkpoint_roundtrip(tmp_path):
    """Saved + reloaded params must drive identical greedy decisions."""
    wl = uniform_pool_workload(POOL[:2], strict_frac=0.25)
    cfg = EnvConfig(mean_rps=30, duration_s=60)
    env = PoolServingEnv(wl, cfg, scenarios=[get_scenario("mmpp_bursts")])
    state = train_ppo_pool(env, PPOConfig(iterations=1, rollout_len=60,
                                          hidden=16))
    path = str(tmp_path / "ckpt.json")
    save_policy_params(state.params, path, meta={"test": True})
    arrivals = get_scenario("mmpp_bursts").build(2, duration_s=90, mean_rps=30)
    a = simulate(arrivals, wl,
                 RLPoolPolicy(params=state.params, greedy=True)).summary()
    loaded = RLPoolPolicy(checkpoint=path, greedy=True)
    assert loaded.trained
    b = simulate(arrivals, wl, loaded).summary()
    assert a == b
