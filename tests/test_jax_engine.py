"""Differential tests: the jitted JAX engine vs the NumPy oracle.

The batched engine (``repro.core.sim.jax_engine``) re-expresses the
structure-of-arrays tick pipeline as a pure-functional ``lax.scan``;
the NumPy :class:`~repro.core.sim.ServingSim` stays the semantic
oracle.  These tests pin the two together:

* differential fuzz over zoo scenarios / seeds / policies — RAW
  (unrounded) ledger totals at 1e-6 relative tolerance plus
  summary-key-set equality (rounded values may differ by one rounding
  ulp from summation order, the raw comparison is the strict one);
* per-arch flow conservation (arrived == served + offloaded + dropped
  + expired + still-queued, per arch) and accuracy-mass consistency;
* ``SimState`` pytree round-trip;
* the jit-recompile guard — repeated same-shape runs must hit one
  trace per (A, T, policy) shape;
* the vmapped grid vs per-cell ``run_scenario`` parity;
* the building blocks the scan shares with the host path (binomial
  inverse-CDF, feature build).

Tests named ``*_smoke_*`` are the CI subset (``-k smoke``).
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core.rl.obs import pool_features, pool_features_arrays
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import ServingSim
from repro.core.sim import jax_engine as je
from repro.core.sim.fleet import BINOMIAL_KMAX, binomial_from_uniform
from repro.core.sim.types import ArchLoad
from repro.core.workloads import SCENARIO_ZOO

ARCHS = ["llama3-8b", "minicpm-2b", "qwen1.5-0.5b"]

#: raw SimResult attribute -> how to read it off the jax raw totals
_LEDGER_KEYS = (
    "cost_reserved", "cost_spot", "cost_burst", "cost_harvest",
    "cost_remote", "violations", "violations_strict", "served_vm",
    "served_burst", "preemptions", "chip_seconds", "chip_seconds_needed",
    "chip_seconds_over", "accuracy_weighted", "accuracy_served",
    "acc_violations",
)


def _workload(A):
    return [
        ArchLoad(ARCHS[i % len(ARCHS)], 1.0 / A, 0.25, name=f"m@{i}")
        for i in range(A)
    ]


def _numpy_run(arrivals, workload, policy, seed=0, catalog=None):
    sim = ServingSim(arrivals, workload, seed=seed, catalog=catalog)
    if policy == "rl_pool":
        from repro.core.rl.policy import RLPoolPolicy
        pol = RLPoolPolicy(greedy=True)
    else:
        pol = VECTOR_SCHEDULERS[policy]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
    return sim


def _raw_ledger_np(res):
    return {
        "cost_reserved": res.cost_reserved,
        "cost_spot": res.cost_spot,
        "cost_burst": res.cost_burst,
        "cost_harvest": res.cost_other.get("harvest", 0.0),
        "cost_remote": res.cost_other.get("remote", 0.0),
        "violations": res.violations,
        "violations_strict": res.violations_strict,
        "served_vm": res.served_vm,
        "served_burst": res.served_burst,
        "preemptions": float(res.preemptions),
        "chip_seconds": res.chip_seconds,
        "chip_seconds_needed": res.chip_seconds_needed,
        "chip_seconds_over": res.chip_seconds_over,
        "accuracy_weighted": res.accuracy_weighted,
        "accuracy_served": res.accuracy_served,
        "acc_violations": res.acc_violations,
    }


def _raw_ledger_jx(out):
    tot = out["raw"]["totals"]
    exp_s, exp_r = out["raw"]["expired_s"], out["raw"]["expired_r"]
    served = float(tot["served"].sum() + tot["dropped"].sum())
    burst = float(tot["burst"].sum())
    return {
        "cost_reserved": float(tot["cost_res"]),
        "cost_spot": float(tot["cost_spot"]),
        "cost_burst": float(tot["cost_burst"]),
        "cost_harvest": float(tot["cost_harv"]),
        "cost_remote": float(tot["cost_rem"]),
        "violations": float(tot["viol"].sum() + exp_s.sum() + exp_r.sum()),
        "violations_strict": float(tot["viol_strict"] + exp_s.sum()),
        "served_vm": served,
        "served_burst": burst,
        "preemptions": float(tot["preempt"]),
        "chip_seconds": float(tot["chip"]),
        "chip_seconds_needed": float(tot["need"]),
        "chip_seconds_over": float(tot["over"]),
        "accuracy_weighted": float(tot["acc_w"].sum()),
        "accuracy_served": served + burst,
        "acc_violations": float(tot["acc_viol"].sum()),
    }


def _assert_equivalent(arrivals, workload, policy, seed=0, catalog=None):
    sim = _numpy_run(arrivals, workload, policy, seed=seed, catalog=catalog)
    out = je.run_scenario(arrivals, workload, policy, seed=seed,
                          catalog=catalog)
    raw_np = _raw_ledger_np(sim.res)
    raw_jx = _raw_ledger_jx(out)
    for k in _LEDGER_KEYS:
        assert raw_jx[k] == pytest.approx(raw_np[k], rel=1e-6, abs=1e-6), (
            f"{policy}: raw ledger key {k!r} drifted "
            f"(np={raw_np[k]!r} jax={raw_jx[k]!r})"
        )
    # rounded summaries expose the same keys (values may sit one
    # rounding ulp apart from summation order — the raw check above is
    # the strict one)
    assert set(out["summary"]) == set(sim.res.summary())
    if catalog is not None:
        # swaps-in-flight accounting: the scan's popped-swap count is an
        # exact integer flow, so it must match the oracle exactly
        assert out["summary"]["variant_swaps"] == (
            sim.res.summary()["variant_swaps"]
        ), f"{policy}: variant_swaps drifted"
    # per-arch flow totals line up with the oracle's
    counts = sim.per_arch_counts()
    per = out["per_arch"]
    for k in ("served_vm", "served_burst", "dropped", "violations",
              "acc_weight", "acc_violations"):
        np.testing.assert_allclose(
            per[k], counts[k], rtol=1e-6, atol=1e-6, err_msg=f"per-arch {k}"
        )
    return out


# ---------------------------------------------------------------------------
# Differential fuzz.
# ---------------------------------------------------------------------------
def test_smoke_fuzz_zoo_portfolio_small():
    """CI subset: two zoo scenarios under the portfolio policy."""
    A, T = 4, 300
    wl = _workload(A)
    for scn in ("shared_berkeley", "mmpp_bursts"):
        arr = SCENARIO_ZOO[scn].build(A, duration_s=T)
        _assert_equivalent(arr, wl, "portfolio", seed=3)


def test_fuzz_all_zoo_scenarios_portfolio():
    """Every SCENARIO_ZOO preset matches under the portfolio policy
    (the policy that exercises all four procurement tiers)."""
    A, T = 4, 400
    wl = _workload(A)
    for i, scn in enumerate(sorted(SCENARIO_ZOO)):
        arr = SCENARIO_ZOO[scn].build(A, duration_s=T, seed=20 + i)
        _assert_equivalent(arr, wl, "portfolio", seed=i)


def test_fuzz_policies_and_shapes():
    """Random (scenario, seed, policy, shape) draws across the other
    in-scan policies."""
    rng = np.random.default_rng(7)
    names = sorted(SCENARIO_ZOO)
    cases = [("reactive", 4, 400), ("paragon", 4, 400),
             ("portfolio", 6, 600), ("reactive", 2, 250)]
    for policy, A, T in cases:
        scn = names[rng.integers(len(names))]
        seed = int(rng.integers(100))
        arr = SCENARIO_ZOO[scn].build(A, duration_s=T, seed=seed)
        _assert_equivalent(arr, _workload(A), policy, seed=seed)


def test_fuzz_fleet_scale_a256():
    """Fleet-scale differential fuzz: the lazy window-min rings, the
    in-carry EWMA and the in-carry totals accumulator must hold the
    ledger contract at A=256, not just at toy pool sizes."""
    A, T = 256, 150
    wl = _workload(A)
    arr = SCENARIO_ZOO["shared_berkeley"].build(
        A, duration_s=T, mean_rps=400.0, seed=9
    )
    _assert_equivalent(arr, wl, "portfolio", seed=9)


def test_fuzz_rl_pool_parity():
    """The in-scan rl_pool twin matches RLPoolPolicy(greedy=True)
    driving the NumPy engine — net forward, feature build, procurement
    decode and engine semantics all at once."""
    A, T = 4, 400
    arr = SCENARIO_ZOO["diurnal_phases"].build(A, duration_s=T)
    _assert_equivalent(arr, _workload(A), "rl_pool", seed=0)


def test_flow_conservation_per_arch():
    """arrived == served_vm + served_burst + dropped + expired + queued
    per arch (the invariant ``ServingSim.per_arch_counts`` documents),
    and the accuracy mass stays within the answered mass (weights are
    per-request accuracies in [0, 1])."""
    A, T = 6, 600
    wl = _workload(A)
    arr = SCENARIO_ZOO["flash_anti"].build(A, duration_s=T)
    out = je.run_scenario(arr, wl, "portfolio")
    per = out["per_arch"]
    answered = per["served_vm"] + per["served_burst"] + per["dropped"]
    np.testing.assert_allclose(
        per["arrived"],
        answered + per["expired_end"] + per["queued"],
        rtol=1e-9, atol=1e-6,
    )
    assert (per["acc_weight"] >= -1e-9).all()
    assert (per["acc_weight"] <= answered + 1e-6).all()
    assert (per["acc_violations"] <= answered + 1e-6).all()


# ---------------------------------------------------------------------------
# Variant axis: catalog-enabled differential fuzz + swap edge cases.
# ---------------------------------------------------------------------------
def _vworkload(floor=0.55):
    import dataclasses

    from repro.core.sim import uniform_pool_workload
    pool = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]
    return [
        dataclasses.replace(w, min_accuracy=floor)
        for w in uniform_pool_workload(pool, strict_frac=0.25)
    ]


@pytest.fixture(scope="module")
def vcatalog():
    from repro.core.sim import VariantCatalog
    return VariantCatalog.for_workload(_vworkload())


def test_smoke_fuzz_variant_catalog(vcatalog):
    """CI subset: both variant-aware schedulers on a catalog run must
    match the NumPy oracle — swaps, accuracy mass and money included."""
    wl = _vworkload()
    arr = SCENARIO_ZOO["diurnal_phases"].build(
        len(wl), duration_s=400, mean_rps=400.0, seed=3
    )
    for policy in ("infaas_variant", "accuracy_floor"):
        out = _assert_equivalent(arr, wl, policy, seed=0, catalog=vcatalog)
        assert out["summary"]["variant_swaps"] > 0, (
            f"{policy}: catalog run never swapped — edge not exercised"
        )


def test_fuzz_variant_zoo(vcatalog):
    """Every zoo scenario under both variant-aware schedulers, plus the
    RL policy's live variant head, at 1e-6 on the raw ledger."""
    wl = _vworkload()
    swapped = 0
    for i, scn in enumerate(sorted(SCENARIO_ZOO)):
        arr = SCENARIO_ZOO[scn].build(
            len(wl), duration_s=300, mean_rps=300.0, seed=40 + i
        )
        for policy in ("infaas_variant", "accuracy_floor"):
            out = _assert_equivalent(arr, wl, policy, seed=i,
                                     catalog=vcatalog)
            swapped += out["summary"]["variant_swaps"]
    assert swapped > 0
    arr = SCENARIO_ZOO["trending_hotswap"].build(
        len(wl), duration_s=400, mean_rps=300.0, seed=11
    )
    _assert_equivalent(arr, wl, "rl_pool", seed=1, catalog=vcatalog)


def test_variant_flow_and_accuracy_conservation(vcatalog):
    """Per-arch flow conservation and accuracy-mass bounds hold on a
    catalog run exactly as on the base engine."""
    wl = _vworkload()
    arr = SCENARIO_ZOO["flash_anti"].build(
        len(wl), duration_s=500, mean_rps=350.0, seed=5
    )
    out = je.run_scenario(arr, wl, "infaas_variant", catalog=vcatalog)
    per = out["per_arch"]
    answered = per["served_vm"] + per["served_burst"] + per["dropped"]
    np.testing.assert_allclose(
        per["arrived"],
        answered + per["expired_end"] + per["queued"],
        rtol=1e-9, atol=1e-6,
    )
    assert (per["acc_weight"] >= -1e-9).all()
    assert (per["acc_weight"] <= answered + 1e-6).all()
    assert (per["acc_violations"] <= answered + 1e-6).all()


def test_variant_policies_degrade_catalog_free():
    """Catalog-free, the in-scan variant-aware schedulers degrade to
    exactly Paragon (same guarantee the vector forms pin) — and the
    whole variant machinery stays untraced."""
    A = 4
    wl = _workload(A)
    arr = SCENARIO_ZOO["mmpp_bursts"].build(A, duration_s=300, seed=2)
    p = je.run_scenario(arr, wl, "paragon", seed=0)["summary"]
    for policy in ("infaas_variant", "accuracy_floor"):
        assert je.run_scenario(arr, wl, policy, seed=0)["summary"] == p


# --- scripted swap edge cases, pinned against the NumPy engine --------------
def _scripted_parity(arr, wl, catalog, np_policy, jax_apply, seed=0):
    """Run a scripted action sequence through BOTH engines and compare
    the raw ledgers at 1e-6 (the harness behind the swap edge tests)."""
    import jax.numpy as jnp  # noqa: F401  (closures use it)

    sim = ServingSim(arr, wl, seed=seed, catalog=catalog)
    while not sim.done:
        sim.apply_pool(np_policy(sim.tick, sim.observe_pool()))
    statics, state0, xs = je.build_sim_inputs(
        arr, wl, catalog=catalog, seed=seed, needs_stats=True,
        lazy_rings=False,
    )
    statics["policy"] = {}
    from jax.experimental import enable_x64
    run = jax.jit(je.make_runner(jax_apply, "sum", variants=True))
    with enable_x64():
        out = jax.tree.map(np.asarray, run(statics, state0, xs))
    res = je._assemble(out, np.asarray(arr, dtype=np.float64))
    raw_np, raw_jx = _raw_ledger_np(sim.res), _raw_ledger_jx(res)
    for k in _LEDGER_KEYS:
        assert raw_jx[k] == pytest.approx(raw_np[k], rel=1e-6, abs=1e-6), (
            f"scripted: raw ledger key {k!r} drifted "
            f"(np={raw_np[k]!r} jax={raw_jx[k]!r})"
        )
    assert res["summary"]["variant_swaps"] == (
        sim.res.summary()["variant_swaps"]
    )
    return sim, res


def _scripted_pair(variant_script_np, spot=0, harvest=0):
    """Matching (NumPy policy, JAX apply) for a reactive-sized fleet
    with a tick-scripted variant request stream."""
    from repro.core.sim import PoolAction

    def np_policy(tick, obs):
        tgt = np.maximum(
            1, np.ceil(obs.ewma_rate / obs.throughput)
        ).astype(np.int64)
        A = len(obs.keys)
        act = PoolAction(target=tgt)
        act.variant_target = variant_script_np(tick, A)
        if spot:
            act.spot_target = np.full(A, spot, dtype=np.int64)
        if harvest:
            act.harvest_target = np.full(A, harvest, dtype=np.int64)
        return act

    def jax_apply(params, obs, key):
        import jax.numpy as jnp
        tgt = jnp.maximum(
            1, jnp.ceil(obs["ewma_rate"] / obs["throughput"])
        ).astype(jnp.int64)
        z = jnp.zeros_like(tgt)
        t = obs["tick"]
        A = tgt.shape[0]
        # trace the SAME script: variant_script_np is evaluated per tick
        # on the host into a [T, A] table is impossible in-scan, so the
        # scripts below are written as jnp expressions of t
        variant = variant_script_np(t, A, xp=jnp)
        return dict(
            target=tgt, offload=z,
            spot=jnp.full_like(tgt, spot) if spot else z,
            harvest=jnp.full_like(tgt, harvest) if harvest else z,
            remote=z, variant=variant,
        ), {}

    return np_policy, jax_apply


def test_swap_retarget_to_current_cancels(vcatalog):
    """Re-targeting the CURRENT variant while a swap is in flight
    cancels it (the in-flight swap never lands); a later re-request
    completes.  Scripted identically into both engines."""
    import jax.numpy as jnp

    wl = _vworkload()
    arr = SCENARIO_ZOO["shared_berkeley"].build(
        len(wl), duration_s=300, mean_rps=200.0, seed=7
    )
    base = vcatalog.as_arrays(wl)["base_idx"].astype(np.int64)

    def script(t, A, xp=np):
        # t=5: request variant 0 (a real move for archs whose base > 0);
        # t=10 (< 5+60 swap latency): re-target CURRENT -> cancel;
        # t=100: request variant 0 again -> completes at tick 160
        b = base if xp is np else jnp.asarray(base)
        zero = xp.zeros(A, dtype=xp.int64)
        hold = zero - 1
        return xp.where(
            t == 10, b,
            xp.where((t == 5) | (t == 100), zero, hold),
        ).astype(xp.int64)

    np_pol, jx_apply = _scripted_pair(script)
    sim, res = _scripted_parity(arr, wl, vcatalog, np_pol, jx_apply)
    # exactly one completed swap per arch whose base isn't variant 0:
    # the canceled first request must never land
    assert res["summary"]["variant_swaps"] == int((base != 0).sum())
    assert not sim.swap.in_flight.any()


def test_swap_lands_on_final_tick(vcatalog):
    """A swap maturing exactly on the last tick pops during that tick's
    step (the arch serves at the new rate through the end-of-trace
    expired sweep), and a request issued ON the final tick stays in
    flight forever — both engines agree on the resulting ledger."""
    import jax.numpy as jnp

    wl = _vworkload()
    T = 200
    arr = SCENARIO_ZOO["flash_correlated"].build(
        len(wl), duration_s=T, mean_rps=250.0, seed=13
    )
    va = vcatalog.as_arrays(wl)
    base = va["base_idx"].astype(np.int64)
    top = (va["n_variants"] - 1).astype(np.int64)
    land = T - 1 - 60    # ready_at == T-1: pops on the final tick

    def script(t, A, xp=np):
        to = top if xp is np else jnp.asarray(top)
        zero = xp.zeros(A, dtype=xp.int64)
        hold = zero - 1
        return xp.where(
            t == land, zero, xp.where(t == T - 1, to, hold)
        ).astype(xp.int64)

    np_pol, jx_apply = _scripted_pair(script)
    sim, res = _scripted_parity(arr, wl, vcatalog, np_pol, jx_apply)
    # the landing request popped (once per arch whose base != 0); the
    # final-tick request entered the pipeline AFTER the pop and is
    # still in flight at the sweep
    assert res["summary"]["variant_swaps"] == int((base != 0).sum())
    assert sim.swap.in_flight.any()


def test_swap_request_on_reclaim_tick(vcatalog):
    """Swap requests issued every tick while spot/harvest churn (reclaims
    and evictions co-occur with swap traffic): the two engines must
    stay ledger-identical through the interleaving."""
    wl = _vworkload()
    arr = SCENARIO_ZOO["mmpp_bursts"].build(
        len(wl), duration_s=400, mean_rps=300.0, seed=17
    )

    def script(t, A, xp=np):
        # oscillate requests: variant 0 on even phases, hold on odd —
        # guarantees requests coincide with whatever reclaim ticks the
        # seeded spot/harvest processes produce
        req = xp.where((t % 7) < 3, 0, -1)
        if xp is np:
            return np.full(A, int(req), dtype=np.int64)
        return xp.broadcast_to(req, (A,)).astype(xp.int64)

    np_pol, jx_apply = _scripted_pair(script, spot=3, harvest=2)
    sim, res = _scripted_parity(arr, wl, vcatalog, np_pol, jx_apply)
    assert sim.res.preemptions > 0, "no reclaim landed — edge not exercised"
    assert res["summary"]["variant_swaps"] > 0


# ---------------------------------------------------------------------------
# Pytree / jit machinery.
# ---------------------------------------------------------------------------
def test_simstate_pytree_roundtrip():
    A, T = 3, 50
    arr = SCENARIO_ZOO["shared_berkeley"].build(A, duration_s=T)
    # stats path: the EWMA arrives via xs, so the carry slot is an
    # empty (None) subtree and contributes no leaf
    _, state0, _ = je.build_sim_inputs(arr, _workload(A))
    assert state0.ewma is None
    # catalog-free runs also leave the 4 variant-swap slots as empty
    # (None) subtrees
    n_var = 4
    leaves, treedef = jax.tree.flatten(state0)
    assert len(leaves) == len(je.SimState._fields) - 1 - n_var
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert isinstance(rebuilt, je.SimState)
    for a, b in zip(jax.tree.leaves(rebuilt), leaves):
        np.testing.assert_array_equal(a, b)
    # non-stats path: the EWMA recurrence lives in the carry
    _, state0, xs = je.build_sim_inputs(arr, _workload(A), needs_stats=False)
    assert state0.ewma is not None and "ewma" not in xs
    leaves, _ = jax.tree.flatten(state0)
    assert len(leaves) == len(je.SimState._fields) - n_var
    # variant-catalog run: the swap pipeline fills every slot
    from repro.core.sim import VariantCatalog
    _, state0, _ = je.build_sim_inputs(
        arr, _workload(A), catalog=VariantCatalog.for_workload(_workload(A))
    )
    leaves, _ = jax.tree.flatten(state0)
    assert len(leaves) == len(je.SimState._fields) - 1
    assert state0.var_pending is not None and (state0.var_pending == -1).all()


def test_smoke_recompile_guard():
    """Repeated same-shape runs reuse one trace; a new (A, T) shape
    adds exactly one more."""
    wl4 = _workload(4)
    arr = SCENARIO_ZOO["shared_berkeley"].build(4, duration_s=120)
    je.run_scenario(arr, wl4, "reactive")
    n0 = je.runner_trace_count("reactive")
    for seed in (1, 2):
        je.run_scenario(arr, wl4, "reactive", seed=seed)
    assert je.runner_trace_count("reactive") == n0
    arr2 = SCENARIO_ZOO["shared_berkeley"].build(5, duration_s=120)
    je.run_scenario(arr2, _workload(5), "reactive")
    assert je.runner_trace_count("reactive") == n0 + 1


def test_donation_safety_and_flavor_parity():
    """The donated opt runner (a) is repeatable — two dispatches from
    the same host-side inputs return identical totals, proving donation
    aliases only the fresh device staging buffers, never the caller's
    NumPy arrays — and (b) does not drift from the legacy flavor
    (eager ring clips, host-fed EWMA, stacked post-scan reduction)."""
    from jax.experimental import enable_x64

    A, T = 8, 300
    wl = _workload(A)
    arr = SCENARIO_ZOO["mmpp_bursts"].build(A, duration_s=T, seed=5)
    pol = je.JAX_POLICIES["portfolio"]
    with enable_x64():
        statics, state0, xs = je.build_sim_inputs(
            arr, wl, seed=3, needs_stats=pol.needs_stats,
            needs_key=pol.needs_key,
        )
        statics = dict(statics)
        statics["policy"] = pol.default_params()
        state_snap = [np.array(x, copy=True) for x in jax.tree.leaves(state0)]
        xs_snap = [np.array(x, copy=True) for x in jax.tree.leaves(xs)]
        runner = je._get_runner("portfolio")
        out1 = jax.tree.map(np.asarray, runner(statics, state0, xs))
        out2 = jax.tree.map(np.asarray, runner(statics, state0, xs))
        for k in out1["totals"]:
            np.testing.assert_array_equal(
                out1["totals"][k], out2["totals"][k], err_msg=k
            )
        for got, want in zip(jax.tree.leaves(state0), state_snap):
            np.testing.assert_array_equal(np.asarray(got), want)
        for got, want in zip(jax.tree.leaves(xs), xs_snap):
            np.testing.assert_array_equal(np.asarray(got), want)

        statics_l, state0_l, xs_l = je.build_sim_inputs(
            arr, wl, seed=3, needs_stats=pol.needs_stats,
            needs_key=pol.needs_key, ewma_in_scan=False, lazy_rings=False,
        )
        statics_l = dict(statics_l)
        statics_l["policy"] = pol.default_params()
        out_l = jax.tree.map(
            np.asarray,
            je._get_runner("portfolio", flavor="legacy")(
                statics_l, state0_l, xs_l
            ),
        )
    for k in out1["totals"]:
        if k in je._LIVE_KEYS:
            # opt folds liveness with logical-or, legacy sums the per-
            # tick flags — only truthiness is consumed (_assemble)
            assert bool(out1["totals"][k]) == bool(out_l["totals"][k]), k
            continue
        np.testing.assert_allclose(
            out1["totals"][k], out_l["totals"][k], rtol=1e-9, atol=1e-9,
            err_msg=f"flavor drift in {k}",
        )


def test_smoke_grid_matches_run_scenario():
    """One vmapped dispatch over (scenario x seed) cells reproduces the
    per-cell scan summaries exactly."""
    A, T, B = 4, 200, 3
    wl = _workload(A)
    names = ("shared_berkeley", "mmpp_bursts", "flash_correlated")
    arrs = np.stack([
        SCENARIO_ZOO[n].build(A, duration_s=T, seed=30 + i)
        for i, n in enumerate(names)
    ])
    seeds = [5, 6, 7]
    cells = je.run_grid(arrs, wl, "portfolio", seeds=seeds)
    for i in range(B):
        single = je.run_scenario(arrs[i], wl, "portfolio", seed=seeds[i])
        assert cells[i]["summary"] == single["summary"], f"cell {i}"


# ---------------------------------------------------------------------------
# Shared building blocks.
# ---------------------------------------------------------------------------
def test_binomial_jnp_matches_numpy():
    """The in-scan inverse-CDF binomial is the NumPy twin's, bit for
    bit, across the (n, p, u) grid both engines draw from."""
    from jax.experimental import enable_x64

    rng = np.random.default_rng(0)
    n = rng.integers(0, BINOMIAL_KMAX + 10, size=200)
    u = rng.random(200)
    for p in (0.0, 1e-4, 0.01, 0.3, 1.0):
        want = binomial_from_uniform(n, p, u)
        with enable_x64():      # the scan always runs in x64
            got = np.asarray(je.binomial_from_uniform_jnp(
                np.asarray(n), float(p), np.asarray(u)
            ))
        np.testing.assert_array_equal(got, want, err_msg=f"p={p}")


def test_pool_features_arrays_parity():
    """The backend-parametric feature build matches the deployed NumPy
    one elementwise on a materialized PoolObs."""
    A, T = 4, 60
    wl = _workload(A)
    arr = SCENARIO_ZOO["shared_berkeley"].build(A, duration_s=T)
    sim = ServingSim(arr, wl)
    pol = VECTOR_SCHEDULERS["portfolio"]()
    for _ in range(30):
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
    obs = sim.observe_pool()
    prev = obs.rate * 0.9
    want = pool_features(obs, prev, rate_scale=100.0, fleet_scale=10.0)
    o = {f: np.broadcast_to(np.asarray(getattr(obs, f)), (A,))
         for f in ("rate", "ewma_rate", "peak_to_median", "queue_strict",
                   "queue_relaxed", "n_active", "n_pending", "utilization",
                   "last_violations", "active_variant", "n_variants",
                   "accuracy", "accuracy_floor", "n_spot", "n_spot_pending",
                   "spot_reclaim_risk", "harvest_level")}
    got = pool_features_arrays(
        o, prev, rate_scale=100.0, fleet_scale=10.0, xp=np
    )
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Batched rollout collection.
# ---------------------------------------------------------------------------
def test_collect_rollouts_jax_buffers():
    """The in-scan collector returns the host loop's buffer layout,
    deterministically per key, with the episode-end reward carrying the
    finalize sweep."""
    from repro.core.rl.env import EnvConfig, PoolServingEnv
    from repro.core.rl.ppo import OBS_DIM, PPOConfig, collect_rollouts_jax, init_net

    A, T = 4, 200
    arr = SCENARIO_ZOO["shared_berkeley"].build(A, duration_s=T)
    env = PoolServingEnv(_workload(A), EnvConfig(duration_s=T), arrivals=arr)
    params = init_net(jax.random.key(0), PPOConfig())
    key = jax.random.key(11)
    buf = collect_rollouts_jax(env, params, key)
    assert buf["obs"].shape == (T, A, OBS_DIM)
    for k in ("actions", "logp", "values", "rewards"):
        assert buf[k].shape == (T, A), k
    assert buf["dones"].sum() == 1.0 and buf["dones"][-1] == 1.0
    assert np.isfinite(buf["rewards"]).all()
    assert (buf["logp"] <= 1e-6).all()
    buf2 = collect_rollouts_jax(env, params, key)
    for k in buf:
        np.testing.assert_array_equal(buf[k], buf2[k], err_msg=k)
    # a different key draws a different action stream
    buf3 = collect_rollouts_jax(env, params, jax.random.key(12))
    assert (buf3["actions"] != buf["actions"]).any()


def test_collect_rollouts_jax_zoo_matches_cells():
    """The full-zoo batched collector is bit-identical, cell by cell,
    to the unbatched collector run on the same (arrivals, seed, key)
    triples — the vmapped dispatch changes wall-clock, not rollouts."""
    from repro.core.rl.env import EnvConfig, PoolServingEnv
    from repro.core.rl.ppo import (
        OBS_DIM,
        PPOConfig,
        collect_rollouts_jax,
        collect_rollouts_jax_zoo,
        init_net,
    )

    A, T = 2, 200
    zoo = [SCENARIO_ZOO[n]
           for n in ("shared_berkeley", "mmpp_bursts", "flash_correlated")]
    S = len(zoo)
    cfg = EnvConfig(duration_s=T, mean_rps=40.0)
    wl = _workload(A)
    env = PoolServingEnv(wl, cfg, scenarios=zoo, scenario_seed=0)
    params = init_net(jax.random.key(0), PPOConfig())
    key = jax.random.key(7)
    buf = collect_rollouts_jax_zoo(env, params, key)
    assert buf["obs"].shape == (T, S * A, OBS_DIM)
    assert buf["dones"].sum() == 1.0 and buf["dones"][-1] == 1.0

    ep = env._episode
    keys = jax.random.split(key, S)
    env1 = PoolServingEnv(wl, cfg, arrivals=np.zeros((A, T)))
    for i, sc in enumerate(zoo):
        arr = sc.build(A, seed=sc.seed + ep, duration_s=T, mean_rps=40.0)
        cell = collect_rollouts_jax(
            env1, params, keys[i], arrivals=arr, seed=ep * S + i
        )
        for k in ("obs", "actions", "logp", "values", "rewards"):
            np.testing.assert_array_equal(
                buf[k][:, i * A:(i + 1) * A], cell[k],
                err_msg=f"cell {i} key {k}",
            )


# ---------------------------------------------------------------------------
# Multi-device grid sharding (forced multi-CPU subprocess).
# ---------------------------------------------------------------------------
def test_sharded_grid_parity_subprocess():
    """``run_grid(sharded=True)`` computes the same cells as the single
    vmapped dispatch.  Device count is a process-level XLA flag, so the
    2-device mesh runs in a subprocess."""
    import os
    import subprocess
    import sys

    script = r"""
import numpy as np, jax
assert len(jax.devices()) == 2, jax.devices()
from repro.core.sim import jax_engine as je
from repro.core.sim.types import ArchLoad
from repro.core.workloads import SCENARIO_ZOO
ARCHS = ["llama3-8b", "minicpm-2b", "qwen1.5-0.5b"]
A, T = 3, 120
wl = [ArchLoad(ARCHS[i % 3], 1.0 / A, 0.25, name=f"m@{i}") for i in range(A)]
names = ("shared_berkeley", "mmpp_bursts")
arrs = np.stack([SCENARIO_ZOO[n].build(A, duration_s=T, seed=30 + i)
                 for i, n in enumerate(names)])
seeds = [5, 6]
sh = je.run_grid(arrs, wl, "portfolio", seeds=seeds, sharded=True)
un = je.run_grid(arrs, wl, "portfolio", seeds=seeds, sharded=False)
for i in range(len(names)):
    assert sh[i]["summary"] == un[i]["summary"], (i, sh[i], un[i])
# auto mode: 2 cells % 2 devices == 0 -> sharded path, same cells
auto = je.run_grid(arrs, wl, "portfolio", seeds=seeds)
for i in range(len(names)):
    assert auto[i]["summary"] == un[i]["summary"], i
print("SHARDED_PARITY_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=2"
    ).strip()
    # the subprocess must resolve the package the same way this one did
    src = os.path.dirname(os.path.dirname(os.path.abspath(je.__file__)))
    src = os.path.dirname(os.path.dirname(src))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script], env=env, capture_output=True,
        text=True, timeout=540,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "SHARDED_PARITY_OK" in proc.stdout
