"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_chunked

KEY = jax.random.key(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------
FA_CASES = [
    # b, sq, sk, nq, nkv, hd, causal, window, bq, bk
    (2, 64, 64, 4, 2, 32, True, 0, 32, 32),
    (1, 128, 128, 8, 8, 64, True, 16, 32, 64),
    (2, 48, 48, 4, 1, 32, True, 0, 16, 16),       # ragged + MQA
    (1, 64, 64, 2, 2, 16, False, 0, 32, 32),       # encoder (non-causal)
    (1, 96, 96, 6, 3, 64, True, 32, 32, 32),       # window + GQA
]


@pytest.mark.parametrize("case", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dtype):
    b, sq, sk, nq, nkv, hd, causal, window, bq, bk = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, sq, nq, hd), dtype)
    k = jax.random.normal(ks[1], (b, sk, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, sk, nkv, hd), dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=True,
    )
    expected = ref.mha_reference(q, k, v, causal=causal, window=window)
    assert out.shape == expected.shape and out.dtype == dtype
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - expected.astype(jnp.float32))))
    assert err < _tol(dtype), (case, dtype, err)


def test_flash_attention_q_offset():
    """Chunked prefill: q block at absolute offset vs full causal."""
    b, s, nq, hd = 1, 64, 4, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, nq, hd))
    k = jax.random.normal(ks[1], (b, s, nq, hd))
    v = jax.random.normal(ks[2], (b, s, nq, hd))
    full = ref.mha_reference(q, k, v, causal=True)
    out = flash_attention(
        q[:, 32:], k, v, causal=True, q_offset=32, block_q=16, block_k=16,
        interpret=True,
    )
    err = float(jnp.max(jnp.abs(out - full[:, 32:])))
    assert err < 2e-5


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------
DA_CASES = [
    (2, 64, 4, 2, 32, 32),
    (1, 100, 8, 1, 64, 32),    # ragged cache + MQA
    (3, 48, 2, 2, 16, 16),
    (1, 256, 16, 4, 64, 128),  # long cache, big block
]


@pytest.mark.parametrize("case", DA_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(case, dtype):
    b, s, nq, nkv, hd, bk = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**31), 4)
    q = jax.random.normal(ks[0], (b, nq, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, nkv, hd), dtype)
    valid = jax.random.uniform(ks[3], (b, s)) < 0.7
    valid = valid.at[:, 0].set(True)              # at least one visible slot
    out = decode_attention(q, k, v, valid, block_k=bk, interpret=True)
    expected = ref.decode_attention_reference(q, k, v, valid)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - expected.astype(jnp.float32))))
    assert err < _tol(dtype), (case, dtype, err)


def test_decode_attention_single_valid_slot():
    """Softmax over one visible slot == plain value read."""
    b, s, nq, hd = 1, 32, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, nq, hd))
    k = jax.random.normal(ks[1], (b, s, nq, hd))
    v = jax.random.normal(ks[2], (b, s, nq, hd))
    valid = jnp.zeros((b, s), bool).at[:, 5].set(True)
    out = decode_attention(q, k, v, valid, block_k=8, interpret=True)
    assert float(jnp.max(jnp.abs(out - v[:, 5]))) < 1e-5


# ---------------------------------------------------------------------------
# rwkv6 chunked scan
# ---------------------------------------------------------------------------
RWKV_CASES = [
    # b, t, h, hd, chunk, with_state
    (2, 64, 2, 32, 16, False),
    (1, 50, 4, 64, 32, True),     # ragged tail (t % chunk != 0)
    (2, 33, 1, 16, 8, True),
    (1, 128, 2, 64, 32, True),
]


@pytest.mark.parametrize("case", RWKV_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_chunked(case, dtype):
    b, t, h, hd, chunk, with_state = case
    ks = jax.random.split(jax.random.fold_in(KEY, hash(case) % 2**31), 6)
    r = (jax.random.normal(ks[0], (b, t, h, hd)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (b, t, h, hd)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, h, hd)).astype(dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, hd)) * 2 - 1)
         * 0.5 + 0.45).astype(dtype)
    u = (jax.random.normal(ks[4], (h, hd)) * 0.3).astype(dtype)
    s0 = (
        (jax.random.normal(ks[5], (b, h, hd, hd)) * 0.2).astype(jnp.float32)
        if with_state else None
    )
    out, sT = rwkv6_chunked(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    exp_o, exp_s = ref.rwkv6_reference(r, k, v, w, u, s0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    e1 = float(jnp.max(jnp.abs(out.astype(jnp.float32) - exp_o.astype(jnp.float32))))
    e2 = float(jnp.max(jnp.abs(sT - exp_s)))
    assert e1 < tol and e2 < tol, (case, dtype, e1, e2)


def test_rwkv6_strong_decay_stability():
    """Data-dependent decay near the clip floor must not overflow (the
    reason the kernel keeps decay ratios inside the hd reduction)."""
    b, t, h, hd = 1, 64, 1, 16
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (b, t, h, hd)) * 0.5
    k = jax.random.normal(ks[1], (b, t, h, hd)) * 0.5
    v = jax.random.normal(ks[2], (b, t, h, hd))
    # w down to exp(-exp(4)) ~ 1e-24: brutal decay
    w = jnp.exp(-jnp.exp(jax.random.uniform(ks[3], (b, t, h, hd), minval=-2.0, maxval=4.0)))
    u = jax.random.normal(ks[4], (h, hd)) * 0.3
    out, sT = rwkv6_chunked(r, k, v, w, u, None, chunk=16, interpret=True)
    exp_o, exp_s = ref.rwkv6_reference(r, k, v, w, u, None)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(jnp.max(jnp.abs(out - exp_o))) < 1e-4


# ---------------------------------------------------------------------------
# rglru (associative-scan path in ops)
# ---------------------------------------------------------------------------
def test_rglru_assoc_matches_sequential():
    from repro.kernels import ops

    b, t, d = 2, 37, 24
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], (b, t, d))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, t, d)))
    h0 = jax.random.normal(ks[2], (b, d))
    got, gT = ops.rglru(x, a, h0)
    exp, eT = ref.rglru_reference(x, a, h0)
    assert float(jnp.max(jnp.abs(got - exp))) < 1e-5
    assert float(jnp.max(jnp.abs(gT - eT))) < 1e-5


# (test_blocked_window_equals_masked_oracle — the hypothesis property test
# for the blocked sliding-window path — moved to test_properties.py)
