"""MoE dispatch paths: sort-based capacity == dense oracle; EP all_to_all."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ModelConfig
from repro.models import moe as moe_lib


def _cfg(e=4, k=2, d=32, ff=64, cf=8.0):
    # huge capacity factor -> no drops -> exact match with the oracle
    return ModelConfig(
        name="moe-test", family="moe", num_layers=1, d_model=d, num_heads=4,
        num_kv_heads=4, d_ff=ff, vocab_size=64, num_experts=e,
        num_experts_per_tok=k, moe_capacity_factor=cf,
    )


def _params(cfg, seed=0):
    return jax.tree.map(
        lambda b: b.value,
        moe_lib.init_moe(jax.random.key(seed), cfg, jnp.float32),
        is_leaf=lambda x: hasattr(x, "axes"),
    )


@pytest.mark.parametrize("e,k", [(4, 1), (4, 2), (8, 2), (3, 2)])
def test_sort_local_matches_dense_oracle(e, k):
    cfg = _cfg(e=e, k=k)
    p = _params(cfg)
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model))
    y_sort, aux_s = moe_lib.moe_sort_local(cfg, p, x)
    y_dense, aux_d = moe_lib.moe_dense_oracle(cfg, p, x)
    assert float(jnp.max(jnp.abs(y_sort - y_dense))) < 1e-5
    assert abs(float(aux_s) - float(aux_d)) < 1e-6


def test_capacity_drops_tokens():
    """With capacity factor ~0 every token is dropped -> output 0."""
    cfg = _cfg(cf=1e-9)
    p = _params(cfg)
    x = jax.random.normal(jax.random.key(2), (1, 32, cfg.d_model))
    y, _ = moe_lib.moe_sort_local(cfg, p, x, capacity=8)
    # capacity 8 per expert with 32*2 assignments over 4 experts: some drop
    y_full, _ = moe_lib.moe_sort_local(cfg, p, x, capacity=64)
    assert float(jnp.max(jnp.abs(y_full))) > 0
    # dropped rows produce smaller norm overall
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) + 1e-6


def test_aux_loss_uniform_router_is_one():
    """Balanced routing gives Switch aux loss ~= 1 (E * E*(1/E^2))."""
    cfg = _cfg(e=8, k=1)
    p = _params(cfg)
    # zero router -> uniform probs; top-1 tie-break is argmax ties -> not
    # uniform assignment, so use random router with many tokens instead
    x = jax.random.normal(jax.random.key(3), (4, 256, cfg.d_model))
    _, aux = moe_lib.moe_sort_local(cfg, p, x)
    assert 0.8 < float(aux) < 1.6


def test_ep_a2a_falls_back_without_rules():
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.key(4), (2, 8, cfg.d_model))
    y_ep, _ = moe_lib.moe_ep_a2a(cfg, p, x)       # no mesh rules -> sort path
    y_sort, _ = moe_lib.moe_sort_local(cfg, p, x)
    assert float(jnp.max(jnp.abs(y_ep - y_sort))) < 1e-6


def test_ep_a2a_single_device_mesh():
    """shard_map path on a 1x1 mesh must equal the dense oracle."""
    from repro.distributed.sharding import AxisRules, axis_rules

    cfg = _cfg(e=4, k=2)
    p = _params(cfg)
    x = jax.random.normal(jax.random.key(5), (2, 8, cfg.d_model))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = AxisRules(mesh=mesh, rules={"experts": "model", "batch": ("data",)})
    with mesh, axis_rules(rules):
        y_ep, _ = moe_lib.moe_ep_a2a(cfg, p, x)
    y_dense, _ = moe_lib.moe_dense_oracle(cfg, p, x)
    assert float(jnp.max(jnp.abs(y_ep - y_dense))) < 1e-5


def test_moe_grads_flow_through_router():
    cfg = _cfg()
    p = _params(cfg)
    x = jax.random.normal(jax.random.key(6), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_lib.moe_sort_local(cfg, p, x)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.linalg.norm(g["router"])) > 0
    assert float(jnp.linalg.norm(g["wi_gate"])) > 0
