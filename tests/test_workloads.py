"""The workload scenario subsystem: generator determinism, Scenario
round-trips, the per-arch engine path (streaming monitor, per-arch
conservation), and backward equivalence — ``from_pool_trace`` arrivals
must reproduce the shared-trace engine."""
import json

import numpy as np
import pytest

from repro.core.load_monitor import LoadMonitor, PoolLoadMonitor
from repro.core.schedulers import SCHEDULERS, VECTOR_SCHEDULERS
from repro.core.sim import ServingSim, shares, simulate, uniform_pool_workload
from repro.core.traces import get_trace
from repro.core.workloads import (
    GENERATORS,
    SCENARIO_ZOO,
    Scenario,
    from_pool_trace,
    get_scenario,
    save_replay,
)

SEED_ARCHS = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]


@pytest.fixture(scope="module")
def workload():
    return uniform_pool_workload(SEED_ARCHS, strict_frac=0.25)


# ---------------------------------------------------------------------------
# Generators.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", sorted(set(GENERATORS) - {"replay"}))
def test_generator_deterministic_and_normalized(kind):
    """Same seed -> bit-identical matrix; different seed -> different
    realization; pool mean lands on mean_rps; everything non-negative.
    (``replay`` is excluded: it is seed-invariant by design — literal
    playback of a capture — and has its own tests below.)"""
    gen = GENERATORS[kind]
    m1 = gen(6, 500, 80.0, 3)
    m2 = gen(6, 500, 80.0, 3)
    m3 = gen(6, 500, 80.0, 4)
    assert m1.shape == (6, 500)
    np.testing.assert_array_equal(m1, m2)
    assert not np.array_equal(m1, m3)
    assert (m1 >= 0).all()
    assert m1.sum(axis=0).mean() == pytest.approx(80.0, rel=0.05)


def test_from_pool_trace_is_exact_share_scaling():
    trace = get_trace("twitter", 300, mean_rps=50)
    share = np.array([0.5, 0.3, 0.2])
    mat = from_pool_trace(trace, share)
    # bit-identical to the engine's internal fan-out (trace[t] * share[a])
    for t in (0, 17, 299):
        np.testing.assert_array_equal(mat[:, t], trace[t] * share)


def test_flash_crowd_modes_differ():
    kw = dict(n_events=2, amplitude=4.0)
    corr = GENERATORS["flash_crowd"](4, 600, 100.0, 1, mode="correlated", **kw)
    anti = GENERATORS["flash_crowd"](4, 600, 100.0, 1, mode="anti", **kw)
    solo = GENERATORS["flash_crowd"](4, 600, 100.0, 1, mode="solo", **kw)
    assert not np.array_equal(corr, anti) and not np.array_equal(anti, solo)


def test_hotswap_shifts_popularity():
    """After a hotswap shift the per-arch share of pool demand moves:
    some arch's late-window share grows well beyond its early share."""
    mat = GENERATORS["hotswap"](4, 1200, 100.0, 5, n_shifts=2, boost=6.0)
    w_early = mat[:, :200].sum(axis=1) / mat[:, :200].sum()
    w_late = mat[:, -200:].sum(axis=1) / mat[:, -200:].sum()
    assert np.abs(w_late - w_early).max() > 0.1


# ---------------------------------------------------------------------------
# Scenario spec.
# ---------------------------------------------------------------------------
def test_scenario_json_roundtrip_rebuilds_identically():
    sc = get_scenario("mmpp_bursts")
    sc2 = Scenario.from_json(sc.to_json())
    assert sc2 == sc
    np.testing.assert_array_equal(sc.build(5), sc2.build(5))
    # the dict form is plain JSON (benchmark artifacts embed it)
    json.dumps(sc.to_dict())


def test_scenario_overrides_do_not_mutate_spec():
    sc = get_scenario("diurnal_phases")
    a = sc.build(3, seed=99, duration_s=200, mean_rps=10.0)
    assert a.shape == (3, 200)
    assert a.sum(axis=0).mean() == pytest.approx(10.0, rel=0.05)
    b = sc.build(3)
    assert b.shape == (3, sc.duration_s)   # spec unchanged


def test_unknown_scenario_kind_rejected():
    with pytest.raises(AssertionError):
        Scenario("bad", kind="nope")


# ---------------------------------------------------------------------------
# The streaming per-arch monitor.
# ---------------------------------------------------------------------------
def test_pool_monitor_matches_scalar_monitor_per_row():
    """PoolLoadMonitor == one LoadMonitor per arch, on arbitrary streams."""
    rng = np.random.default_rng(0)
    rates = rng.uniform(0, 50, size=(3, 700))   # longer than the window
    pool = PoolLoadMonitor(3)
    scalars = [LoadMonitor() for _ in range(3)]
    for t in range(rates.shape[1]):
        pool.observe(rates[:, t])
        for a, m in enumerate(scalars):
            m.observe(float(rates[a, t]))
        np.testing.assert_allclose(pool.rate, [m.rate for m in scalars], rtol=1e-12)
        np.testing.assert_allclose(pool.peak, [m.peak for m in scalars], rtol=1e-12)
        if t in (0, 5, 298, 299, 300, 699):     # window edges + steady state
            np.testing.assert_allclose(
                pool.median, [m.median for m in scalars], rtol=1e-12
            )
            np.testing.assert_allclose(
                pool.peak_to_median,
                [m.peak_to_median for m in scalars], rtol=1e-12,
            )


@pytest.mark.parametrize("stream", ["gamma", "duplicates", "constant", "walk"])
def test_pool_monitor_incremental_matches_naive(stream):
    """The banded incremental order-statistic structure must be
    bit-identical to the naive full-window recompute on every tick —
    continuous data, duplicate-heavy integer data, constant rows (zero
    arrivals), and drifting random walks (band re-centering)."""
    rng = np.random.default_rng(7)
    T, A, W = 700, 16, 300
    s = {
        "gamma": rng.gamma(2.0, 40.0, (T, A)),
        "duplicates": rng.integers(0, 5, (T, A)).astype(float),
        "constant": np.zeros((T, A)),
        "walk": np.abs(np.cumsum(rng.normal(0, 4.0, (T, A)), axis=0) + 200),
    }[stream]
    inc = PoolLoadMonitor(A, window_s=W)
    ref = PoolLoadMonitor(A, window_s=W, incremental=False)
    for t in range(T):
        inc.observe(s[t])
        ref.observe(s[t])
        np.testing.assert_array_equal(inc.peak, ref.peak)
        np.testing.assert_array_equal(inc.median, ref.median)
    for a, b in zip(inc.stats(), ref.stats()):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Scenario composition.
# ---------------------------------------------------------------------------
def test_compose_splice_equals_children_segments():
    sc = get_scenario("diurnal_flash_splice")
    m = sc.build(6)
    kids = [Scenario.from_dict(c) for c in sc.params["children"]]
    built = [k.build(6, duration_s=sc.duration_s, mean_rps=sc.mean_rps)
             for k in kids]
    half = sc.duration_s // 2
    np.testing.assert_array_equal(m[:, :half], built[0][:, :half])
    np.testing.assert_array_equal(m[:, half:], built[1][:, half:])


def test_compose_roundtrip_and_seed_delta():
    sc = get_scenario("diurnal_flash_splice")
    sc2 = Scenario.from_json(sc.to_json())
    assert sc2 == sc
    np.testing.assert_array_equal(sc.build(4), sc2.build(4))
    json.dumps(sc.to_dict())        # artifacts embed the spec
    # a seed override re-rolls every child coherently and deterministically
    a = sc.build(4, seed=sc.seed + 9)
    b = sc.build(4, seed=sc.seed + 9)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, sc.build(4))


def test_compose_sum_preserves_pool_mean():
    kids = [
        Scenario("a", kind="diurnal").to_dict(),
        Scenario("b", kind="mmpp", seed=2).to_dict(),
    ]
    sc = Scenario("mix", kind="compose",
                  params={"op": "sum", "weights": [0.7, 0.3], "children": kids})
    m = sc.build(5, duration_s=600, mean_rps=90.0)
    assert m.shape == (5, 600)
    assert (m >= 0).all()
    assert m.sum(axis=0).mean() == pytest.approx(90.0, rel=0.05)


def test_compose_rejects_bad_specs():
    kid = Scenario("a", kind="diurnal").to_dict()
    with pytest.raises(AssertionError):
        Scenario("x", kind="compose", params={"children": [kid]})     # 1 child
    with pytest.raises(AssertionError):
        Scenario("x", kind="compose",
                 params={"op": "nope", "children": [kid, kid]})
    with pytest.raises(AssertionError):
        Scenario("x", kind="compose",
                 params={"op": "splice", "splits": [1.5],
                         "children": [kid, kid]})


# ---------------------------------------------------------------------------
# Backward equivalence: the per-arch path reproduces the shared path.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["reactive", "exascale", "mixed", "paragon"])
def test_from_pool_trace_matches_shared_engine(workload, policy):
    """Driving the engine with the from_pool_trace matrix must reproduce
    the shared-trace run exactly at summary level — the adapter IS
    today's behavior, through the new per-arch monitor path."""
    trace = get_trace("berkeley", 400, mean_rps=120)
    mat = from_pool_trace(trace, shares(workload))
    a = simulate(trace, workload, SCHEDULERS[policy]()).summary()
    b = simulate(mat, workload, SCHEDULERS[policy]()).summary()
    assert a == b


def test_from_pool_trace_matches_shared_engine_vectorized(workload):
    trace = get_trace("wits", 500, mean_rps=90)
    mat = from_pool_trace(trace, shares(workload))
    a = simulate(trace, workload, VECTOR_SCHEDULERS["paragon"]()).summary()
    b = simulate(mat, workload, VECTOR_SCHEDULERS["paragon"]()).summary()
    assert a == b


# ---------------------------------------------------------------------------
# Per-arch conservation through the matrix path.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(SCENARIO_ZOO))
def test_per_arch_conservation_every_tick(workload, name):
    """admitted == served_vm + served_burst + dropped + queued, per arch,
    after every tick, for every zoo scenario."""
    sc = get_scenario(name)
    arrivals = sc.build(len(workload), duration_s=300, mean_rps=60.0)
    sim = ServingSim(arrivals, workload)
    pol = VECTOR_SCHEDULERS["paragon"]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
        c = sim.per_arch_counts()
        accounted = (
            c["served_vm"] + c["served_burst"] + c["dropped"]
            + c["expired_end"] + c["queued"]
        )
        np.testing.assert_allclose(c["arrived"], accounted, atol=1e-6)
    # and the per-arch totals agree with the pool ledger
    c = sim.per_arch_counts()
    assert sim.res.total_requests == pytest.approx(float(c["arrived"].sum()))
    assert sim.res.served_burst == pytest.approx(float(c["served_burst"].sum()))


def test_heterogeneous_monitor_sees_per_arch_bursts(workload):
    """One arch bursts, the rest stay flat: only the bursting arch's
    peak-to-median should blow up — exactly what share-scaling of a pool
    monitor can never express."""
    n, T = len(workload), 900
    arrivals = np.full((n, T), 20.0)
    arrivals[2, 450:480] = 200.0           # one flash crowd on arch 2
    sim = ServingSim(arrivals, workload)
    pol = VECTOR_SCHEDULERS["reactive"]()
    p2m_at_burst = None
    while not sim.done:
        obs = sim.observe_pool()
        if sim.tick == 500:
            p2m_at_burst = obs.peak_to_median.copy()
        sim.apply_pool(pol(sim.tick, obs))
    flat = [a for a in range(n) if a != 2]
    assert p2m_at_burst[2] > 5.0
    assert np.all(p2m_at_burst[flat] < 1.5)


def test_matrix_shape_mismatch_rejected(workload):
    with pytest.raises(AssertionError):
        ServingSim(np.ones((2, 100)), workload)   # 2 rows for 4 archs


# ---------------------------------------------------------------------------
# Trace replay: captured [A, T] matrices as first-class scenarios.
# ---------------------------------------------------------------------------
def test_replay_roundtrips_capture_exactly(tmp_path):
    """save_replay -> Scenario(kind="replay") -> build returns the
    captured matrix verbatim, and the spec JSON-round-trips."""
    captured = get_scenario("mmpp_bursts").build(4, duration_s=300,
                                                 mean_rps=70)
    path = str(tmp_path / "capture.npz")
    save_replay(path, captured)
    sc = Scenario("replayed", kind="replay", duration_s=300, mean_rps=70,
                  params={"path": path})
    np.testing.assert_array_equal(sc.build(4), captured)
    # replay is literal: a re-rolled episode seed replays the capture
    np.testing.assert_array_equal(sc.build(4, seed=sc.seed + 5), captured)
    sc2 = Scenario.from_json(sc.to_json())
    assert sc2 == sc
    np.testing.assert_array_equal(sc2.build(4), captured)


def test_replay_truncates_never_invents(tmp_path):
    captured = get_scenario("diurnal_phases").build(3, duration_s=200,
                                                    mean_rps=50)
    path = str(tmp_path / "cap.npz")
    save_replay(path, captured)
    short = Scenario("cut", kind="replay", duration_s=120,
                     params={"path": path}).build(3)
    np.testing.assert_array_equal(short, captured[:, :120])
    with pytest.raises(AssertionError):    # longer than the capture
        Scenario("long", kind="replay", duration_s=500,
                 params={"path": path}).build(3)
    with pytest.raises(AssertionError):    # wrong pool size
        Scenario("rows", kind="replay", duration_s=100,
                 params={"path": path}).build(5)


def test_replay_renormalizes_pool_mean(tmp_path):
    captured = get_scenario("flash_anti").build(4, duration_s=240,
                                                mean_rps=30)
    path = str(tmp_path / "cap.npz")
    save_replay(path, captured)
    mat = Scenario("scaled", kind="replay", duration_s=240, mean_rps=90,
                   params={"path": path, "renormalize": True}).build(4)
    assert mat.sum(axis=0).mean() == pytest.approx(90.0)
    # shape preserved up to one global scale
    np.testing.assert_allclose(
        mat / max(mat.max(), 1e-12), captured / max(captured.max(), 1e-12),
        atol=1e-12,
    )


def test_replay_drives_engine_and_env(workload, tmp_path):
    """A replayed scenario is a drop-in engine/RL-env workload source —
    closes the ROADMAP trace-replay item end to end."""
    from repro.core.rl import EnvConfig, PoolServingEnv

    captured = get_scenario("flash_correlated").build(
        len(workload), duration_s=150, mean_rps=60
    )
    path = str(tmp_path / "cap.npz")
    save_replay(path, captured)
    sc = Scenario("rp", kind="replay", duration_s=150, mean_rps=60,
                  params={"path": path})
    res = simulate(sc.build(len(workload)), workload,
                   VECTOR_SCHEDULERS["paragon"]())
    assert res.total_requests == pytest.approx(float(captured.sum()))
    env = PoolServingEnv(workload, EnvConfig(mean_rps=60, duration_s=150),
                         scenarios=[sc])
    env.reset()
    np.testing.assert_array_equal(env.sim.arrivals, captured)
