"""Prefill + decode against full-sequence forward — the serving-engine
correctness contract, including sliding-window ring caches and enc-dec."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import model as model_lib

# the two MoE members xfail: pre-existing seed failure — their decode-step
# logits diverge from the full forward (err ~1.1 vs 5e-3 tol), a routing
# mismatch between the batched prefill and single-token decode paths
_MOE_XFAIL = pytest.mark.xfail(
    reason="seed-era MoE prefill/decode routing divergence (fails at seed commit)",
    strict=True,
)
DECODE_ARCHS = [
    "llama3-8b", "qwen1.5-0.5b", "qwen2-72b", "minicpm-2b",
    pytest.param("phi3.5-moe-42b-a6.6b", marks=_MOE_XFAIL),
    "rwkv6-1.6b", "recurrentgemma-9b",
    "whisper-small",
    pytest.param("kimi-k2-1t-a32b", marks=_MOE_XFAIL),
]


def _setup(arch, b=2, s=20, seed=0):
    cfg = get_config(arch).reduced()
    params = model_lib.init_params(cfg, jax.random.key(seed))
    toks = jax.random.randint(jax.random.key(seed + 1), (b, s), 0, cfg.vocab_size)
    enc = None
    if cfg.is_encoder_decoder:
        enc = jax.random.normal(
            jax.random.key(seed + 2), (b, cfg.encoder_seq, cfg.d_model)
        )
    return cfg, params, toks, enc


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg, params, toks, enc = _setup(arch)
    b, s = toks.shape
    cache = model_lib.init_cache(cfg, b, 32)
    last, cache = model_lib.prefill(cfg, params, toks, cache, enc_inputs=enc)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    dl, cache = model_lib.decode_step(cfg, params, nxt, cache)
    ext = jnp.concatenate([toks, nxt[:, None]], 1)
    full, _ = model_lib.forward(cfg, params, ext, enc_inputs=enc)
    err = float(jnp.max(jnp.abs(dl - full[:, -1])))
    assert err < 5e-3, (arch, err)


def test_multi_token_decode_chain():
    cfg, params, toks, _ = _setup("qwen1.5-0.5b")
    b, s = toks.shape
    cache = model_lib.init_cache(cfg, b, 40)
    last, cache = model_lib.prefill(cfg, params, toks, cache)
    seq = [jnp.argmax(last, -1).astype(jnp.int32)]
    for _ in range(4):
        dl, cache = model_lib.decode_step(cfg, params, seq[-1], cache)
        seq.append(jnp.argmax(dl, -1).astype(jnp.int32))
    # greedy rollout with full forward must agree
    cur = toks
    for i in range(5):
        full, _ = model_lib.forward(cfg, params, cur)
        nxt = jnp.argmax(full[:, -1], -1).astype(jnp.int32)
        assert bool(jnp.all(nxt == seq[i])), f"divergence at step {i}"
        cur = jnp.concatenate([cur, nxt[:, None]], 1)


def test_sliding_window_ring_cache():
    cfg, params, toks, _ = _setup("llama3-8b")
    W = 8
    b, s = toks.shape
    ref, _ = model_lib.forward(cfg, params, toks, window=W)

    # ring cache exactly the window size, smaller than the prompt
    cache = model_lib.init_cache(cfg, b, W, window=W)
    last, cache = model_lib.prefill(cfg, params, toks, cache, window=W)
    err = float(jnp.max(jnp.abs(last - ref[:, -1])))
    assert err < 5e-3, err

    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    dl, cache = model_lib.decode_step(cfg, params, nxt, cache, window=W)
    ext = jnp.concatenate([toks, nxt[:, None]], 1)
    ref2, _ = model_lib.forward(cfg, params, ext, window=W)
    err2 = float(jnp.max(jnp.abs(dl - ref2[:, -1])))
    assert err2 < 5e-3, err2


def test_long_context_window_decode_rgemma():
    """Hybrid arch: RG-LRU state + local-attention ring must chain."""
    cfg, params, toks, _ = _setup("recurrentgemma-9b", s=24)
    b = toks.shape[0]
    cache = model_lib.init_cache(cfg, b, 16)
    last, cache = model_lib.prefill(cfg, params, toks, cache)
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    for _ in range(3):
        dl, cache = model_lib.decode_step(cfg, params, nxt, cache)
        nxt = jnp.argmax(dl, -1).astype(jnp.int32)
        assert not bool(jnp.any(jnp.isnan(dl)))


def test_whisper_cross_attention_cache():
    cfg, params, toks, enc = _setup("whisper-small", s=12)
    b = toks.shape[0]
    cache = model_lib.init_cache(cfg, b, 24)
    last, cache = model_lib.prefill(cfg, params, toks, cache, enc_inputs=enc)
    assert "cross" in cache
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    dl, cache = model_lib.decode_step(cfg, params, nxt, cache)
    ext = jnp.concatenate([toks, nxt[:, None]], 1)
    full, _ = model_lib.forward(cfg, params, ext, enc_inputs=enc)
    err = float(jnp.max(jnp.abs(dl - full[:, -1])))
    assert err < 5e-3, err
