"""The assigned architectures: exact hyper-parameters + reduced variants."""
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_architectures

# (arch, layers, d_model, heads, kv_heads, d_ff, vocab) from the assignment
ASSIGNED = {
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "rwkv6-1.6b": (24, 2048, None, None, 7168, 65536),
    "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
    "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
    "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "whisper-small": (12, 768, 12, 12, 3072, 51865),
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
    "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
}


def test_all_assigned_present():
    assert set(list_architectures()) == set(ASSIGNED)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_exact_hyperparams(arch):
    L, d, h, kv, ff, v = ASSIGNED[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    if h is not None:
        assert cfg.num_heads == h
        assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.source, f"{arch} missing citation"
    assert 0 < cfg.quality < 1


def test_moe_configs():
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert (phi.num_experts, phi.num_experts_per_tok) == (16, 2)
    kimi = get_config("kimi-k2-1t-a32b")
    assert (kimi.num_experts, kimi.num_experts_per_tok) == (384, 8)
    # active params must be well under total
    assert kimi.params_active < 0.08 * kimi.params_total
    assert 0.9e12 < kimi.params_total < 1.3e12, "kimi should be ~1T total"
    assert 25e9 < kimi.params_active < 40e9, "kimi ~32B active"


def test_param_scale_sanity():
    for arch, lo, hi in [
        ("llama3-8b", 7e9, 9e9),
        ("qwen2-72b", 65e9, 80e9),
        ("qwen1.5-0.5b", 0.4e9, 0.8e9),
        ("rwkv6-1.6b", 1.2e9, 2.2e9),
        ("minicpm-2b", 2.0e9, 3.3e9),
    ]:
        total = get_config(arch).params_total
        assert lo < total < hi, (arch, total)


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.num_layers <= 4
    assert r.d_model <= 512
    assert (r.num_experts or 0) <= 4
    assert r.vocab_size <= 1024
    # family preserved
    assert r.family == get_config(arch).family
    assert r.block_pattern == get_config(arch).block_pattern


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_layer_kinds_cover_all_layers():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        kinds = cfg.layer_kinds()
        assert len(kinds) == cfg.num_layers


def test_recurrentgemma_pattern():
    cfg = get_config("recurrentgemma-9b")
    kinds = cfg.layer_kinds()
    assert kinds.count("local") == 12          # 1 local-attn per 3 layers
    assert kinds.count("rglru") == 26
