"""RL controller: env contract + PPO machinery (fast versions)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.rl.env import HEADROOMS, N_ACTIONS, OBS_DIM, OFFLOADS, EnvConfig, ServingEnv
from repro.core.rl.ppo import (
    PPOConfig,
    compute_gae,
    evaluate_policy,
    init_net,
    policy_logits_value,
    train_ppo,
)
from repro.core.traces import get_trace
from repro.core.workloads import get_scenario


@pytest.fixture(scope="module")
def env():
    trace = get_trace("twitter", 300, mean_rps=40)
    return ServingEnv(EnvConfig(arch="qwen1.5-0.5b", mean_rps=40), trace)


def test_env_scenario_sampling_deterministic_and_varied():
    """A scenario-pool env samples a fresh seeded realization per episode:
    two envs with the same scenario_seed walk identical episode sequences,
    and consecutive episodes see different arrivals."""
    cfg = EnvConfig(arch="qwen1.5-0.5b", mean_rps=40, duration_s=150)
    scs = [get_scenario("mmpp_bursts"), get_scenario("flash_anti")]
    e1 = ServingEnv(cfg, scenarios=scs, scenario_seed=3)
    e2 = ServingEnv(cfg, scenarios=scs, scenario_seed=3)
    o1, o2 = e1.reset(), e2.reset()
    np.testing.assert_array_equal(o1, o2)
    np.testing.assert_array_equal(e1.sim.trace, e2.sim.trace)
    assert e1.last_scenario.name == e2.last_scenario.name
    ep1 = e1.sim.trace.copy()
    e1.reset()
    assert not np.array_equal(e1.sim.trace, ep1)   # fresh realization
    # the sampled arrivals land on the cfg's duration / pool mean
    assert e1.sim.trace.shape == (150,)
    assert e1.sim.trace.mean() == pytest.approx(40.0, rel=0.1)


def test_env_scenario_episode_runs_to_done():
    cfg = EnvConfig(arch="qwen1.5-0.5b", mean_rps=30, duration_s=120)
    env = ServingEnv(cfg, scenarios=[get_scenario("diurnal_phases")])
    env.reset()
    done, steps = False, 0
    while not done:
        _, r, done, _ = env.step(steps % N_ACTIONS)
        assert np.isfinite(r)
        steps += 1
    assert steps == 120


def test_env_contract(env):
    obs = env.reset()
    assert obs.shape == (OBS_DIM,)
    total = 0.0
    for t in range(50):
        obs, r, done, metrics = env.step(t % N_ACTIONS)
        assert obs.shape == (OBS_DIM,)
        assert np.isfinite(r) and r <= 0.0
        assert metrics["cost"] >= 0.0
        assert not done
        total += r
    assert total < 0.0


def test_env_offload_action_buys_slo(env):
    """Forcing blind offload must not violate more than never offloading."""
    def run(action):
        e = ServingEnv(env.cfg, env.base_trace)
        e.reset()
        done = False
        while not done:
            _, _, done, _ = e.step(action)
        return e.episode_result()

    a_none = HEADROOMS.index(1.0) * len(OFFLOADS) + OFFLOADS.index("none")
    a_blind = HEADROOMS.index(1.0) * len(OFFLOADS) + OFFLOADS.index("blind")
    r_none, r_blind = run(a_none), run(a_blind)
    assert r_blind.violation_rate <= r_none.violation_rate
    assert r_blind.cost_total >= r_none.cost_total  # premium is not free


def test_gae_simple_case():
    rewards = np.array([1.0, 1.0, 1.0], np.float32)
    values = np.zeros(3, np.float32)
    dones = np.zeros(3, np.float32)
    adv, ret = compute_gae(rewards, values, dones, last_value=0.0,
                           gamma=1.0, lam=1.0)
    # undiscounted full-lambda GAE == reward-to-go
    assert np.allclose(ret, [3.0, 2.0, 1.0])


def test_gae_done_boundary():
    rewards = np.array([1.0, 1.0], np.float32)
    values = np.zeros(2, np.float32)
    dones = np.array([1.0, 0.0], np.float32)    # episode ends after step 0
    adv, ret = compute_gae(rewards, values, dones, last_value=5.0,
                           gamma=0.9, lam=1.0)
    assert ret[0] == pytest.approx(1.0)          # no bootstrap across done
    assert ret[1] == pytest.approx(1.0 + 0.9 * 5.0)


def test_net_shapes():
    params = init_net(jax.random.key(0), PPOConfig(hidden=16))
    logits, value = policy_logits_value(params, jnp.zeros((OBS_DIM,)))
    assert logits.shape == (N_ACTIONS,)
    assert value.shape == ()
    logits_b, value_b = policy_logits_value(params, jnp.zeros((5, OBS_DIM)))
    assert logits_b.shape == (5, N_ACTIONS)
    assert value_b.shape == (5,)


def test_ppo_short_training_improves(env):
    """A few PPO iterations must improve on the untrained policy."""
    cfg = PPOConfig(iterations=8, rollout_len=300, hidden=32, seed=1)
    state = train_ppo(env, cfg)
    assert len(state.history) == 8
    assert np.isfinite(state.best_reward)
    first = state.history[0]["rollout_reward"]
    assert state.best_reward >= first
    res = evaluate_policy(ServingEnv(env.cfg, env.base_trace), state.params, seed=3)
    assert res.total_requests > 0
    assert res.violation_rate < 0.5
