"""Serving engine + continuous batching."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import frontends, model as model_lib
from repro.serving import ContinuousBatcher, Engine, EngineConfig, Request


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = model_lib.init_params(cfg, jax.random.key(0))
    return cfg, params


def test_engine_matches_forward_rollout(small_lm):
    cfg, params = small_lm
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
    eng = Engine(cfg, params, EngineConfig(slots=2, cache_len=64, max_new_tokens=5))
    req = Request(rid=0, prompt=prompt, max_new_tokens=5)
    eng.insert(req)
    while not req.finished:
        eng.step()

    toks = list(prompt)
    for _ in range(6):
        logits, _ = model_lib.forward(cfg, params, jnp.asarray(toks, jnp.int32)[None])
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.output[:6] == toks[len(prompt):]


def test_ragged_batch_isolation(small_lm):
    """Two requests of different lengths decode independently."""
    cfg, params = small_lm
    rng = np.random.default_rng(2)
    p1 = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab_size, size=11).astype(np.int32)

    def solo(prompt, n):
        eng = Engine(cfg, params, EngineConfig(slots=1, cache_len=64, max_new_tokens=n))
        r = Request(rid=0, prompt=prompt, max_new_tokens=n)
        eng.insert(r)
        while not r.finished:
            eng.step()
        return r.output

    eng = Engine(cfg, params, EngineConfig(slots=2, cache_len=64, max_new_tokens=4))
    r1 = Request(rid=1, prompt=p1, max_new_tokens=4)
    r2 = Request(rid=2, prompt=p2, max_new_tokens=4)
    eng.insert(r1)
    eng.insert(r2)
    while not (r1.finished and r2.finished):
        eng.step()
    assert r1.output == solo(p1, 4)
    assert r2.output == solo(p2, 4)


def test_slot_reuse_after_finish(small_lm):
    cfg, params = small_lm
    rng = np.random.default_rng(3)
    eng = Engine(cfg, params, EngineConfig(slots=2, cache_len=64, max_new_tokens=3))
    bat = ContinuousBatcher(eng)
    for i in range(6):
        bat.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
            max_new_tokens=3,
        ))
    stats = bat.run_until_idle()
    s = stats.summary()
    assert s["admitted"] == 6 and s["finished"] == 6
    # 6 requests x 3 tokens on 2 slots: >= 9 decode steps, < 6*3+prefills
    assert s["decode_steps"] >= 8


def test_batcher_conservation(small_lm):
    cfg, params = small_lm
    rng = np.random.default_rng(4)
    eng = Engine(cfg, params, EngineConfig(slots=3, cache_len=64, max_new_tokens=2))
    bat = ContinuousBatcher(eng)
    n = 7
    reqs = []
    for i in range(n):
        r = Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
                    max_new_tokens=2)
        reqs.append(r)
        bat.submit(r)
    bat.run_until_idle()
    assert all(r.finished for r in reqs)
    assert all(len(r.output) == 1 + 2 for r in reqs)  # prefill token + 2 decoded


def test_vlm_embedding_serving():
    """VLM path: precomputed patch+text embeddings through forward."""
    cfg = get_config("llava-next-mistral-7b").reduced()
    params = model_lib.init_params(cfg, jax.random.key(5))
    rng = np.random.default_rng(5)
    text = rng.integers(0, cfg.vocab_size, size=(2, 6)).astype(np.int32)
    inputs = frontends.multimodal_inputs(cfg, text, params["embed"], tiles=0, seed=1)
    # tiles=0 -> max(1, 0) = 1 tile of 576 patches
    assert inputs.shape == (2, 576 + 6, cfg.d_model)
    logits, _ = model_lib.forward(cfg, params, jnp.asarray(inputs))
    assert logits.shape == (2, 576 + 6, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_audio_frontend_shapes():
    cfg = get_config("whisper-small").reduced()
    x = frontends.audio_frames(cfg, 3, seed=2)
    assert x.shape == (3, cfg.encoder_seq, cfg.d_model)
