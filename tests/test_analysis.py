"""repro.analysis: each pass catches its seeded violation, stays silent
on the compliant idiom, and the real tree is clean modulo the baseline.

Fixture trees are written to tmp_path (``src/`` + optional ``tests/``)
and analyzed through the same :class:`AnalysisContext` the CLI uses, so
these tests cover the full parse → pass → finding-key pipeline.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import (
    AnalysisContext,
    BaselineError,
    PASS_REGISTRY,
    apply_baseline,
    load_baseline,
    run_passes,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_PASSES = ("registry-parity", "jit-hygiene", "determinism",
              "telemetry-guard", "soa-aliasing")


def _ctx(tmp_path, files):
    """Write ``{relpath: source}`` under tmp_path, analyze its src/."""
    for rel, source in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(source))
    return AnalysisContext([str(tmp_path / "src")], repo_root=str(tmp_path))


def _run(tmp_path, files, select):
    return run_passes(_ctx(tmp_path, files), select=[select])


def _slugs(findings):
    return {f.slug for f in findings}


def test_pass_registry_is_complete():
    assert tuple(PASS_REGISTRY) == ALL_PASSES
    for lp in PASS_REGISTRY.values():
        assert lp.description


# ---------------------------------------------------------------------------
# registry-parity
# ---------------------------------------------------------------------------
def test_registry_parity_flags_missing_twins(tmp_path):
    findings = _run(tmp_path, {
        "src/regs.py": """
            SCHEDULERS = {"reactive": 1}
            VECTOR_SCHEDULERS = {"reactive": 2}
            VECTOR_SCHEDULERS["soa_only"] = 3
            JAX_POLICIES = {"reactive": 4, "scan_only": 5}
        """,
    }, "registry-parity")
    assert _slugs(findings) == {
        "vector-soa_only-missing-dict-twin",
        "jax-scan_only-missing-vector-twin",
    }
    # stable keys: pass:path:slug, no line numbers
    assert all(f.key.startswith("registry-parity:") for f in findings)


def test_registry_parity_flags_stale_test_parametrization(tmp_path):
    findings = _run(tmp_path, {
        "src/regs.py": 'SCHEDULERS = {"reactive": 1}\n',
        "tests/test_parity.py": """
            import pytest

            @pytest.mark.parametrize("policy", ["reactive", "ghost"])
            def test_p(policy):
                pass
        """,
    }, "registry-parity")
    assert _slugs(findings) == {"test-param-ghost-unregistered"}


def test_registry_parity_silent_on_twinned_registries(tmp_path):
    findings = _run(tmp_path, {
        "src/regs.py": """
            SCHEDULERS = {"reactive": 1, "paragon": 2}
            VECTOR_SCHEDULERS = {"reactive": 3, "paragon": 4}
            JAX_POLICIES = {"reactive": 5}
        """,
        "tests/test_parity.py": """
            import pytest

            @pytest.mark.parametrize("policy", ["reactive", "paragon"])
            def test_p(policy):
                pass

            @pytest.mark.parametrize("policy", sorted({"computed"}))
            def test_computed(policy):   # non-literal lists are skipped
                pass
        """,
    }, "registry-parity")
    assert findings == []


# ---------------------------------------------------------------------------
# jit-hygiene
# ---------------------------------------------------------------------------
def test_jit_hygiene_flags_host_syncs_and_branches(tmp_path):
    findings = _run(tmp_path, {
        "src/hot.py": """
            import jax
            import jax.numpy as jnp
            import numpy as np

            @jax.jit
            def step(x):
                if x > 0:
                    x = np.maximum(x, 0.0)
                y = x.item()
                return float(x) + y
        """,
    }, "jit-hygiene")
    assert _slugs(findings) == {
        "step-python-if-on-traced",
        "step-np-on-traced-maximum",
        "step-host-sync-item",
        "step-host-sync-float",
    }


def test_jit_hygiene_follows_scan_vmap_and_jaxpolicy_roots(tmp_path):
    findings = _run(tmp_path, {
        "src/engine.py": """
            import jax
            from helpers import shared

            def body(carry, x):
                return shared(carry), x

            def run(xs):
                return jax.lax.scan(body, 0.0, xs)

            JAX_POLICIES = {"p": JaxPolicy(pol)}

            def pol(state):
                return state.q.item()
        """,
        "src/helpers.py": """
            def shared(c):
                while c:
                    c = c - 1
                return c
        """,
    }, "jit-hygiene")
    assert _slugs(findings) == {
        "shared-python-while-on-traced",   # cross-module via from-import
        "pol-host-sync-item",              # JaxPolicy apply root
    }


def test_jit_hygiene_flags_unhashable_static_arg(tmp_path):
    findings = _run(tmp_path, {
        "src/hot.py": """
            from functools import partial
            import jax

            @partial(jax.jit, static_argnames=("cfg",))
            def update(x, cfg):
                return x

            update(1.0, cfg={"lr": 0.1})
        """,
    }, "jit-hygiene")
    assert _slugs(findings) == {"unhashable-static-update-cfg"}


def test_jit_hygiene_silent_on_compliant_jit_code(tmp_path):
    findings = _run(tmp_path, {
        "src/hot.py": """
            from functools import partial
            import jax
            import jax.numpy as jnp
            import numpy as np

            @partial(jax.jit, static_argnames=("mode",))
            def step(x, key, mode, lazy: bool, xp=np, unroll=4):
                if mode == "fast":          # static_argnames
                    x = jnp.maximum(x, 0.0)
                if lazy:                    # bool-annotated = static flag
                    x = x * 2
                if xp is np:                # identity check = trace-time
                    pass
                if x.shape[0] > unroll:     # shapes are static
                    x = x[:unroll]
                return jnp.where(x > 0, x, 0.0)
        """,
    }, "jit-hygiene")
    assert findings == []


def test_jit_hygiene_ignores_host_side_code(tmp_path):
    findings = _run(tmp_path, {
        "src/host.py": """
            import numpy as np

            def summarize(xs):            # never jitted: np/if/float fine
                if xs.size:
                    return float(np.mean(xs))
                return 0.0
        """,
    }, "jit-hygiene")
    assert findings == []


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------
def test_determinism_flags_global_state_randomness(tmp_path):
    findings = _run(tmp_path, {
        "src/bad.py": """
            import random
            import time
            import numpy as np

            def draw(n):
                seed = time.time()
                np.random.seed(int(seed))
                return np.random.rand(n) + random.random()
        """,
    }, "determinism")
    assert _slugs(findings) == {
        "draw-np-random-seed",
        "draw-np-random-rand",
        "draw-stdlib-random-random",
        "draw-clock-seed",
    }


def test_determinism_flags_from_random_import(tmp_path):
    findings = _run(tmp_path, {
        "src/bad.py": "from random import shuffle\n",
    }, "determinism")
    assert _slugs(findings) == {"from-random-import"}


def test_determinism_silent_on_seeded_generators(tmp_path):
    findings = _run(tmp_path, {
        "src/good.py": """
            import time
            import numpy as np
            import jax

            def draw(n, seed):
                rng = np.random.default_rng(seed)
                key = jax.random.PRNGKey(seed)
                t0 = time.perf_counter()      # timing, not seeding
                out = rng.normal(size=n) + jax.random.uniform(key, (n,))
                return out, time.perf_counter() - t0
        """,
    }, "determinism")
    assert findings == []


# ---------------------------------------------------------------------------
# telemetry-guard
# ---------------------------------------------------------------------------
_TEL = """
    EV_ARRIVAL = "arrival"
    EVENT_TYPES = {EV_ARRIVAL: "arrivals this tick", "serve": "served"}

    class Telemetry:
        def emit(self, tick, etype, value):
            pass
"""


def test_telemetry_guard_flags_unguarded_emission(tmp_path):
    findings = _run(tmp_path, {
        "src/tel.py": _TEL,
        "src/engine.py": """
            def step(self, tick):
                tel = self.telemetry
                tel.emit(tick, "arrival", 1)
        """,
    }, "telemetry-guard")
    assert _slugs(findings) == {"unguarded-step-emit"}


def test_telemetry_guard_flags_unknown_etype_and_ev_const(tmp_path):
    findings = _run(tmp_path, {
        "src/tel.py": _TEL + '\n    EV_GHOST = "ghost"\n',
        "src/engine.py": """
            def step(self, tick):
                tel = self.telemetry
                if tel is not None:
                    tel.emit(tick, "arival", 1)   # typo'd etype
        """,
    }, "telemetry-guard")
    assert _slugs(findings) == {
        "etype-const-EV_GHOST-undocumented",
        "etype-arival-unknown",
    }


def test_telemetry_guard_silent_on_guarded_idioms(tmp_path):
    findings = _run(tmp_path, {
        "src/tel.py": _TEL,
        "src/engine.py": """
            def a(self, tick):
                tel = self.telemetry
                if tel is not None:
                    tel.emit(tick, "arrival", 1)

            def b(self, tick):
                if self.telemetry is not None:
                    self.telemetry.emit(tick, "serve", 2)

            def c(self, tick, tel):
                if tel is None:
                    return
                tel.emit(tick, "arrival", 3)

            def d(self, tick, tel, extra):
                if tel is not None and extra:
                    tel.emit(tick, "serve", 4)
        """,
    }, "telemetry-guard")
    assert findings == []


def test_telemetry_guard_flags_undocumented_summary_key(tmp_path):
    findings = _run(tmp_path, {
        "src/acct.py": """
            SUMMARY_KEY_DOCS = {
                "total_cost": "ledger total",
                "cost_<tier>": "per-tier cost",
            }

            class SimResult:
                def summary(self):
                    s = {
                        "total_cost": 1.0,
                        "mystery": 2.0,
                        **{f"cost_{t}": 0.0 for t in ("od",)},
                    }
                    s["also_undocumented"] = 3.0
                    return s
        """,
    }, "telemetry-guard")
    assert _slugs(findings) == {
        "summary-key-mystery-undocumented",
        "summary-key-also_undocumented-undocumented",
    }


# ---------------------------------------------------------------------------
# soa-aliasing
# ---------------------------------------------------------------------------
_POOLOBS = """
    class PoolObs:
        rate: object
        backlog: object

        def copy(self):
            return self
"""


def test_soa_aliasing_flags_uncopied_field_store(tmp_path):
    findings = _run(tmp_path, {
        "src/types.py": _POOLOBS,
        "src/agent.py": """
            class Agent:
                def step(self):
                    obs = self.sim.observe_pool()
                    self._prev_rate = obs.rate      # aliases scratch
        """,
    }, "soa-aliasing")
    assert _slugs(findings) == {"step-_prev_rate-aliases-rate"}


def test_soa_aliasing_silent_on_copy_and_locals(tmp_path):
    findings = _run(tmp_path, {
        "src/types.py": _POOLOBS,
        "src/agent.py": """
            class Agent:
                def step(self):
                    obs = self.sim.observe_pool()
                    self._prev_rate = obs.rate.copy()   # snapshot
                    self._pobs = self.sim.observe_pool()  # whole handle
                    rate = obs.rate                     # dies this tick
                    return rate
        """,
    }, "soa-aliasing")
    assert findings == []


# ---------------------------------------------------------------------------
# baseline mechanics
# ---------------------------------------------------------------------------
def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "baseline.txt"
    p.write_text("determinism:src/x.py:some-slug\n")
    with pytest.raises(BaselineError):
        load_baseline(str(p))


def test_baseline_matches_by_stable_key_and_reports_stale(tmp_path):
    p = tmp_path / "baseline.txt"
    p.write_text(
        "determinism:src/bad.py:draw-np-random-rand  # legacy shim\n"
        "determinism:src/bad.py:gone-finding  # fixed long ago\n")
    findings = _run(tmp_path, {
        "src/bad.py": """
            import numpy as np

            def draw(n):
                return np.random.rand(n)
        """,
    }, "determinism")
    new, baselined, stale = apply_baseline(findings, load_baseline(str(p)))
    assert new == []
    assert [f.slug for f in baselined] == ["draw-np-random-rand"]
    assert [e.key for e in stale] == ["determinism:src/bad.py:gone-finding"]


def test_parse_errors_are_reported_as_findings(tmp_path):
    ctx = _ctx(tmp_path, {"src/broken.py": "def f(:\n"})
    findings = run_passes(ctx)
    assert [f.slug for f in findings] == ["syntax-error"]


# ---------------------------------------------------------------------------
# the real tree is clean modulo the checked-in baseline
# ---------------------------------------------------------------------------
def test_repo_src_is_clean_against_baseline():
    ctx = AnalysisContext([os.path.join(REPO, "src")], repo_root=REPO)
    findings = run_passes(ctx)
    entries = load_baseline(os.path.join(REPO, "analysis_baseline.txt"))
    new, baselined, stale = apply_baseline(findings, entries)
    assert new == [], "\n".join(f.format_text() for f in new)
    assert stale == [], [e.key for e in stale]
    # the two deliberate registry exceptions stay pinned
    assert sorted(f.slug for f in baselined) == [
        "jax-rl_sample-missing-vector-twin",
        "vector-rl_pool-missing-dict-twin",
    ]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def _cli(*args, cwd=REPO):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_exits_zero_on_clean_tree():
    r = _cli("src")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 finding(s)" in r.stderr
    assert "2 baselined" in r.stderr


def test_cli_github_format_emits_annotations():
    r = _cli("src", "--format", "github", "--baseline", "none",
             "--select", "registry-parity")
    assert r.returncode == 1
    lines = [ln for ln in r.stdout.splitlines() if ln]
    assert lines, r.stderr
    for ln in lines:
        assert ln.startswith("::error file=")
        assert "title=repro.analysis registry-parity" in ln


def test_cli_lists_passes():
    r = _cli("--list")
    assert r.returncode == 0
    for pid in ALL_PASSES:
        assert pid in r.stdout


def test_cli_rejects_unknown_pass():
    r = _cli("src", "--select", "no-such-pass")
    assert r.returncode == 2
    assert "unknown pass" in r.stderr
