"""Hypothesis property tests, consolidated.

These are the randomized-property halves of test_simulator / test_units /
test_kernels / test_profiles_selection.  They live in one module behind
``importorskip`` so the rest of the suite still collects on environments
without ``hypothesis`` (it is a dev-only dependency — see
requirements-dev.txt); CI installs it and runs everything here.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.load_monitor import LoadMonitor  # noqa: E402
from repro.core.model_selection import (  # noqa: E402
    Constraint,
    NoFeasibleModel,
    feasible_set,
    select_naive,
    select_paragon,
)
from repro.core.profiles import model_pool  # noqa: E402
from repro.core.sim.queues import BucketQueue, QueueArray  # noqa: E402


# ---------------------------------------------------------------------------
# BucketQueue properties (the scalar reference queue).
# ---------------------------------------------------------------------------
@given(
    pushes=st.lists(
        st.tuples(st.integers(0, 50), st.floats(0.0, 100.0)), max_size=30
    ),
    amount=st.floats(0.0, 2000.0),
)
@settings(max_examples=200, deadline=None)
def test_queue_pop_conserves_mass(pushes, amount):
    q = BucketQueue()
    total = 0.0
    for tick, count in sorted(pushes):
        q.push(tick, count)
        total += count if count > 0 else 0.0
    popped = q.pop(amount)
    popped_mass = sum(c for _, c in popped)
    assert popped_mass <= min(amount, total) + 1e-6
    assert abs(popped_mass + q.total - total) < 1e-6


@given(
    pushes=st.lists(
        st.tuples(st.integers(0, 50), st.floats(0.1, 10.0)),
        min_size=1, max_size=20,
    )
)
@settings(max_examples=200, deadline=None)
def test_queue_fifo_order(pushes):
    q = BucketQueue()
    for tick, count in sorted(pushes):
        q.push(tick, count)
    out = q.pop(1e9)
    ticks = [t for t, _ in out]
    assert ticks == sorted(ticks)


@given(
    now=st.integers(10, 100),
    max_age=st.integers(0, 20),
    pushes=st.lists(st.tuples(st.integers(0, 100), st.floats(0.1, 5.0)), max_size=20),
)
@settings(max_examples=200, deadline=None)
def test_queue_pop_older_than(now, max_age, pushes):
    q = BucketQueue()
    expected_old = 0.0
    for tick, count in sorted(pushes):
        q.push(tick, count)
        if now - tick > max_age:
            expected_old += count
    got = q.pop_older_than(now, max_age)
    assert abs(got - expected_old) < 1e-6
    # everything remaining is young enough
    for t0, _ in q.buckets:
        assert now - t0 <= max_age


# ---------------------------------------------------------------------------
# QueueArray vs BucketQueue: the vectorized pool queue serves identically.
# ---------------------------------------------------------------------------
@given(
    arrivals=st.lists(st.floats(0.0, 20.0), min_size=1, max_size=40),
    capacity=st.floats(0.0, 15.0),
)
@settings(max_examples=100, deadline=None)
def test_queue_array_matches_bucket_queue(arrivals, capacity):
    qa = QueueArray(2, slo_s=2.0, slack=np.array([1, 1]))
    qb = BucketQueue()
    served_a = late_a = served_b = late_b = 0.0
    for tick, n in enumerate(arrivals):
        qa.push(tick, np.array([n, 0.0]))
        qb.push(tick, n)
        s, l = qa.serve(tick, np.array([capacity, 0.0]))
        served_a += float(s[0])
        late_a += float(l[0])
        for t0, cnt in qb.pop(capacity):
            served_b += cnt
            late_b += cnt if tick - t0 > 1 else 0.0
        d = qa.drop_expired(tick)
        dropped_b = qb.pop_older_than(tick, qa.drop_age)
        assert float(d[0]) == pytest.approx(dropped_b, abs=1e-6)
        served_a += float(d[0])
        served_b += dropped_b
    assert served_a == pytest.approx(served_b, abs=1e-6)
    assert late_a == pytest.approx(late_b, abs=1e-6)
    assert float(qa.totals()[0]) == pytest.approx(qb.total, abs=1e-6)
    assert float(qa.totals()[1]) == 0.0


# ---------------------------------------------------------------------------
# LoadMonitor.
# ---------------------------------------------------------------------------
@given(st.lists(st.floats(0.1, 1000.0), min_size=1, max_size=200))
@settings(max_examples=100, deadline=None)
def test_monitor_peak_bounds_median(rates):
    m = LoadMonitor(window_s=50)
    for r in rates:
        m.observe(r)
    assert m.peak >= m.median > 0
    assert m.peak_to_median >= 1.0


# ---------------------------------------------------------------------------
# Blocked sliding-window attention (XLA §Perf path).
# ---------------------------------------------------------------------------
@given(
    s=st.integers(20, 120),
    window=st.sampled_from([4, 8, 16]),
    nq=st.sampled_from([2, 4]),
    group=st.sampled_from([1, 2]),
)
@settings(max_examples=12, deadline=None)
def test_blocked_window_equals_masked_oracle(s, window, nq, group):
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    from repro.kernels import ref

    nkv = max(1, nq // group)
    hd = 16
    key = jax.random.fold_in(jax.random.key(0), s * 131 + window * 7 + nq)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, s, nq, hd))
    k = jax.random.normal(ks[1], (1, s, nkv, hd))
    v = jax.random.normal(ks[2], (1, s, nkv, hd))
    got = ref.local_attention_blocked(q, k, v, window=window)
    exp = ref.mha_reference(q, k, v, causal=True, window=window)
    assert float(jnp.max(jnp.abs(got - exp))) < 1e-5


# ---------------------------------------------------------------------------
# Selection properties.
# ---------------------------------------------------------------------------
@given(
    acc=st.floats(0.0, 0.9),
    lat=st.floats(0.05, 3.0),
)
@settings(max_examples=100, deadline=None)
def test_paragon_never_costlier_than_naive(acc, lat):
    c = Constraint(min_accuracy=acc, max_latency_s=lat)
    pool = model_pool()
    try:
        n = select_naive(c)
    except NoFeasibleModel:
        return
    try:
        p = select_paragon(c)
    except NoFeasibleModel:
        return
    assert pool[p]["cost_per_1k"] <= pool[n]["cost_per_1k"] + 1e-12


@given(acc=st.floats(0.0, 0.87), lat=st.floats(0.05, 3.0))
@settings(max_examples=100, deadline=None)
def test_paragon_meets_both_constraints(acc, lat):
    c = Constraint(min_accuracy=acc, max_latency_s=lat)
    if not feasible_set(c):
        return
    pool = model_pool()
    p = select_paragon(c)
    assert pool[p]["accuracy"] >= acc
    assert pool[p]["latency_s"] <= lat
