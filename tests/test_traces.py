"""Trace twins: Fig-7 regimes, normalization, determinism."""
import numpy as np
import pytest

from repro.core.traces import TRACES, get_trace, peak_to_median, trace_stats


@pytest.mark.parametrize("name", sorted(TRACES))
def test_mean_normalized(name):
    r = get_trace(name, 3600, mean_rps=123.0)
    assert abs(r.mean() - 123.0) < 1e-6
    assert (r >= 0).all()


@pytest.mark.parametrize("name", sorted(TRACES))
def test_deterministic(name):
    a = get_trace(name, 600, seed=3)
    b = get_trace(name, 600, seed=3)
    assert np.array_equal(a, b)
    c = get_trace(name, 600, seed=4)
    assert not np.array_equal(a, c)


def test_fig7_regimes():
    """Wiki ~1.3-1.5 (mixed will not pay off); others clearly > 2."""
    stats = trace_stats()
    assert stats["wiki"]["peak_to_median"] < 1.6
    for name in ("berkeley", "wits", "twitter"):
        assert stats[name]["peak_to_median"] > 2.0, name


def test_peak_to_median_function():
    flat = np.ones(100)
    assert peak_to_median(flat) == pytest.approx(1.0)
    spiky = np.ones(100)
    spiky[:2] = 100.0
    assert peak_to_median(spiky) > 2.0
