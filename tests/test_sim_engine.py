"""The refactored sim package: golden equivalence against the seed
per-arch loop, conservation on the vectorized queues, tier mechanics,
and the vectorized policy interface."""
import numpy as np
import pytest

from repro.core.hardware import PRICING
from repro.core.schedulers import SCHEDULERS, VECTOR_SCHEDULERS
from repro.core.sim import (
    Action,
    ArchLoad,
    PoolAction,
    ProvisionPipeline,
    QueueArray,
    ServingSim,
    simulate,
    simulate_reference,
    replicate_pool,
    uniform_pool_workload,
)
from repro.core.traces import get_trace

SEED_ARCHS = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]


def _summaries_close(a: dict, b: dict, tol=1e-6):
    for k in a:
        assert abs(a[k] - b[k]) <= tol * max(1.0, abs(a[k])), (
            f"{k}: reference={a[k]} engine={b[k]}"
        )


# ---------------------------------------------------------------------------
# Golden equivalence: the vectorized engine reproduces the seed loop.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "policy", ["reactive", "util_aware", "exascale", "mixed", "paragon"]
)
def test_golden_equivalence_4arch(policy):
    """On the 4-arch seed workload the engine must reproduce the seed
    simulator's SimResult.summary() (spot policies excluded: the engine
    draws reclaims vectorized, so the RNG streams differ by design)."""
    trace = get_trace("berkeley", 400, mean_rps=120)
    wl = uniform_pool_workload(SEED_ARCHS, strict_frac=0.25)
    ref = simulate_reference(trace, wl, SCHEDULERS[policy]())
    got = simulate(trace, wl, SCHEDULERS[policy]())
    _summaries_close(ref.summary(), got.summary())


def test_golden_equivalence_premium_pricing_and_trace():
    import dataclasses

    pricing = dataclasses.replace(PRICING, burst_premium=8.0)
    trace = get_trace("twitter", 600, mean_rps=80)
    wl = uniform_pool_workload(SEED_ARCHS, strict_frac=0.5)
    ref = simulate_reference(trace, wl, SCHEDULERS["mixed"](), pricing=pricing)
    got = simulate(trace, wl, SCHEDULERS["mixed"](), pricing=pricing)
    _summaries_close(ref.summary(), got.summary())


def test_golden_equivalence_stepwise_default_action():
    """Missing per-arch actions default to 'hold the active fleet' in
    both implementations."""
    from repro.core.sim import ReferenceSim

    trace = get_trace("wiki", 120, mean_rps=30)
    wl = [ArchLoad("qwen1.5-0.5b", 1.0, 0.5), ArchLoad("minicpm-2b", 0.0, 0.5)]
    ref, new = ReferenceSim(trace, wl), ServingSim(trace, wl)
    while not new.done:
        ref.observe()
        new.observe()
        acts = {"qwen1.5-0.5b": Action(target=2, offload="blind")}
        m_ref = ref.apply(acts)
        m_new = new.apply(acts)
        assert m_new["cost"] == pytest.approx(m_ref["cost"], abs=1e-9)
        assert m_new["violations"] == pytest.approx(m_ref["violations"], abs=1e-9)
    _summaries_close(ref.res.summary(), new.res.summary())


@pytest.mark.parametrize("seed", range(6))
def test_golden_equivalence_adversarial_actions(seed):
    """Differential fuzz: random procurement/offload actions under edge
    pricing (short pipelines, tiny burst idle timeout) must keep engine
    and reference in lockstep — guards the burst warm/cold state against
    float residue in the vectorized queues."""
    import dataclasses

    from repro.core.sim import ReferenceSim

    pricing = dataclasses.replace(
        PRICING, reserved_provision_s=7, spot_provision_s=3,
        burst_idle_timeout_s=5,
    )
    rng = np.random.default_rng(seed)
    trace = get_trace("berkeley", 120, mean_rps=25, seed=seed)
    wl = [ArchLoad("llama3-8b", 0.6, 0.3), ArchLoad("minicpm-2b", 0.4, 0.7)]
    new = ServingSim(trace, wl, pricing=pricing, prewarm=False)
    ref = ReferenceSim(trace, wl, pricing=pricing, prewarm=False)
    while not new.done:
        new.observe()
        ref.observe()
        acts = {
            w.arch: Action(
                target=int(rng.integers(0, 4)),
                offload=["none", "blind", "slack_aware"][rng.integers(0, 3)],
            )
            for w in wl
        }
        m_new, m_ref = new.apply(acts), ref.apply(acts)
        assert m_new["violations"] == pytest.approx(
            m_ref["violations"], abs=1e-6
        ), f"tick {ref.tick}"
    _summaries_close(ref.res.summary(), new.res.summary())


# ---------------------------------------------------------------------------
# Conservation on the vectorized queues.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["reactive", "mixed", "paragon"])
def test_engine_conservation_every_tick(policy):
    """admitted == served_vm + served_burst + still-queued, every tick."""
    trace = get_trace("berkeley", 300, mean_rps=90)
    wl = uniform_pool_workload(SEED_ARCHS, strict_frac=0.25)
    sim = ServingSim(trace, wl)
    pol = SCHEDULERS[policy]()
    while not sim.done:
        obs = sim.observe()
        sim.apply(pol(sim.tick, obs))
        queued = float(sim.q_strict.totals().sum() + sim.q_relaxed.totals().sum())
        res = sim.res
        assert res.total_requests == pytest.approx(
            res.served_vm + res.served_burst + queued, abs=1e-6
        )
        assert queued >= -1e-9


def test_queue_array_tracked_totals_match_buffer():
    rng = np.random.default_rng(3)
    q = QueueArray(3, slo_s=2.0, slack=np.array([0, 1, 2]))
    for tick in range(50):
        q.push(tick, rng.uniform(0, 5, size=3))
        q.serve(tick, rng.uniform(0, 4, size=3))
        if tick % 7 == 0:
            q.drain(np.array([False, True, False]))
        q.drop_expired(tick)
        np.testing.assert_allclose(q.totals(), q.buf.sum(axis=1), atol=1e-9)
    assert (q.totals() >= -1e-9).all()


# ---------------------------------------------------------------------------
# Fleet tier mechanics.
# ---------------------------------------------------------------------------
def test_pipeline_fixed_latency():
    p = ProvisionPipeline(2, latency_s=3.0)
    p.launch(0, np.array([2, 0]))
    assert (p.pop_ready(1) == 0).all()
    assert (p.pop_ready(2) == 0).all()
    np.testing.assert_array_equal(p.pop_ready(3), [2, 0])
    assert (p.total == 0).all()


def test_pipeline_cancel_newest_first():
    p = ProvisionPipeline(1, latency_s=5.0)
    p.launch(0, np.array([2]))      # ready at 5
    p.launch(2, np.array([3]))      # ready at 7
    p.cancel_newest(2, np.array([3]))   # kills the tick-2 batch only
    np.testing.assert_array_equal(p.pop_ready(5), [2])
    assert (p.pop_ready(7) == 0).all()


def test_spot_unused_costs_nothing():
    trace = get_trace("berkeley", 200, mean_rps=60)
    wl = uniform_pool_workload(SEED_ARCHS[:2], strict_frac=0.25)
    res = simulate(trace, wl, SCHEDULERS["paragon"]())
    assert res.cost_spot == 0.0 and res.preemptions == 0


# ---------------------------------------------------------------------------
# Vectorized policy interface.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "policy", sorted(set(VECTOR_SCHEDULERS) & set(SCHEDULERS))
)
def test_vector_policy_matches_dict_policy(policy):
    trace = get_trace("berkeley", 400, mean_rps=90)
    wl = uniform_pool_workload(SEED_ARCHS, strict_frac=0.25)
    d = simulate(trace, wl, SCHEDULERS[policy]()).summary()
    v = simulate(trace, wl, VECTOR_SCHEDULERS[policy]()).summary()
    assert d == v


def test_replicated_pool_keys_and_scaling():
    """replicate_pool gives unique keys; a 16-way replicated pool sees
    the same total demand as the 4-arch pool it cycles."""
    wl = replicate_pool(SEED_ARCHS, 16, strict_frac=0.25)
    assert len({w.key for w in wl}) == 16
    assert sum(w.share for w in wl) == pytest.approx(1.0)
    trace = get_trace("wiki", 200, mean_rps=80)
    res = simulate(trace, wl, VECTOR_SCHEDULERS["paragon"]())
    assert res.total_requests == pytest.approx(float(trace.sum()))
    assert res.violation_rate < 0.5


def test_pool_action_defaults():
    a = PoolAction(target=np.array([1, 2]))
    assert (a.offload_codes(2) == 0).all()
    assert (a.spot_targets(2) == 0).all()


def test_pool_obs_aliasing_contract_and_copy():
    """``observe_pool`` refills engine-owned buffers in place: a retained
    PoolObs silently aliases the next tick's values, while ``copy()``
    snapshots.  This pins the documented aliasing contract so a future
    'defensive copy' refactor (or an accidental buffer re-allocation)
    shows up as a test diff, not a performance surprise."""
    trace = get_trace("berkeley", 50, mean_rps=200)
    wl = uniform_pool_workload(SEED_ARCHS, strict_frac=0.25)
    sim = ServingSim(trace, wl)
    pol = VECTOR_SCHEDULERS["reactive"]()

    obs0 = sim.observe_pool()
    snap = obs0.copy()
    np.testing.assert_array_equal(snap.rate, obs0.rate)
    assert snap.rate is not obs0.rate           # independent storage
    assert snap.keys is not obs0.keys and snap.keys == list(obs0.keys)

    stale = obs0
    sim.apply_pool(pol(sim.tick, obs0))
    obs1 = sim.observe_pool()
    # same persistent buffers: the stale handle IS the new observation
    for field in ("rate", "queue_len", "n_active", "throughput"):
        assert getattr(obs1, field) is getattr(stale, field), field
    np.testing.assert_array_equal(stale.rate, obs1.rate)

    # ... while the snapshot keeps tick-0 values; step until the stream
    # actually moves (berkeley is bursty, so this exits immediately in
    # practice — the loop just de-flakes a constant-rate tick pair)
    moved = not np.array_equal(snap.rate, obs1.rate)
    while not moved and not sim.done:
        sim.apply_pool(pol(sim.tick, obs1))
        obs1 = sim.observe_pool()
        moved = not np.array_equal(snap.rate, obs1.rate)
    assert moved, "trace never moved; aliasing divergence unobservable"
