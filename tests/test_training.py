"""Training substrate: loss decreases, schedules, optimizer, checkpoint."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import (
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import get_config
from repro.training.data import SyntheticLM
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update
from repro.training.schedule import ScheduleConfig, make_schedule
from repro.training.train_loop import TrainConfig, train


def test_loss_decreases_quickly():
    cfg = get_config("qwen1.5-0.5b").reduced()
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3),
                       schedule=ScheduleConfig(kind="constant", peak_lr=1e-3,
                                               warmup_steps=2, total_steps=25))
    data = SyntheticLM(cfg.vocab_size, 32, 4, seed=0)
    _, _, hist = train(cfg, tcfg, iter(data), 25, log_every=5)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, hist


def test_moe_training_with_aux_loss():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    tcfg = TrainConfig(optimizer=OptimizerConfig(lr=1e-3),
                       schedule=ScheduleConfig(kind="constant", peak_lr=1e-3,
                                               warmup_steps=2, total_steps=10))
    data = SyntheticLM(cfg.vocab_size, 16, 2, seed=1)
    _, _, hist = train(cfg, tcfg, iter(data), 10, log_every=3)
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert hist[-1]["ce"] < hist[0]["ce"]


def test_wsd_schedule_shape():
    s = make_schedule(ScheduleConfig(kind="wsd", peak_lr=1.0, warmup_steps=10,
                                     total_steps=100, decay_start_frac=0.8,
                                     min_lr_frac=0.1))
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(50)) == pytest.approx(1.0)          # stable phase
    assert float(s(79)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.1, rel=1e-2)  # decayed
    mid = float(s(90))
    assert 0.1 < mid < 1.0


def test_cosine_linear_schedules():
    for kind in ("cosine", "linear"):
        s = make_schedule(ScheduleConfig(kind=kind, peak_lr=2.0, warmup_steps=5,
                                         total_steps=50, min_lr_frac=0.1))
        assert float(s(5)) == pytest.approx(2.0)
        assert float(s(50)) == pytest.approx(0.2, rel=1e-2)


def test_adamw_bf16_states():
    params = {"w": jnp.ones((4, 4))}
    ocfg = OptimizerConfig(state_dtype=jnp.bfloat16)
    opt = adamw_init(params, ocfg)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.full((4, 4), 0.1)}
    new_p, new_opt, _ = adamw_update(params, grads, opt, ocfg)
    assert new_opt["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(new_p["w"] < params["w"]))


def test_grad_clip():
    params = {"w": jnp.ones((2,))}
    ocfg = OptimizerConfig(grad_clip=1.0, lr=1.0, weight_decay=0.0)
    opt = adamw_init(params, ocfg)
    big = {"w": jnp.full((2,), 1e6)}
    _, _, m = adamw_update(params, big, opt, ocfg)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip


def test_synthetic_data_learnable_structure():
    data = SyntheticLM(1000, 64, 4, seed=0)
    batch = next(iter(data))
    assert batch["inputs"].shape == (4, 64)
    assert batch["labels"].shape == (4, 64)
    # bigram structure: successor (t*7+3)%support appears often
    x, y = batch["inputs"].ravel(), batch["labels"].ravel()
    hits = np.mean(y == (x * 7 + 3) % 1000)
    assert hits > 0.4


def test_checkpoint_roundtrip():
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jnp.ones((4,), jnp.bfloat16), "step": jnp.int32(7)},
    }
    with tempfile.TemporaryDirectory() as d:
        path = save_checkpoint(d, 3, tree)
        assert os.path.exists(path)
        assert latest_step(d) == 3
        restored = restore_checkpoint(d, 3, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert a.dtype == b.dtype
            assert np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
