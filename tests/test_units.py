"""Small-unit coverage: load monitor, ops dispatch, pricing, frontends."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hardware import PRICING, V5E
from repro.core.load_monitor import LoadMonitor
from repro.kernels import ops, ref
from repro.models import frontends
from repro.configs import get_config


# ---------------------------------------------------------------------------
# LoadMonitor.
# ---------------------------------------------------------------------------
def test_monitor_flat_stream_not_bursty():
    m = LoadMonitor(window_s=50)
    for _ in range(100):
        m.observe(10.0)
    assert m.peak_to_median == pytest.approx(1.0)
    assert not m.bursty()
    assert m.rate == pytest.approx(10.0)


def test_monitor_spike_detected():
    m = LoadMonitor(window_s=100)
    for _ in range(80):
        m.observe(10.0)
    for _ in range(5):
        m.observe(50.0)
    assert m.peak_to_median > 1.5
    assert m.bursty()


def test_monitor_window_slides():
    m = LoadMonitor(window_s=10)
    for _ in range(20):
        m.observe(100.0)
    for _ in range(10):
        m.observe(1.0)
    # the spike has left the window entirely
    assert m.peak == pytest.approx(1.0)


# (test_monitor_peak_bounds_median moved to test_properties.py)


# ---------------------------------------------------------------------------
# ops dispatch.
# ---------------------------------------------------------------------------
def test_default_impl_switch():
    assert ops.default_impl() == "xla"
    ops.set_default_impl("pallas_interpret")
    try:
        assert ops.default_impl() == "pallas_interpret"
        q = jax.random.normal(jax.random.key(0), (1, 32, 2, 16))
        out = ops.flash_attention(q, q, q, causal=True)   # kernel path
        exp = ref.mha_reference(q, q, q, causal=True)
        assert float(jnp.max(jnp.abs(out - exp))) < 1e-4
    finally:
        ops.set_default_impl("xla")


def test_invalid_impl_rejected():
    with pytest.raises(AssertionError):
        ops.set_default_impl("cuda")


def test_blocked_dispatch_only_when_profitable():
    """window >= S/2 (not profitable) must use the plain masked path and
    still be exact."""
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    got = ops.flash_attention(q, k, v, causal=True, window=24)
    exp = ref.mha_reference(q, k, v, causal=True, window=24)
    assert float(jnp.max(jnp.abs(got - exp))) < 1e-5


# ---------------------------------------------------------------------------
# Pricing sanity.
# ---------------------------------------------------------------------------
def test_pricing_relationships():
    assert PRICING.burst_chip_s > PRICING.reserved_chip_s
    assert PRICING.spot_discount < 1.0
    assert PRICING.burst_spinup_s < PRICING.reserved_provision_s
    assert V5E.peak_flops_bf16 / V5E.hbm_bandwidth > 100  # ops:byte ridge


# ---------------------------------------------------------------------------
# Frontends.
# ---------------------------------------------------------------------------
def test_vision_embeddings_deterministic_and_scaled():
    cfg = get_config("llava-next-mistral-7b").reduced()
    a = frontends.vision_embeddings(cfg, 2, tiles=2, seed=5)
    b = frontends.vision_embeddings(cfg, 2, tiles=2, seed=5)
    assert np.array_equal(a, b)
    assert a.shape == (2, 2 * frontends.VLM_BASE_PATCHES, cfg.d_model)
    # unit-RMS rows
    rms = np.sqrt((a ** 2).sum(-1).mean())
    assert 0.8 < rms < 1.2


def test_frontend_type_guards():
    lm = get_config("llama3-8b").reduced()
    with pytest.raises(AssertionError):
        frontends.vision_embeddings(lm, 1)
    with pytest.raises(AssertionError):
        frontends.audio_frames(lm, 1)
