"""The model-variant axis: VariantCatalog construction + the shared
candidate filter, SwapPipeline latency semantics, engine accuracy/flow
conservation with swaps in flight, hold-is-bit-identical, parity of the
variant-aware vectorized schedulers against their dict forms, and the
RL variant head."""
import dataclasses

import numpy as np
import pytest

from repro.core.model_selection import Constraint, feasible_set, select_paragon
from repro.core.profiles import model_pool
from repro.core.schedulers import SCHEDULERS, VECTOR_SCHEDULERS
from repro.core.sim import (
    STRICT,
    Action,
    PoolAction,
    ServingSim,
    SwapPipeline,
    VariantCatalog,
    filter_pool_candidates,
    simulate,
    uniform_pool_workload,
)
from repro.core.workloads import get_scenario

POOL = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]
FLOOR = 0.5


def _workload(floor=FLOOR, pool=POOL):
    wl = uniform_pool_workload(pool, strict_frac=0.25)
    return [dataclasses.replace(w, min_accuracy=floor) for w in wl]


@pytest.fixture(scope="module")
def catalog():
    return VariantCatalog.for_workload(_workload())


# ---------------------------------------------------------------------------
# Catalog construction + the shared candidate filter (dedup with the
# offline selector).
# ---------------------------------------------------------------------------
def test_catalog_ordered_and_base_is_identity(catalog):
    for arch in POOL:
        vs = catalog.variants(arch)
        accs = [v.accuracy for v in vs]
        assert accs == sorted(accs)
        b = catalog.base_idx[arch]
        assert vs[b].arch == arch
        assert vs[b].service_mult == 1.0 and vs[b].cost_mult == 1.0
        # default candidates = the workload's archs (the deployable pool)
        assert {v.arch for v in vs} <= set(POOL)


def test_catalog_floor_indices(catalog):
    pool = model_pool(STRICT)
    for arch in POOL:
        vs = catalog.variants(arch)
        lo, cheapest = catalog.floor_indices(arch, FLOOR)
        assert vs[lo].accuracy >= FLOOR
        assert lo == min(i for i, v in enumerate(vs) if v.accuracy >= FLOOR)
        ok = [i for i, v in enumerate(vs) if v.accuracy >= FLOOR]
        assert cheapest == min(ok, key=lambda i: vs[i].cost_per_1k)
        # the Fig-2 numbers are the single source of truth
        assert vs[cheapest].cost_per_1k == pool[vs[cheapest].arch]["cost_per_1k"]
    # impossible floor falls back to the most accurate variant
    lo, cheapest = catalog.floor_indices(POOL[0], 2.0)
    assert lo == cheapest == catalog.n_variants(POOL[0]) - 1


def test_selector_and_catalog_share_the_filter():
    """The offline selector's feasible set and the catalog's variant set
    come from the same predicate: Paragon's least-cost pick for a
    constraint equals the catalog's cheapest floor-satisfying variant."""
    c = Constraint(min_accuracy=FLOOR, max_latency_s=STRICT.slo_s)
    fs = feasible_set(c, STRICT)
    assert fs == filter_pool_candidates(
        model_pool(STRICT), min_accuracy=FLOOR, max_latency_s=STRICT.slo_s
    )
    ct = VariantCatalog.from_pool(model_pool(STRICT))   # full-pool candidates
    arch = "llama3-8b"
    _, cheapest = ct.floor_indices(arch, FLOOR)
    assert ct.variants(arch)[cheapest].arch == select_paragon(c, STRICT)


# ---------------------------------------------------------------------------
# SwapPipeline latency semantics.
# ---------------------------------------------------------------------------
def test_swap_pipeline_fixed_latency():
    sp = SwapPipeline(np.array([0, 2]), latency_s=3.0)
    sp.request(0, np.array([1, -1]))              # arch 0: 0 -> 1 at tick 3
    np.testing.assert_array_equal(sp.current, [0, 2])   # old until ready
    assert not sp.pop_ready(1).any()
    assert not sp.pop_ready(2).any()
    done = sp.pop_ready(3)
    np.testing.assert_array_equal(done, [True, False])
    np.testing.assert_array_equal(sp.current, [1, 2])
    assert sp.completed == 1
    assert not sp.in_flight.any()


def test_swap_pipeline_cancel_newest_first():
    sp = SwapPipeline(np.array([0]), latency_s=5.0)
    sp.request(0, np.array([2]))                  # ready at 5
    sp.request(2, np.array([3]))                  # replaces: ready at 7
    assert not sp.pop_ready(5).any()              # the tick-0 swap was
    assert sp.in_flight.all()                     # cancelled, not landed
    assert sp.pop_ready(7).all()
    assert sp.current[0] == 3
    # re-requesting the in-flight target must NOT restart the clock
    sp.request(8, np.array([1]))
    sp.request(10, np.array([1]))
    assert sp.pop_ready(13).all()                 # 8 + 5, not 10 + 5
    # re-requesting the current variant cancels outright
    sp.request(14, np.array([0]))
    sp.request(15, np.array([1]))
    assert not sp.in_flight.any()
    assert not sp.pop_ready(30).any()
    assert sp.current[0] == 1


# ---------------------------------------------------------------------------
# Engine: hold is bit-identical; serving rate follows the swap latency.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["reactive", "paragon", "mixed"])
def test_hold_bit_identical_to_no_catalog(policy, catalog):
    """With every variant_target held, a catalog-enabled run must equal
    the catalog-free run on every summary key (money, violations, AND
    accuracy — the base variant is the arch itself)."""
    arr = get_scenario("flash_anti").build(len(POOL), duration_s=300,
                                           mean_rps=80)
    wl = _workload(floor=0.0)
    a = simulate(arr, wl, VECTOR_SCHEDULERS[policy]()).summary()
    b = simulate(arr, wl, VECTOR_SCHEDULERS[policy](), catalog=catalog).summary()
    assert a == b


def test_variant_aware_policies_hold_on_degenerate_catalog():
    """On the default single-variant world the two variant-aware
    schedulers degrade to exactly Paragon."""
    arr = get_scenario("mmpp_bursts").build(len(POOL), duration_s=240,
                                            mean_rps=60)
    wl = _workload(floor=0.0)
    p = simulate(arr, wl, VECTOR_SCHEDULERS["paragon"]()).summary()
    for name in ("infaas_variant", "accuracy_floor"):
        assert simulate(arr, wl, VECTOR_SCHEDULERS[name]()).summary() == p


def test_swap_serves_at_old_rate_until_latency_elapses(catalog):
    """A requested swap changes PoolObs.throughput/active_variant only
    after pricing.variant_swap_s ticks; cost follows the old footprint
    meanwhile."""
    wl = _workload()
    arr = np.full((len(POOL), 240), 10.0)
    sim = ServingSim(arr, wl, catalog=catalog)
    lat = sim.pricing.variant_swap_s
    base = sim.swap.current.copy()
    target = np.where(base + 1 < sim.var_n, base + 1, base - 1).astype(np.int64)
    obs0 = sim.observe_pool()
    thr0 = obs0.throughput.copy()
    sim.apply_pool(PoolAction(
        target=np.ones(len(POOL), dtype=np.int64),
        variant_target=target,
    ))
    hold = PoolAction(target=np.ones(len(POOL), dtype=np.int64))
    # the swap lands inside the _step of tick (request + lat): every
    # observation up to and including that tick still shows the OLD
    # variant and rate — the reload has not finished when serving starts
    for _ in range(int(lat)):
        obs = sim.observe_pool()
        np.testing.assert_array_equal(obs.active_variant, base)
        np.testing.assert_array_equal(obs.throughput, thr0)   # old rate
        assert obs.variant_in_flight.all()
        sim.apply_pool(hold)
    obs = sim.observe_pool()                       # swap landed
    np.testing.assert_array_equal(obs.active_variant, target)
    assert (obs.throughput != thr0).any()
    assert not obs.variant_in_flight.any()
    assert sim.res.variant_swaps == len(POOL)


# ---------------------------------------------------------------------------
# Accuracy + flow conservation with swaps in flight.
# ---------------------------------------------------------------------------
def test_accuracy_and_flow_conservation_under_random_swaps(catalog):
    """Per tick: the accuracy marginal equals answered x the active
    variant's accuracy per arch, sums match the ledger, and the per-arch
    flow identity holds throughout a run with random swaps in flight."""
    wl = _workload()
    arr = get_scenario("mmpp_bursts").build(len(POOL), duration_s=300,
                                            mean_rps=80, seed=7)
    sim = ServingSim(arr, wl, catalog=catalog)
    rng = np.random.default_rng(0)
    n = len(POOL)
    prev = {k: v.copy() for k, v in sim.per_arch_counts().items()}
    while not sim.done:
        sim.observe_pool()
        m = sim.apply_pool(PoolAction(
            target=rng.integers(1, 5, size=n),
            offload=rng.integers(0, 3, size=n),
            variant_target=rng.integers(-1, sim.var_n, size=n),
        ))
        counts = sim.per_arch_counts()
        answered_d = (
            counts["served_vm"] - prev["served_vm"]
            + counts["served_burst"] - prev["served_burst"]
            + counts["dropped"] - prev["dropped"]
        )
        # the tick's accuracy marginal is answered x active accuracy
        np.testing.assert_allclose(
            m["accuracy_arch"], answered_d * sim.cur_acc, atol=1e-9
        )
        assert m["accuracy"] == pytest.approx(float(m["accuracy_arch"].sum()))
        assert m["acc_violations"] == pytest.approx(
            float(m["acc_violations_arch"].sum())
        )
        # flow conservation per arch, every tick, swaps in flight or not
        accounted = (
            counts["served_vm"] + counts["served_burst"] + counts["dropped"]
            + counts["expired_end"] + counts["queued"]
        )
        np.testing.assert_allclose(counts["arrived"], accounted, atol=1e-6)
        prev = {k: v.copy() for k, v in counts.items()}
    res = sim.res
    counts = sim.per_arch_counts()
    # cumulative per-arch weights sum to the ledger totals
    assert float(counts["acc_weight"].sum()) == pytest.approx(
        res.accuracy_weighted
    )
    assert float(counts["acc_violations"].sum()) == pytest.approx(
        res.acc_violations
    )
    answered = counts["served_vm"] + counts["served_burst"] + counts["dropped"]
    assert res.accuracy_served == pytest.approx(float(answered.sum()))
    # delivered accuracy is a convex combination of catalog accuracies
    assert sim.var_acc.min() - 1e-9 <= res.mean_accuracy <= sim.var_acc.max() + 1e-9


def test_accuracy_floor_violations_counted():
    """An impossible floor books every answered request as an accuracy
    violation; a trivially met floor books none."""
    arr = np.full((len(POOL), 60), 5.0)
    hi = simulate(arr, _workload(floor=0.99), SCHEDULERS["paragon"]())
    assert hi.acc_violations == pytest.approx(hi.accuracy_served)
    lo = simulate(arr, _workload(floor=0.0), SCHEDULERS["paragon"]())
    assert lo.acc_violations == 0.0


# ---------------------------------------------------------------------------
# Dict/vector parity of the variant-aware schedulers on a live catalog.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["infaas_variant", "accuracy_floor"])
def test_variant_scheduler_dict_vector_parity(policy, catalog):
    wl = _workload()
    arr = get_scenario("flash_correlated").build(len(POOL), duration_s=400,
                                                 mean_rps=120)
    d = simulate(arr, wl, SCHEDULERS[policy](), catalog=catalog).summary()
    v = simulate(arr, wl, VECTOR_SCHEDULERS[policy](), catalog=catalog).summary()
    assert d == v
    assert d["variant_swaps"] > 0       # the parity run actually swapped


def test_accuracy_floor_meets_floor_and_undercuts_reactive():
    """The bench claim at test scale: cheapest-meeting-floor variants
    beat the fixed-variant reactive baseline on cost at better delivered
    accuracy, with fewer accuracy violations.  Needs the 8-arch serving
    pool — dominance comes from its dominated members (e.g. the cheap
    accurate MoE undercutting llama; recurrentgemma undercutting
    minicpm), which the 4-arch seed pool lacks."""
    pool8 = POOL + ["whisper-small", "llava-next-mistral-7b",
                    "recurrentgemma-9b", "phi3.5-moe-42b-a6.6b"]
    wl = _workload(floor=0.55, pool=pool8)
    ct = VariantCatalog.for_workload(wl)
    arr = get_scenario("flash_anti").build(len(pool8), duration_s=500,
                                           mean_rps=400)
    fixed = simulate(arr, wl, VECTOR_SCHEDULERS["reactive"](), catalog=ct)
    floor = simulate(arr, wl, VECTOR_SCHEDULERS["accuracy_floor"](),
                     catalog=ct)
    assert floor.cost_total < fixed.cost_total
    assert floor.mean_accuracy >= fixed.mean_accuracy - 1e-9
    assert floor.acc_violation_rate < fixed.acc_violation_rate


# ---------------------------------------------------------------------------
# Dict-form Action plumbing.
# ---------------------------------------------------------------------------
def test_dict_action_variant_field(catalog):
    wl = _workload()
    arr = np.full((len(POOL), 130), 8.0)
    sim = ServingSim(arr, wl, catalog=catalog)
    i = int(np.argmin(sim.swap.current))    # an arch with an upgrade left
    key = wl[i].key
    up = int(sim.swap.current[i]) + 1
    assert up < sim.var_n[i]
    sim.observe()
    sim.apply({key: Action(target=1, variant=up)})
    assert sim.swap.in_flight[i]
    assert sim.swap.in_flight.sum() == 1
    while not sim.done:
        sim.observe()
        sim.apply({})
    assert sim.swap.current[i] == up
    assert sim.res.variant_swaps == 1


# ---------------------------------------------------------------------------
# RL: the variant head.
# ---------------------------------------------------------------------------
def test_procurement_action_variant_head(catalog):
    from repro.core.rl import (
        N_PROCURE,
        N_ACTIONS,
        SPOT_MOVES,
        VARIANT_MOVES,
        procurement_action,
    )

    wl = _workload()
    arr = np.full((len(POOL), 10), 5.0)
    sim = ServingSim(arr, wl, catalog=catalog)
    obs = sim.observe_pool()
    n = len(POOL)
    # hold-first: every legacy action index decodes to variant hold
    for a in range(N_PROCURE):
        act = procurement_action(obs, np.full(n, a))
        assert (act.variant_target == -1).all()
    assert N_ACTIONS == len(SPOT_MOVES) * len(VARIANT_MOVES) * N_PROCURE
    # down / up step from the base index, clipped to the variant range
    down = procurement_action(obs, np.full(n, N_PROCURE))
    up = procurement_action(obs, np.full(n, 2 * N_PROCURE))
    base = sim.swap.current
    exp_down = np.where(base > 0, base - 1, -1)
    exp_up = np.where(base < sim.var_n - 1, base + 1, -1)
    np.testing.assert_array_equal(down.variant_target, exp_down)
    np.testing.assert_array_equal(up.variant_target, exp_up)


def test_pool_env_variant_features_and_reward(catalog):
    from repro.core.rl import EnvConfig, N_PROCURE, OBS_DIM, PoolServingEnv

    wl = _workload()
    cfg = EnvConfig(mean_rps=40, duration_s=80, accuracy_bonus=0.001)
    env = PoolServingEnv(wl, cfg, scenarios=[get_scenario("mmpp_bursts")],
                         catalog=catalog)
    obs = env.reset()
    assert obs.shape == (len(POOL), OBS_DIM)
    base = env.sim.swap.current
    np.testing.assert_allclose(
        obs[:, 10], base / np.maximum(env.sim.var_n - 1, 1), atol=1e-6
    )
    # accuracy headroom over the 0.5 floor
    np.testing.assert_allclose(
        obs[:, 11], np.clip(env.sim.cur_acc - FLOOR, 0, 1), atol=1e-6
    )
    # reward blends the accuracy bonus against cost/violations
    rng = np.random.default_rng(1)
    done = False
    while not done:
        a = rng.integers(0, 3 * N_PROCURE, size=len(POOL))
        _, r_arch, done, m = env.step(a)
        expected = -cfg.reward_scale * (
            m["cost_arch"]
            + cfg.violation_penalty * m["violations_arch"]
            - cfg.accuracy_bonus * m["accuracy_arch"]
        )
        np.testing.assert_allclose(r_arch, expected, atol=1e-9)
    assert env.episode_result().variant_swaps >= 0


def test_ppo_trains_variant_head_and_checkpoint_roundtrips(catalog, tmp_path):
    """PPO smoke over the extended (headroom x offload x variant-move)
    action space on a catalog-enabled pool env + round-trip through the
    JSON checkpoint into the deployed scheduler."""
    from repro.core.rl import (
        EnvConfig,
        PPOConfig,
        PoolServingEnv,
        RLPoolPolicy,
        save_policy_params,
        train_ppo_pool,
    )

    wl = _workload()
    cfg = EnvConfig(mean_rps=40, duration_s=60, accuracy_bonus=0.001)
    env = PoolServingEnv(wl, cfg, scenarios=[get_scenario("flash_anti")],
                         catalog=catalog, scenario_seed=4)
    state = train_ppo_pool(env, PPOConfig(iterations=2, rollout_len=60,
                                          hidden=16, seed=2))
    assert len(state.history) == 2
    assert np.isfinite(state.best_reward)
    path = str(tmp_path / "variant_ckpt.json")
    save_policy_params(state.params, path)
    arr = get_scenario("flash_anti").build(len(POOL), duration_s=90,
                                           mean_rps=40)
    a = simulate(arr, wl, RLPoolPolicy(params=state.params, greedy=True),
                 catalog=catalog).summary()
    b = simulate(arr, wl, RLPoolPolicy(checkpoint=path, greedy=True),
                 catalog=catalog).summary()
    assert a == b


def test_stale_checkpoint_falls_back(tmp_path):
    """A checkpoint trained under the pre-variant obs/action space must
    warn and fall back instead of crashing the deployed policy."""
    import json

    from repro.core.rl import RLPoolPolicy
    from repro.core.rl.policy import _fallback_params, params_to_jsonable

    stale = {
        name: {k: np.asarray(v) for k, v in layer.items()}
        for name, layer in _fallback_params(0).items()
    }
    stale["torso1"]["w"] = stale["torso1"]["w"][:10, :]     # old OBS_DIM
    stale["pi"]["w"] = stale["pi"]["w"][:, :12]             # old N_ACTIONS
    stale["pi"]["b"] = stale["pi"]["b"][:12]
    path = str(tmp_path / "stale.json")
    with open(path, "w") as f:
        json.dump({"params": params_to_jsonable(stale), "meta": {}}, f)
    with pytest.warns(UserWarning, match="STALE"):
        pol = RLPoolPolicy(checkpoint=path, seed=3)
    assert not pol.trained
    wl = _workload(floor=0.0)
    arr = np.full((len(POOL), 50), 5.0)
    res = simulate(arr, wl, pol)
    assert res.total_requests > 0
