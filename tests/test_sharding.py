"""Distribution layer: logical-axis rules, divisibility, spec building,
and an end-to-end lower+compile of the sharded steps on a tiny mesh."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.registry import InputShape
from repro.distributed.sharding import AxisRules, axis_rules, logical_to_spec
from repro.launch.mesh import make_rules
from repro.launch.specs import build_step

# AbstractMesh takes (name, size) pairs since jax 0.4.36
PROD_MESH = jax.sharding.AbstractMesh((("data", 16), ("model", 16)))
POD_MESH = jax.sharding.AbstractMesh(
    (("pod", 2), ("data", 16), ("model", 16))
)


def test_logical_to_spec_basic():
    rules = AxisRules(mesh=PROD_MESH, rules={"batch": ("data",), "ff": "model"})
    assert logical_to_spec(("batch", None, "ff"), rules) == P("data", None, "model")


def test_logical_to_spec_consumes_axis_once():
    rules = AxisRules(mesh=PROD_MESH, rules={"a": "model", "b": "model"})
    # the second dimension must NOT reuse the already-consumed mesh axis
    assert logical_to_spec(("a", "b"), rules) == P("model")


def test_rules_divisibility_minicpm():
    """minicpm: 36 heads don't divide 16 -> heads replicated; ff 5760 does."""
    cfg = get_config("minicpm-2b")
    rules = make_rules(cfg, PROD_MESH, "train", batch_size=256).rules
    assert rules["heads"] is None
    assert rules["kv_heads"] is None
    assert rules["ff"] == "model"          # 5760 % 16 == 0
    assert rules["vocab"] is None          # 122753 is odd


def test_rules_divisibility_llama():
    cfg = get_config("llama3-8b")
    rules = make_rules(cfg, PROD_MESH, "train", batch_size=256).rules
    assert rules["heads"] == "model"       # 32 % 16
    assert rules["kv_heads"] is None       # 8 < 16
    assert rules["vocab"] == "model"       # 128256 % 16
    assert rules["batch"] == ("data",)


def test_rules_multipod_batch():
    cfg = get_config("llama3-8b")
    rules = make_rules(cfg, POD_MESH, "train", batch_size=256).rules
    assert rules["batch"] == ("pod", "data")


def test_rules_decode_kv_split():
    cfg = get_config("llama3-8b")
    rules = make_rules(cfg, PROD_MESH, "decode", batch_size=128,
                       cache_len=32768).rules
    assert rules["kv_seq"] == "model"      # flash-decode split-K
    rules2 = make_rules(cfg, PROD_MESH, "prefill", batch_size=32).rules
    assert rules2["kv_seq"] is None


def test_batch_not_divisible_stays_replicated():
    cfg = get_config("llama3-8b")
    rules = make_rules(cfg, PROD_MESH, "decode", batch_size=1, cache_len=4096).rules
    assert rules["batch"] is None          # long_500k batch=1


# ---------------------------------------------------------------------------
# End-to-end: lower + compile the production step builders on a 1x1 mesh
# with REDUCED configs and small shapes (the real 512-device dry-run is
# launch/dryrun.py; this guards the plumbing in CI).
# ---------------------------------------------------------------------------
SMALL_SHAPES = {
    "train": InputShape("train_4k", 64, 4, "train"),
    "prefill": InputShape("prefill_32k", 64, 2, "prefill"),
    "decode": InputShape("decode_32k", 64, 4, "decode"),
    "long": InputShape("long_500k", 256, 1, "decode"),
}


@pytest.mark.parametrize("arch", ["llama3-8b", "rwkv6-1.6b", "phi3.5-moe-42b-a6.6b",
                                  "recurrentgemma-9b", "whisper-small"])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_lower_compile_small_mesh(arch, kind):
    cfg = get_config(arch).reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = SMALL_SHAPES[kind]
    step, args, in_shardings, rules, _donate = build_step(
        cfg, shape, mesh, param_dtype=jnp.float32)
    with mesh, axis_rules(rules):
        compiled = jax.jit(step, in_shardings=in_shardings).lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_lower_long_context_window(arch="llama3-8b"):
    """long_500k on a dense arch must lower through the sliding-window
    variant (ring cache shorter than the sequence)."""
    cfg = get_config(arch).reduced()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    step, args, in_shardings, rules, _donate = build_step(
        cfg, SMALL_SHAPES["long"], mesh, param_dtype=jnp.float32
    )
    # the cache spec must be window-sized, not seq-sized
    cache = args[2]
    k_shapes = [l.shape for l in jax.tree.leaves(cache) if hasattr(l, "shape")]
    assert all(s[2] <= cfg.long_context_window or len(s) < 3 for s in k_shapes if len(s) >= 3)
    with mesh, axis_rules(rules):
        compiled = jax.jit(step, in_shardings=in_shardings).lower(*args).compile()
    assert compiled is not None
