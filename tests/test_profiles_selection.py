"""Profile table + model selection: invariants and paper properties
(deterministic; the hypothesis property tests live in test_properties.py)."""
import pytest

from repro.configs import list_architectures
from repro.core.model_selection import (
    Constraint,
    NoFeasibleModel,
    select_paragon,
)
from repro.core.profiles import (
    STANDARD,
    ModelProfile,
    get_profile,
    iso_accuracy_set,
    iso_latency_set,
    model_pool,
)
from repro.configs import get_config


# ---------------------------------------------------------------------------
# Profile physics.
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", list_architectures())
def test_more_chips_never_slower(arch):
    cfg = get_config(arch)
    base = ModelProfile(cfg, ModelProfile(cfg, 1).min_chips)
    bigger = ModelProfile(cfg, base.chips * 2)
    assert bigger.decode_step_latency(8) <= base.decode_step_latency(8) * 1.05
    assert bigger.prefill_latency(512) <= base.prefill_latency(512) * 1.05


@pytest.mark.parametrize("arch", list_architectures())
def test_bigger_batch_never_faster_per_step(arch):
    prof = get_profile(arch)
    assert prof.decode_step_latency(16) >= prof.decode_step_latency(1) - 1e-12


def test_fig8_knee_exists():
    """The serverless memory knob (Fig 8): latency falls with slice size
    but with diminishing returns; cost per request rises past the knee."""
    prof1 = get_profile("llama3-8b")
    lats, costs = [], []
    for mult in (1, 2, 4, 8):
        p = ModelProfile(prof1.cfg, prof1.chips * mult)
        lats.append(p.request_latency(STANDARD, 1))
        costs.append(p.chips * p.request_latency(STANDARD, 1))
    assert all(a >= b - 1e-9 for a, b in zip(lats, lats[1:])), "latency must fall"
    # diminishing returns: first doubling helps more than the last
    gain_first = lats[0] / lats[1]
    gain_last = lats[2] / lats[3]
    assert gain_first >= gain_last - 1e-9
    # chip-seconds per request (the billable quantity) grows past the knee
    assert costs[-1] > costs[0]


def test_min_chips_fit_hbm():
    for arch in list_architectures():
        prof = get_profile(arch)
        assert prof.weight_bytes * 1.05 < prof.chips * prof.chip.hbm_bytes


def test_attention_free_has_constant_state():
    rwkv = get_profile("rwkv6-1.6b")
    assert rwkv.state_bytes(1_000) == rwkv.state_bytes(500_000)
    llama = get_profile("llama3-8b")
    assert llama.state_bytes(2_000) > llama.state_bytes(1_000)


def test_pool_complete_and_positive():
    pool = model_pool()
    assert set(pool) == set(list_architectures())
    for a, e in pool.items():
        assert e["latency_s"] > 0
        assert e["throughput_rps"] > 0, a
        assert e["cost_per_1k"] > 0
        assert e["burst_cost_per_req"] > e["cost_per_1k"] / 1000.0, (
            f"{a}: burst must cost more per request than reserved")


def test_iso_sets():
    pool = model_pool()
    iso_lat = iso_latency_set(0.5)
    assert all(e["latency_s"] <= 0.5 for e in iso_lat.values())
    iso_acc = iso_accuracy_set(0.6)
    assert all(e["accuracy"] >= 0.6 for e in iso_acc.values())
    assert 0 < len(iso_lat) < len(pool)
    assert 0 < len(iso_acc) < len(pool)


# ---------------------------------------------------------------------------
# Selection properties.
# ---------------------------------------------------------------------------
# (test_paragon_never_costlier_than_naive / test_paragon_meets_both_constraints
# — the hypothesis property tests — moved to test_properties.py)


def test_selection_raises_when_infeasible():
    with pytest.raises(NoFeasibleModel):
        select_paragon(Constraint(min_accuracy=0.99, max_latency_s=0.01))
