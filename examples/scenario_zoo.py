"""Walkthrough: heterogeneous workload scenarios vs two schedulers (CPU).

Renders a few named scenarios from the workload zoo as ASCII spark
lines — per-arch arrival streams that one share-scaled pool trace cannot
express — then runs two procurement schemes on each and compares cost /
violations / per-arch violation spread.  The punchline is the paper's:
which scheme wins depends on the load *shape*, which is why the serving
system has to watch the load monitor instead of hard-coding a policy.

  PYTHONPATH=src python examples/scenario_zoo.py
  PYTHONPATH=src python examples/scenario_zoo.py --duration 3600 \\
      --policies paragon exascale
"""
import argparse

import numpy as np

from repro.core import get_scenario
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import ServingSim, uniform_pool_workload

ARCHS = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]
SHOWN = ["diurnal_phases", "flash_anti", "mmpp_bursts", "trending_hotswap",
         "diurnal_flash_splice"]
SPARKS = " .:-=+*#%@"


def spark(row: np.ndarray, width: int = 64) -> str:
    """One arch's arrival stream as a spark line (row-relative scale)."""
    bins = np.array_split(row, width)
    vals = np.array([b.mean() for b in bins])
    hi = max(vals.max(), 1e-9)
    return "".join(SPARKS[int(v / hi * (len(SPARKS) - 1))] for v in vals)


def run_policy(arrivals: np.ndarray, wl, name: str) -> dict:
    sim = ServingSim(arrivals, wl)
    pol = VECTOR_SCHEDULERS[name]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
    c = sim.per_arch_counts()
    viol = c["violations"] / np.maximum(c["arrived"], 1e-9)
    return {
        "cost": sim.res.cost_total,
        "viol": sim.res.violation_rate,
        "spread": float(viol.max() - viol.min()),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=int, default=1800)
    ap.add_argument("--mean-rps", type=float, default=120.0)
    ap.add_argument("--policies", nargs=2, default=["paragon", "mixed"],
                    choices=sorted(VECTOR_SCHEDULERS))
    args = ap.parse_args()

    wl = uniform_pool_workload(ARCHS, strict_frac=0.25)
    p1, p2 = args.policies

    for name in SHOWN:
        sc = get_scenario(name)
        arrivals = sc.build(len(wl), duration_s=args.duration,
                            mean_rps=args.mean_rps)
        print(f"\n=== {name}  (kind={sc.kind}, seed={sc.seed}) ===")
        for a, arch in enumerate(ARCHS):
            print(f"  {arch:14s} |{spark(arrivals[a])}|")
        for pol in (p1, p2):
            r = run_policy(arrivals, wl, pol)
            print(f"  {pol:14s} cost=${r['cost']:.2f}  "
                  f"violations={r['viol'] * 100:.2f}%  "
                  f"per-arch spread={r['spread'] * 100:.2f}pp")


if __name__ == "__main__":
    main()
