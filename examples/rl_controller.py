"""Scenario: train the §V PPO controller and face it off against the
hand-built schemes on a held-out trace (CPU, ~2-4 minutes).

  PYTHONPATH=src python examples/rl_controller.py --iterations 60
"""
import argparse

from repro.core import get_trace, simulate
from repro.core.rl import EnvConfig, PPOConfig, ServingEnv, train_ppo
from repro.core.rl.ppo import evaluate_policy
from repro.core.schedulers import SCHEDULERS
from repro.core.sim import ArchLoad


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--train-trace", default="twitter")
    ap.add_argument("--eval-trace", default="berkeley")
    ap.add_argument("--iterations", type=int, default=60)
    ap.add_argument("--mean-rps", type=float, default=60.0)
    ap.add_argument("--duration", type=int, default=1200)
    ap.add_argument("--penalty", type=float, default=0.02)
    args = ap.parse_args()

    envcfg = EnvConfig(
        arch=args.arch, duration_s=args.duration, mean_rps=args.mean_rps,
        violation_penalty=args.penalty,
    )
    train_tr = get_trace(args.train_trace, args.duration, mean_rps=args.mean_rps)
    eval_tr = get_trace(args.eval_trace, args.duration, mean_rps=args.mean_rps,
                        seed=7)

    print(f"[rl] training PPO on {args.train_trace} "
          f"({args.iterations} iterations)...", flush=True)
    state = train_ppo(
        ServingEnv(envcfg, train_tr), PPOConfig(iterations=args.iterations),
        verbose=True,
    )
    print(f"[rl] best rollout reward {state.best_reward:.2f}")

    obj = lambda r: r.cost_total + args.penalty * r.violations  # noqa: E731
    wl = [ArchLoad(args.arch, 1.0, 0.25)]
    print(f"\n[rl] evaluation on held-out {args.eval_trace}:")
    print(f"  {'scheme':12s} {'cost $':>8s} {'viol %':>7s} {'objective':>10s}")
    for name, cls in SCHEDULERS.items():
        r = simulate(eval_tr, wl, cls())
        print(f"  {name:12s} {r.cost_total:8.3f} {r.violation_rate*100:7.2f} "
              f"{obj(r):10.3f}")
    r = evaluate_policy(ServingEnv(envcfg, eval_tr), state.params, seed=11)
    print(f"  {'ppo':12s} {r.cost_total:8.3f} {r.violation_rate*100:7.2f} "
          f"{obj(r):10.3f}   <- learned")


if __name__ == "__main__":
    main()
