"""Scenario: a reserved slice rides a real arrival trace (CPU, reduced).

The simulator decides HOW MANY slices to run; this example runs ONE of
those slices for real — the continuous-batching engine consumes a
30-second window of the berkeley trace scaled to engine capacity, and we
compare the measured queue behaviour against what the profile predicted.

  PYTHONPATH=src python examples/serve_trace.py --arch qwen1.5-0.5b
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import get_trace
from repro.models import model as model_lib
from repro.serving import ContinuousBatcher, Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--window-s", type=int, default=30)
    ap.add_argument("--mean-rps", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = model_lib.init_params(cfg, jax.random.key(args.seed))
    engine = Engine(cfg, params, EngineConfig(
        slots=args.slots, cache_len=64, max_new_tokens=8))
    batcher = ContinuousBatcher(engine)

    trace = get_trace("berkeley", args.window_s, mean_rps=args.mean_rps,
                      seed=args.seed)
    rng = np.random.default_rng(args.seed)

    rid = 0
    print(f"[serve_trace] {cfg.name}: {args.window_s}s of berkeley @ "
          f"{args.mean_rps} req/s into {args.slots} slots")
    for second, rate in enumerate(trace):
        n = rng.poisson(rate)
        for _ in range(n):
            prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
            batcher.submit(Request(rid=rid, prompt=prompt, max_new_tokens=8))
            rid += 1
        # a "second" of engine time: run a few scheduler iterations
        for _ in range(2):
            if not batcher.idle:
                batcher.run_step()
        if second % 10 == 0:
            print(f"  t={second:3d}s rate={rate:5.2f} queued={len(batcher.queue):3d} "
                  f"live={engine.live}")
    stats = batcher.run_until_idle()
    s = stats.summary()
    print(f"[serve_trace] done: {s}")
    print(f"[serve_trace] submitted={rid} finished={s['finished']} "
          f"mean_latency={s['latency_mean_s']:.2f}s (queue waves visible above)")


if __name__ == "__main__":
    main()
