"""Walkthrough: a whole evaluation grid in one vmapped dispatch (CPU).

The batched JAX engine (``repro.core.sim.jax_engine``) holds the whole
tick pipeline — admit → provision → serve → offload → drop → account —
as a jitted ``lax.scan`` over the ``[A, T]`` arrival matrix, with a
``vmap`` over a leading batch axis.  That turns the zoo × seed × policy
sweep the benchmarks run as nested Python loops into ONE device
dispatch: every (scenario, seed) cell of a grid simulates in parallel,
and the summaries come back shaped exactly like the NumPy engine's
``SimResult.summary()`` (the differential tests in
``tests/test_jax_engine.py`` pin the two engines together to 1e-6).

The sweep below runs every zoo scenario × a handful of seeds under two
procurement policies, then prints the per-cell blended objective and
the wall-clock for the batched dispatch vs what the serial NumPy loop
would have cost (extrapolated from one timed cell).

  PYTHONPATH=src python examples/batched_grid.py
  PYTHONPATH=src python examples/batched_grid.py --archs 16 \\
      --duration 1200 --seeds 4 --policies portfolio reactive
"""
import argparse
import time

import numpy as np

from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import ServingSim, jax_engine, uniform_pool_workload
from repro.core.workloads import SCENARIO_ZOO

ARCHS = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"]
PENALTY = 0.02          # $ per violated request, the benchmarks' blend


def numpy_cell(arrivals, wl, policy, seed):
    sim = ServingSim(arrivals, wl, seed=seed)
    pol = VECTOR_SCHEDULERS[policy]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
    return sim.res.summary()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", type=int, default=8)
    ap.add_argument("--duration", type=int, default=900)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--policies", nargs="+", default=["portfolio", "reactive"],
                    choices=sorted(jax_engine.JAX_POLICIES))
    args = ap.parse_args()

    wl = uniform_pool_workload(ARCHS * (args.archs // len(ARCHS) + 1),
                               strict_frac=0.25)[: args.archs]
    names = sorted(SCENARIO_ZOO)

    # every (scenario x seed) cell of the grid as one [B, A, T] stack
    arrs = np.stack([
        SCENARIO_ZOO[n].build(args.archs, duration_s=args.duration,
                              seed=100 + s)
        for n in names for s in range(args.seeds)
    ])
    seeds = [s for _ in names for s in range(args.seeds)]
    B = len(seeds)

    for policy in args.policies:
        t0 = time.perf_counter()
        cells = jax_engine.run_grid(arrs, wl, policy, seeds=seeds)
        first = time.perf_counter() - t0          # includes the one compile
        t0 = time.perf_counter()
        jax_engine.run_grid(arrs, wl, policy, seeds=seeds)
        warm = time.perf_counter() - t0

        # one serial NumPy cell, to scale the comparison
        t0 = time.perf_counter()
        numpy_cell(arrs[0], wl, policy, seeds[0])
        np_serial = (time.perf_counter() - t0) * B

        print(f"\n== {policy}: {B} cells ({len(names)} scenarios x "
              f"{args.seeds} seeds), A={args.archs}, T={args.duration} ==")
        print(f"   one dispatch: {warm:.2f}s warm ({first:.2f}s with "
              f"compile); serial NumPy est. {np_serial:.1f}s "
              f"({np_serial / warm:.1f}x)")
        print(f"   {'scenario':22s} {'seed':>4s} {'cost_total':>10s} "
              f"{'viol_rate':>9s} {'objective':>10s}")
        for i, cell in enumerate(cells):
            s = cell["summary"]
            obj = s["cost_total"] + PENALTY * s["violation_rate"] * float(
                arrs[i].sum()
            )
            print(f"   {names[i // args.seeds]:22s} {seeds[i]:4d} "
                  f"{s['cost_total']:10.2f} {s['violation_rate']:9.4f} "
                  f"{obj:10.2f}")


if __name__ == "__main__":
    main()
