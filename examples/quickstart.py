"""Quickstart: the whole stack in one script (CPU, ~2 minutes).

1. Characterize the model pool (the paper's Fig-2 table, derived).
2. Serve a small model with continuously-batched requests.
3. Run the paper's procurement schemes on a flash-crowd trace.
4. Pick models with Paragon selection vs the naive baseline.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import (
    Constraint,
    get_trace,
    model_pool,
    selection_cost,
    simulate,
    uniform_pool_workload,
)
from repro.core.schedulers import SCHEDULERS
from repro.models import model as model_lib
from repro.serving import ContinuousBatcher, Engine, EngineConfig, Request


def main() -> None:
    # ------------------------------------------------------------- 1. pool
    print("=== 1. model pool (accuracy / latency / cost, derived) ===")
    pool = model_pool()
    for a, e in sorted(pool.items(), key=lambda kv: kv[1]["latency_s"]):
        print(f"  {a:26s} acc={e['accuracy']:.3f} lat={e['latency_s']*1e3:7.1f}ms "
              f"chips={e['chips']:3d} $/1k={e['cost_per_1k']:.4f}")

    # ------------------------------------------------------------ 2. serve
    print("\n=== 2. continuous-batching engine (reduced llama3-8b) ===")
    cfg = get_config("llama3-8b").reduced()
    params = model_lib.init_params(cfg, jax.random.key(0))
    engine = Engine(cfg, params, EngineConfig(slots=4, cache_len=64, max_new_tokens=8))
    batcher = ContinuousBatcher(engine)
    rng = np.random.default_rng(0)
    for i in range(12):
        prompt = rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
        batcher.submit(Request(rid=i, prompt=prompt, max_new_tokens=8))
    stats = batcher.run_until_idle()
    print(f"  {stats.summary()}")

    # -------------------------------------------------------- 3. schedulers
    print("\n=== 3. procurement schemes on the berkeley trace ===")
    trace = get_trace("berkeley", 1200, mean_rps=200)
    wl = uniform_pool_workload(
        ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b"], strict_frac=0.25
    )
    base = None
    for name, cls in SCHEDULERS.items():
        r = simulate(trace, wl, cls())
        base = base or r
        print(f"  {name:11s} cost={r.cost_total:7.3f} "
              f"({r.cost_total / base.cost_total:4.2f}x reactive) "
              f"SLO-violations={r.violation_rate * 100:5.2f}%")

    # --------------------------------------------------- 4. model selection
    print("\n=== 4. model selection: naive vs paragon ===")
    rng = np.random.default_rng(1)
    cons = [
        Constraint(float(rng.uniform(0.3, 0.85)), float(rng.uniform(0.3, 2.0)))
        for _ in range(100)
    ]
    n = selection_cost(cons, "naive")
    p = selection_cost(cons, "paragon")
    print(f"  naive   cost={n['cost']:7.3f} (delivered acc {n['mean_accuracy']:.3f})")
    print(f"  paragon cost={p['cost']:7.3f} (delivered acc {p['mean_accuracy']:.3f})")
    print(f"  paragon is {(1 - p['cost'] / n['cost']) * 100:.1f}% cheaper")


if __name__ == "__main__":
    main()
