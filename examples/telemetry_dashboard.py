"""Walkthrough: the telemetry subsystem end to end, as an ASCII dashboard.

Runs one zoo scenario through the NumPy engine with a
:class:`~repro.core.sim.Telemetry` hook attached — per-tick recorder,
structured event log, SLO burn-rate / queue-age / cost-drift monitors —
then renders what an operator console for the serving pool would show:

  * pool-level sparklines (arrivals, served, violations, queue depths,
    tier fleets, cost) over the run, with incident spans marked ``!``;
  * a per-arch arrival/violation timeline for every pool member;
  * the detected-incident table and the event-log type counts.

Exporters ride along: ``--jsonl`` dumps the raw event log (one JSON
object per line, reloadable via ``events_from_jsonl`` and exactly
reconcilable against the run's ledger), ``--prom`` writes a Prometheus
text-format snapshot of the counters and the run summary.

  PYTHONPATH=src python examples/telemetry_dashboard.py
  PYTHONPATH=src python examples/telemetry_dashboard.py \\
      --scenario mmpp_bursts --ticks 900 --policy spot_paragon \\
      --jsonl /tmp/events.jsonl --prom /tmp/metrics.prom
  PYTHONPATH=src python examples/telemetry_dashboard.py --require-incident
"""
import argparse
from collections import Counter

import numpy as np

from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import (
    MonitorConfig,
    Telemetry,
    detect_incidents,
    incidents_table,
    simulate,
    uniform_pool_workload,
)
from repro.core.workloads import SCENARIO_ZOO

POOL = [
    "llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
    "whisper-small", "llava-next-mistral-7b", "recurrentgemma-9b",
    "phi3.5-moe-42b-a6.6b",
]
BLOCKS = " ▁▂▃▄▅▆▇█"
WIDTH = 72


def spark(series: np.ndarray, width: int = WIDTH, reduce: str = "mean") -> str:
    """Downsample a series into a ``width``-column unicode sparkline."""
    x = np.asarray(series, dtype=float)
    if x.size == 0:
        return " " * width
    edges = np.linspace(0, x.size, width + 1).astype(int)
    cols = np.array([
        (x[a:b].max() if reduce == "max" else x[a:b].mean()) if b > a else 0.0
        for a, b in zip(edges[:-1], edges[1:])
    ])
    hi = cols.max()
    if hi <= 0:
        return BLOCKS[0] * width
    lvl = np.ceil(cols / hi * (len(BLOCKS) - 1)).astype(int)
    return "".join(BLOCKS[i] for i in lvl)


def incident_ruler(incidents, ticks: int, width: int = WIDTH) -> str:
    """One ruler row: ``!`` under every column an incident overlaps."""
    mask = np.zeros(max(ticks, 1), dtype=bool)
    for inc in incidents:
        mask[inc.start_tick: inc.end_tick + 1] = True
    edges = np.linspace(0, mask.size, width + 1).astype(int)
    return "".join(
        "!" if b > a and mask[a:b].any() else "·"
        for a, b in zip(edges[:-1], edges[1:])
    )


def row(label: str, line: str, note: str = "") -> None:
    print(f"{label:>22s} │{line}│ {note}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--scenario", default="flash_correlated",
                    choices=sorted(SCENARIO_ZOO))
    ap.add_argument("--ticks", type=int, default=600)
    ap.add_argument("--rps", type=float, default=300.0)
    ap.add_argument("--policy", default="portfolio",
                    choices=sorted(VECTOR_SCHEDULERS))
    ap.add_argument("--stride", type=int, default=1,
                    help="recorder downsampling stride (ticks per row)")
    ap.add_argument("--jsonl", metavar="PATH",
                    help="export the event log as JSONL")
    ap.add_argument("--prom", metavar="PATH",
                    help="export a Prometheus text-format snapshot")
    ap.add_argument("--require-incident", action="store_true",
                    help="exit nonzero unless >= 1 incident is detected")
    args = ap.parse_args()

    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    arrivals = SCENARIO_ZOO[args.scenario].build(
        len(wl), duration_s=args.ticks, mean_rps=args.rps)
    tel = Telemetry(stride=args.stride)
    res = simulate(arrivals, wl, VECTOR_SCHEDULERS[args.policy](),
                   telemetry=tel)
    rec = tel.recorder
    incidents = detect_incidents(rec, MonitorConfig())

    s = res.summary()
    print(f"scenario={args.scenario}  policy={args.policy}  "
          f"A={len(wl)}  T={args.ticks}  mean_rps={args.rps:g}")
    print(f"cost_total=${s['cost_total']:.4f}  "
          f"violation_rate={s['violation_rate']:.3%}  "
          f"served_vm={s['served_vm']:.0f}  "
          f"served_burst={s['served_burst']:.0f}  "
          f"events={len(tel.events)}  incidents={len(incidents)}")
    print()

    # -- pool-level timelines ---------------------------------------------
    row("arrivals/s", spark(rec.pool_flow("arrived")),
        f"peak {rec.pool_flow('arrived').max():.0f}")
    row("served (vm+burst)", spark(rec.pool_flow("served_vm")
                                   + rec.pool_flow("served_burst")))
    viol = rec.pool_flow("viol_strict") + rec.pool_flow("viol_relaxed")
    row("SLO violations", spark(viol, reduce="max"),
        f"total {viol.sum():.0f}")
    n = rec.n_rows
    for cls in ("strict", "relaxed"):
        depth = rec.queue_depth[cls][:n].sum(axis=1)
        age = rec.queue_age_p99[cls][:n].max(axis=1)
        row(f"queue[{cls}]", spark(depth),
            f"p99 age max {age.max()}s")
    for tier in rec.tier_names:
        active = rec.tier_active[tier][:n].sum(axis=1)
        if active.any():
            row(f"fleet[{tier}]", spark(active),
                f"max {active.max()} instances")
    row("burst offload/s", spark(rec.pool_flow("served_burst")))
    row("cost $/tick", spark(rec.tier_cost[:n].sum(axis=1)))
    if rec.harvest_level[:n].any():
        row("harvest signal", spark(rec.harvest_level[:n]))
    row("incidents", incident_ruler(incidents, args.ticks),
        "(! = inside an incident span)")
    print()

    # -- per-arch timelines -----------------------------------------------
    print("per-arch arrivals:")
    arr = rec.flows["arrived"][:n]
    for i, load in enumerate(wl):
        row(load.arch[:22], spark(arr[:, i], width=48),
            f"{arr[:, i].sum():.0f} req")
    print()

    # -- incidents + event-log digest --------------------------------------
    print(incidents_table(incidents))
    counts = Counter(ev.etype for ev in tel.events)
    print("event log:",
          ", ".join(f"{k}={v}" for k, v in counts.most_common(8)),
          f"(+{len(counts) - 8} more types)" if len(counts) > 8 else "")

    if args.jsonl:
        n_ev = tel.to_jsonl(args.jsonl)
        print(f"wrote {n_ev} events -> {args.jsonl}")
    if args.prom:
        with open(args.prom, "w") as f:
            f.write(tel.prometheus_text(res))
        print(f"wrote Prometheus snapshot -> {args.prom}")

    if args.require_incident and not incidents:
        print("FAIL: no incidents detected (--require-incident)")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
