"""Scenario: train the pool-wide §V PPO controller on scenario batches
and face it off against the classical vectorized schedulers on held-out
realizations of the workload zoo (CPU, ~1-3 minutes).

  PYTHONPATH=src python examples/rl_pool_controller.py --iterations 24

One policy, applied per arch row, controls the whole heterogeneous
pool: observations are the engine's [A, 10] feature matrix, actions are
factored per arch (headroom x offload), and the reward is decomposed
per arch from the engine's cost attribution — so what you train here is
exactly what ``VECTOR_SCHEDULERS["rl_pool"]`` deploys.
"""
import argparse

from repro.core.rl import (
    EnvConfig,
    PPOConfig,
    PoolServingEnv,
    RLPoolPolicy,
    train_ppo_pool,
)
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import simulate, uniform_pool_workload
from repro.core.workloads import SCENARIO_ZOO

POOL = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
        "whisper-small", "recurrentgemma-9b"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=24)
    ap.add_argument("--mean-rps", type=float, default=90.0)
    ap.add_argument("--duration", type=int, default=600)
    ap.add_argument("--penalty", type=float, default=0.02)
    ap.add_argument("--eval-scenario", default="flash_anti")
    args = ap.parse_args()

    wl = uniform_pool_workload(POOL, strict_frac=0.25)
    cfg = EnvConfig(mean_rps=args.mean_rps, duration_s=args.duration,
                    violation_penalty=args.penalty)
    env = PoolServingEnv(wl, cfg, scenarios=list(SCENARIO_ZOO.values()),
                         scenario_seed=1)

    print(f"[rl-pool] training on scenario batches over {len(wl)} archs "
          f"({args.iterations} iterations)...", flush=True)
    state = train_ppo_pool(
        env, PPOConfig(iterations=args.iterations,
                       rollout_len=args.duration), verbose=True,
    )
    print(f"[rl-pool] best rollout reward {state.best_reward:.2f}")

    sc = SCENARIO_ZOO[args.eval_scenario]
    arrivals = sc.build(len(wl), seed=sc.seed + 777,
                        duration_s=args.duration, mean_rps=args.mean_rps)
    obj = lambda r: r.cost_total + args.penalty * r.violations  # noqa: E731
    print(f"\n[rl-pool] held-out '{args.eval_scenario}' realization:")
    print(f"  {'scheme':12s} {'cost $':>8s} {'viol %':>7s} {'objective':>10s}")
    for name in sorted(VECTOR_SCHEDULERS):
        if name == "rl_pool":
            continue
        r = simulate(arrivals, wl, VECTOR_SCHEDULERS[name]())
        print(f"  {name:12s} {r.cost_total:8.3f} {r.violation_rate*100:7.2f} "
              f"{obj(r):10.3f}")
    r = simulate(arrivals, wl, RLPoolPolicy(params=state.params, seed=11))
    print(f"  {'rl_pool':12s} {r.cost_total:8.3f} {r.violation_rate*100:7.2f} "
          f"{obj(r):10.3f}   <- learned")


if __name__ == "__main__":
    main()
