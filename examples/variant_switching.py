"""Walkthrough: runtime model-variant switching under a flash crowd (CPU).

The engine's variant axis (PR 4) lets a scheduler change WHICH model
serves each stream while the fleet keeps running: a swap requested at
tick t serves at the old variant's rate for ``pricing.variant_swap_s``
seconds (the weight reload), then the arch's service rate, chip
footprint, and delivered accuracy all follow the new variant.

This example runs a flash-crowd scenario over the 8-arch serving pool
with a pool-wide accuracy SLO, and sweeps that accuracy floor to trace
the cost/accuracy frontier:

  * ``reactive`` stays pinned to every arch's base model — it cannot
    move along the frontier at all: one accuracy, and accuracy-SLO
    violations as soon as the floor passes the cheap models;
  * ``accuracy_floor`` re-pins each stream to the cheapest variant
    meeting the floor — it WALKS the frontier, and at moderate floors
    lands strictly below the fixed fleet's cost at higher accuracy
    (the paper's joint model x resource claim, INFaaS's model-less
    pitch);
  * ``infaas_variant`` spends slack on upgrades and sheds accuracy
    under pressure — more delivered accuracy, more spent.

  PYTHONPATH=src python examples/variant_switching.py
  PYTHONPATH=src python examples/variant_switching.py --duration 3600 \\
      --floors 0.4 0.55 0.65
"""
import argparse
import dataclasses


from repro.core import get_scenario
from repro.core.schedulers import VECTOR_SCHEDULERS
from repro.core.sim import ServingSim, VariantCatalog, uniform_pool_workload

ARCHS = ["llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
         "whisper-small", "llava-next-mistral-7b", "recurrentgemma-9b",
         "phi3.5-moe-42b-a6.6b"]
POLICIES = ("reactive", "accuracy_floor", "infaas_variant")


def run_policy(arrivals, wl, catalog, name):
    sim = ServingSim(arrivals, wl, catalog=catalog)
    pol = VECTOR_SCHEDULERS[name]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
    r = sim.res
    return {
        "cost": r.cost_total,
        "acc": r.mean_accuracy,
        "viol": r.violation_rate,
        "acc_viol": r.acc_violation_rate,
        "swaps": r.variant_swaps,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=int, default=1800)
    ap.add_argument("--mean-rps", type=float, default=400.0)
    ap.add_argument("--floors", nargs="*", type=float,
                    default=[0.0, 0.45, 0.55, 0.65])
    args = ap.parse_args()

    sc = get_scenario("flash_anti")
    arrivals = sc.build(len(ARCHS), duration_s=args.duration,
                        mean_rps=args.mean_rps)
    base_wl = uniform_pool_workload(ARCHS, strict_frac=0.25)
    catalog = VariantCatalog.for_workload(base_wl)
    print(f"scenario={sc.name}  pool={len(ARCHS)} archs  "
          f"duration={args.duration}s  mean={args.mean_rps} req/s")
    print("variant sets (accuracy-ordered):")
    for a in ARCHS[:3]:
        vs = catalog.variants(a)
        chain = " < ".join(f"{v.arch}@{v.accuracy:.2f}" for v in vs[:4])
        print(f"  {a}: base#{catalog.base_idx[a]} of {len(vs)}  [{chain} ...]")

    print(f"\n{'floor':>6s} {'policy':>16s} {'cost $':>8s} {'accuracy':>9s} "
          f"{'slo-viol':>9s} {'acc-viol':>9s} {'swaps':>6s}")
    frontier = {}
    for floor in args.floors:
        wl = [dataclasses.replace(w, min_accuracy=floor) for w in base_wl]
        for name in POLICIES:
            r = run_policy(arrivals, wl, catalog, name)
            print(f"{floor:6.2f} {name:>16s} {r['cost']:8.3f} "
                  f"{r['acc']:9.4f} {r['viol']:9.4f} {r['acc_viol']:9.4f} "
                  f"{r['swaps']:6d}")
            frontier.setdefault(name, []).append((r["cost"], r["acc"]))

    fixed = frontier["reactive"][-1]
    walked = frontier["accuracy_floor"]
    print("\nThe fixed-variant fleet sits at one point "
          f"(cost {fixed[0]:.3f}, accuracy {fixed[1]:.3f}); accuracy_floor "
          "walks the frontier:")
    for floor, (c, a) in zip(args.floors, walked):
        mark = " <- beats fixed on BOTH axes" if (
            c < fixed[0] and a > fixed[1]
        ) else ""
        print(f"  floor {floor:.2f}: cost {c:.3f}, accuracy {a:.3f}{mark}")


if __name__ == "__main__":
    main()
