"""Scenario: the ``long_500k`` story at CPU scale.

Decodes past the attention horizon with the three long-context families
the assignment exercises:

  * rwkv6      — O(1) recurrent state, no KV at all
  * rgemma     — RG-LRU state + local-attention ring buffer
  * llama      — sliding-window variant (the dense archs' long_500k path):
                 a ring KV cache of ``window`` slots replaces the full cache

All three decode 3x past their cache capacity and must stay finite and
shape-correct — the structural property that lets the full configs lower
``long_500k`` (seq 524288) in the dry-run.

  PYTHONPATH=src python examples/long_context.py --steps 48
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as model_lib


def run_arch(arch: str, steps: int, window: int = 0) -> None:
    cfg = get_config(arch).reduced()
    params = model_lib.init_params(cfg, jax.random.key(0))
    b = 2
    prompt = jax.random.randint(jax.random.key(1), (b, 12), 0, cfg.vocab_size)

    cache_len = window if window else 16       # tiny ring/state budget
    cache = model_lib.init_cache(cfg, b, cache_len, window=window)
    last, cache = model_lib.prefill(cfg, params, prompt, cache, window=window)
    tok = jnp.argmax(last, -1).astype(jnp.int32)

    decode = jax.jit(
        lambda p, t, c: model_lib.decode_step(cfg, p, t, c, window=window)
    )
    for i in range(steps):
        logits, cache = decode(params, tok, cache)
        assert logits.shape == (b, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch} NaN at step {i}"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

    total = prompt.shape[1] + steps
    state_desc = f"ring window={window}" if window else f"state cache_len={cache_len}"
    print(f"  {arch:22s} decoded {total:4d} tokens with {state_desc} "
          f"(t={int(cache['t'][0])}) OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=48)
    args = ap.parse_args()

    print("[long_context] decoding far past the cache horizon:")
    run_arch("rwkv6-1.6b", args.steps)                 # O(1) state
    run_arch("recurrentgemma-9b", args.steps)          # RG-LRU + local ring
    run_arch("llama3-8b", args.steps, window=8)        # sliding-window dense
    print("[long_context] all families stable beyond their horizon")


if __name__ == "__main__":
    main()
