"""Scenario: end-to-end training driver — a ~50-100M-parameter member of
the minicpm family (WSD schedule, the arch's own training recipe) for a
few hundred steps on the synthetic LM pipeline.  Loss must fall.

Reduced further with --small for CI-speed runs.

  PYTHONPATH=src python examples/train_lm.py --steps 200
  PYTHONPATH=src python examples/train_lm.py --small --steps 40
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.training.data import SyntheticLM
from repro.training.optimizer import OptimizerConfig
from repro.training.schedule import ScheduleConfig
from repro.training.train_loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true",
                    help="2-layer d=256 variant (seconds, for CI)")
    args = ap.parse_args()

    cfg = get_config("minicpm-2b").reduced()
    if not args.small:
        # ~100M-class member of the same family
        cfg = dataclasses.replace(
            cfg, name="minicpm-100m", num_layers=8, d_model=512,
            num_heads=8, num_kv_heads=8, head_dim=64, d_ff=1536,
            vocab_size=32_768,
        )
    print(f"[train_lm] {cfg.name}: params={cfg.params_total/1e6:.1f}M "
          f"steps={args.steps} (WSD schedule)")

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=6e-4),
        schedule=ScheduleConfig(
            kind="wsd", peak_lr=6e-4, warmup_steps=max(10, args.steps // 10),
            total_steps=args.steps, decay_start_frac=0.8,
        ),
    )
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)

    def log(step, m):
        print(f"[train_lm] step={step:4d} loss={m['loss']:.4f} "
              f"lr={m['lr']:.2e} wall={m['wall_s']:.1f}s", flush=True)

    _, _, hist = train(cfg, tcfg, iter(data), args.steps, log_every=20,
                       callback=log)
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not improve"
    print(f"[train_lm] loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} OK")


if __name__ == "__main__":
    main()
