"""Scenario: a production-scale model pool rides a 24 h trace (CPU).

INFaaS-style model-less serving keeps a large pool of model variants
live; this example simulates procurement for a 64-variant pool over a
day of berkeley arrivals with the vectorized engine + vectorized Paragon
policy (structure-of-arrays end to end) — the seed per-arch loop took
~18 minutes for this; the engine takes seconds.

  PYTHONPATH=src python examples/pool_scale.py --pool-size 64
"""
import argparse
import time

from repro.core import get_trace, replicate_pool, simulate
from repro.core.schedulers import VECTOR_SCHEDULERS

ARCHS = [
    "llama3-8b", "qwen1.5-0.5b", "rwkv6-1.6b", "minicpm-2b",
    "whisper-small", "llava-next-mistral-7b", "recurrentgemma-9b",
    "phi3.5-moe-42b-a6.6b",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool-size", type=int, default=64)
    ap.add_argument("--trace", default="berkeley")
    ap.add_argument("--duration", type=int, default=86_400)
    ap.add_argument("--mean-rps", type=float, default=400.0)
    ap.add_argument("--policy", default="paragon", choices=sorted(VECTOR_SCHEDULERS))
    args = ap.parse_args()

    trace = get_trace(args.trace, args.duration, mean_rps=args.mean_rps)
    wl = replicate_pool(ARCHS, args.pool_size, strict_frac=0.25)

    print(f"[pool_scale] {args.pool_size}-variant pool, {args.duration} ticks "
          f"of {args.trace} @ {args.mean_rps} req/s, policy={args.policy}")
    t0 = time.perf_counter()
    res = simulate(trace, wl, VECTOR_SCHEDULERS[args.policy]())
    wall = time.perf_counter() - t0
    s = res.summary()
    print(f"[pool_scale] {wall:.1f}s wall ({args.duration / wall:.0f} ticks/s)")
    print(f"  cost ${s['cost_total']:.2f}  violations {s['violation_rate']*100:.3f}%  "
          f"overprovision {s['overprovision_ratio']*100:.1f}%")
    print(f"  served: vm={s['served_vm']:.0f} burst={s['served_burst']:.0f}")


if __name__ == "__main__":
    main()
