"""Numpy-based checkpointing: pytrees -> flat key/value .npz + metadata.

Atomic (write-to-temp, rename), step-indexed, restartable.  No orbax
dependency; works for any pytree of arrays (params, optimizer state,
PPO agents, simulator RNG state).
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "__/__"


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # npz has no codec for ml_dtypes; store the raw bits — the
            # restore template's dtype recovers the view
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _key_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    return f"s:{p}"


def save_checkpoint(directory: str, step: int, tree: Any, *, name: str = "state") -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    treedef = jax.tree.structure(tree)
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        meta = os.path.join(directory, f"{name}_{step:08d}.json")
        with open(meta + ".tmp", "w") as f:
            json.dump({"step": step, "treedef": str(treedef)}, f)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
        os.replace(meta + ".tmp", meta)
    finally:
        for leftover in (tmp, tmp + ".npz"):
            if os.path.exists(leftover):
                os.remove(leftover)
    return path


def restore_checkpoint(directory: str, step: int, like: Any, *, name: str = "state") -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    path = os.path.join(directory, f"{name}_{step:08d}.npz")
    data = np.load(path)
    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, leaf in flat_like:
        key = _SEP.join(_key_str(x) for x in p)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        want = np.dtype(leaf.dtype)
        if arr.dtype == np.uint16 and want.itemsize == 2 and want.kind == "V" or (
            arr.dtype == np.uint16 and want.name == "bfloat16"
        ):
            arr = arr.view(want)
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(like), leaves)


def latest_step(directory: str, *, name: str = "state") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    pat = re.compile(rf"{re.escape(name)}_(\d+)\.npz$")
    steps = [int(m.group(1)) for f in os.listdir(directory) if (m := pat.match(f))]
    return max(steps) if steps else None
