"""Shared layers: norms, MLPs, embeddings, rotary positions."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.distributed.sharding import shard
from repro.models.params import Boxed, boxed_normal, boxed_ones, boxed_zeros

# ---------------------------------------------------------------------------
# Norms (always computed in fp32).
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dtype) -> dict:
    p = {"scale": boxed_ones((cfg.d_model,), ("embed",), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = boxed_zeros((cfg.d_model,), ("embed",), dtype)
    return p


def apply_norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + 1e-6)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# MLP: SwiGLU (wi_gate, wi_up, wo) or GELU (wi, wo).
# ---------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=jnp.float32) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    if cfg.mlp == "swiglu":
        return {
            "wi_gate": boxed_normal(k1, (d, ff), ("embed", "ff"), s_in, dtype),
            "wi_up": boxed_normal(k2, (d, ff), ("embed", "ff"), s_in, dtype),
            "wo": boxed_normal(k3, (ff, d), ("ff", "embed"), s_out, dtype),
        }
    return {
        "wi": boxed_normal(k1, (d, ff), ("embed", "ff"), s_in, dtype),
        "wo": boxed_normal(k2, (ff, d), ("ff", "embed"), s_out, dtype),
    }


def apply_mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.mlp == "swiglu":
        g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, p["wi_up"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, p["wi"]))
    if h.ndim == 3:
        h = shard(h, "batch", None, "ff")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embeddings.
# ---------------------------------------------------------------------------
def init_embed(key, cfg: ModelConfig, dtype) -> Boxed:
    return boxed_normal(key, (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), 1.0, dtype)


def embed_tokens(embed: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(embed, tokens, axis=0)


# ---------------------------------------------------------------------------
# Rotary position embedding.
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)          # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    if theta <= 0.0:
        return x
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    """Whisper-style absolute sinusoidal embeddings (seq, d_model)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, d_model, 2, dtype=jnp.float32) * (-jnp.log(10_000.0) / d_model)
    )
    pe = jnp.zeros((seq_len, d_model), dtype=jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe
