"""Composable language model.

One code path serves all 10 architectures: the config's ``block_pattern``
(attn / local-attn / rglru / rwkv) is scanned over layers as *super-blocks*
(one repetition of the pattern), with any ``tail_blocks`` unrolled after the
scan.  Whisper adds an encoder stack + cross-attention in the decoder.

Entry points
------------
``init_params``  — (traceable) build the parameter tree; use with
                   ``jax.eval_shape`` for abstract 72B/1T initialization.
``param_axes``   — logical-axes tree matching ``init_params`` (sharding).
``forward``      — full-sequence logits (training).
``loss_fn``      — next-token cross-entropy (optionally seq-chunked).
``init_cache``   — decode cache pytree for a (batch, cache_len).
``prefill``      — populate the cache from a prompt, return last logits.
``decode_step``  — one token for every sequence in the batch.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import ATTN, LOCAL_ATTN, RGLRU, RWKV, ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention as attn_lib
from repro.models import griffin, layers, moe as moe_lib, rwkv as rwkv_lib
from repro.models.params import Boxed, axes_of, is_boxed, values_of


# ---------------------------------------------------------------------------
# Init.
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": layers.init_norm(cfg, dtype)}
    if kind in (ATTN, LOCAL_ATTN):
        p["attn"] = attn_lib.init_attention(k1, cfg, dtype)
        p["norm2"] = layers.init_norm(cfg, dtype)
        if cfg.num_experts:
            p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = layers.init_mlp(k2, cfg, dtype=dtype)
    elif kind == RGLRU:
        p["rglru"] = griffin.init_rglru(k1, cfg, dtype)
        p["norm2"] = layers.init_norm(cfg, dtype)
        p["mlp"] = layers.init_mlp(k2, cfg, dtype=dtype)
    elif kind == RWKV:
        p["rwkv"] = rwkv_lib.init_rwkv(k1, cfg, dtype)
        p["norm2"] = layers.init_norm(cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def _init_enc_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": layers.init_norm(cfg, dtype),
        "attn": attn_lib.init_attention(k1, cfg, dtype),
        "norm2": layers.init_norm(cfg, dtype),
        "mlp": layers.init_mlp(k2, cfg, dtype=dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype) -> dict:
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": layers.init_norm(cfg, dtype),
        "attn": attn_lib.init_attention(k1, cfg, dtype),
        "norm_x": layers.init_norm(cfg, dtype),
        "xattn": attn_lib.init_attention(k2, cfg, dtype, cross=True),
        "norm2": layers.init_norm(cfg, dtype),
        "mlp": layers.init_mlp(k3, cfg, dtype=dtype),
    }


def _stack(init_fn, key, n: int):
    """vmap-stack n layer inits; prepend the 'layers' logical axis."""
    keys = jax.random.split(key, n)
    stacked = jax.vmap(init_fn)(keys)
    return jax.tree.map(
        lambda b: Boxed(b.value, ("layers",) + b.axes), stacked, is_leaf=is_boxed
    )


def _pattern_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    """(n_repeats, pattern, tail) with n_repeats*len(pattern)+len(tail)==L."""
    pat = cfg.block_pattern
    n_rep = (cfg.num_layers - len(cfg.tail_blocks)) // len(pat)
    assert n_rep * len(pat) + len(cfg.tail_blocks) == cfg.num_layers, cfg.name
    return n_rep, pat, cfg.tail_blocks


def init_params_boxed(cfg: ModelConfig, key, dtype=jnp.float32):
    ke, kl, kh, kt, kenc = jax.random.split(key, 5)
    n_rep, pat, tail = _pattern_layout(cfg)
    p: Dict[str, Any] = {
        "embed": layers.init_embed(ke, cfg, dtype),
        "final_norm": layers.init_norm(cfg, dtype),
        "blocks": {},
        "tail": {},
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.tree.map(
            lambda b: b, layers.init_embed(kh, cfg, dtype), is_leaf=is_boxed
        )
    if cfg.is_encoder_decoder:
        p["blocks"]["dec"] = _stack(
            lambda k: _init_dec_block(k, cfg, dtype), kl, cfg.num_layers
        )
        p["encoder"] = {
            "blocks": _stack(lambda k: _init_enc_block(k, cfg, dtype), kenc, cfg.encoder_layers),
            "final_norm": layers.init_norm(cfg, dtype),
        }
    else:
        for i, kind in enumerate(pat):
            p["blocks"][f"p{i}_{kind}"] = _stack(
                lambda k, kind=kind: _init_block(k, cfg, kind, dtype),
                jax.random.fold_in(kl, i),
                n_rep,
            )
        for j, kind in enumerate(tail):
            p["tail"][f"t{j}_{kind}"] = _init_block(
                jax.random.fold_in(kt, j), cfg, kind, dtype
            )
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    return values_of(init_params_boxed(cfg, key, dtype))


@functools.lru_cache(maxsize=64)
def _param_axes_cached(cfg: ModelConfig, dtype_name: str):
    dtype = jnp.dtype(dtype_name)
    boxed = jax.eval_shape(
        lambda k: init_params_boxed(cfg, k, dtype), jax.random.key(0)
    )
    return axes_of(boxed)


def param_axes(cfg: ModelConfig, dtype=jnp.float32):
    return _param_axes_cached(cfg, jnp.dtype(dtype).name)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct tree — no allocation (dry-run / cost model)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k, dtype), jax.random.key(0)
    )


@functools.lru_cache(maxsize=128)
def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    import math

    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Layer-stack iteration.
#
# ``jax.lax.scan`` is the production path (compact HLO, fast compiles).
# ``REPRO_UNROLL_SCANS=1`` switches every layer scan to a Python loop: the
# dry-run sets it so ``compiled.cost_analysis()`` counts every layer's
# FLOPs/bytes/collectives instead of the scan body once (XLA's cost model
# does not multiply while-loop trip counts) — see EXPERIMENTS.md §Dry-run.
# ---------------------------------------------------------------------------
def _unroll_scans() -> bool:
    return os.environ.get("REPRO_UNROLL_SCANS") == "1"


def _scan(f, init, xs, length: Optional[int] = None):
    """jax.lax.scan, or an unrolled Python loop under REPRO_UNROLL_SCANS."""
    if not _unroll_scans():
        return jax.lax.scan(f, init, xs, length=length)
    n = length
    if n is None:
        n = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = ys[0] if ys else None
    return carry, stacked


# ---------------------------------------------------------------------------
# Block application (shared by forward / prefill / decode).
# ---------------------------------------------------------------------------
def _ffn(cfg: ModelConfig, p: dict, x, moe_path: str):
    if cfg.num_experts:
        y, aux = moe_lib.moe_apply(cfg, p["moe"], x, path=moe_path)
        return y, aux
    return layers.apply_mlp(cfg, p["mlp"], x), 0.0


def _apply_block_full(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[dict],
    *,
    window_global: int = 0,
    moe_path: str = "local",
    impl: Optional[str] = None,
):
    """Full-sequence (train / prefill) application of one block."""
    aux = 0.0
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.local_window if kind == LOCAL_ATTN else window_global
        h = layers.apply_norm(cfg, p["norm1"], x)
        attn_cache = cache["attn"] if cache is not None else None
        y, new_attn_cache = attn_lib.attention_full(
            cfg, p["attn"], h, positions, window=window, impl=impl, cache=attn_cache
        )
        x = x + y
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        y2, aux = _ffn(cfg, p, h2, moe_path)
        x = x + y2
        new_cache = {"attn": new_attn_cache} if cache is not None else None
    elif kind == RGLRU:
        h = layers.apply_norm(cfg, p["norm1"], x)
        st = cache["rglru"] if cache is not None else None
        y, new_st = griffin.rglru_block(cfg, p["rglru"], h, st)
        x = x + y
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        y2, aux = _ffn(cfg, p, h2, "local") if cfg.num_experts else (
            layers.apply_mlp(cfg, p["mlp"], h2), 0.0)
        x = x + y2
        new_cache = {"rglru": new_st} if cache is not None else None
    elif kind == RWKV:
        st = cache["rwkv"] if cache is not None else None
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, shift_tm, wkv = rwkv_lib.time_mix(
            cfg, p["rwkv"], h,
            st["shift_tm"] if st else None,
            st["wkv"] if st else None,
            impl=impl,
        )
        x = x + y
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        y2, shift_cm = rwkv_lib.channel_mix(cfg, p["rwkv"], h2, st["shift_cm"] if st else None)
        x = x + y2
        new_cache = (
            {"rwkv": {"shift_tm": shift_tm, "shift_cm": shift_cm, "wkv": wkv}}
            if cache is not None else None
        )
    else:
        raise ValueError(kind)
    x = shard(x, "batch", "seq_act", None)
    return x, new_cache, aux


def _apply_block_decode(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x: jax.Array,              # (B, 1, d)
    t: jax.Array,              # (B,)
    cache: dict,
    *,
    window_global: int = 0,
    impl: Optional[str] = None,
):
    aux = 0.0
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.local_window if kind == LOCAL_ATTN else window_global
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, new_attn = attn_lib.attention_decode(
            cfg, p["attn"], h, t, cache["attn"], window=window, impl=impl
        )
        x = x + y
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        y2, aux = _ffn(cfg, p, h2, "local")
        x = x + y2
        new_cache = {"attn": new_attn}
    elif kind == RGLRU:
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, new_st = griffin.rglru_block(cfg, p["rglru"], h, cache["rglru"])
        x = x + y
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.apply_mlp(cfg, p["mlp"], h2)
        new_cache = {"rglru": new_st}
    elif kind == RWKV:
        st = cache["rwkv"]
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, shift_tm, wkv = rwkv_lib.time_mix(
            cfg, p["rwkv"], h, st["shift_tm"], st["wkv"], impl=impl
        )
        x = x + y
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        y2, shift_cm = rwkv_lib.channel_mix(cfg, p["rwkv"], h2, st["shift_cm"])
        x = x + y2
        new_cache = {"rwkv": {"shift_tm": shift_tm, "shift_cm": shift_cm, "wkv": wkv}}
    else:
        raise ValueError(kind)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Embedding in/out.
# ---------------------------------------------------------------------------
def _embed_in(cfg: ModelConfig, params, inputs, positions) -> jax.Array:
    if inputs.ndim == 3:           # precomputed embeddings (VLM / audio enc)
        x = inputs.astype(params["embed"].dtype)
    else:
        x = layers.embed_tokens(params["embed"], inputs)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype) if cfg.is_encoder_decoder else x
    if cfg.is_encoder_decoder and inputs.ndim == 2:
        # whisper decoder: absolute sinusoidal positions
        pe = _abs_pos(positions, cfg.d_model).astype(x.dtype)
        x = x + pe[None] if pe.ndim == 2 else x + pe
    return x


def _abs_pos(positions: jax.Array, d_model: int) -> jax.Array:
    pos = positions.astype(jnp.float32)[..., None]
    div = jnp.exp(
        jnp.arange(0, d_model, 2, dtype=jnp.float32) * (-jnp.log(10_000.0) / d_model)
    )
    ang = pos * div
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _unembed(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed"].T
    else:
        w = params["lm_head"].T
    # d^-0.5 keeps init logit variance O(1) (embed tables are unit-scale)
    logits = jnp.einsum("...d,dv->...v", x, w) * (cfg.d_model ** -0.5)
    return logits


# ---------------------------------------------------------------------------
# Forward (full sequence).
# ---------------------------------------------------------------------------

def _maybe_checkpoint(fn, remat):
    """remat: False | True/'full' (recompute everything) | 'dots' (save
    matmul outputs, recompute elementwise — less recompute FLOPs for more
    activation HBM; §Perf lever for dense training)."""
    if not remat:
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _run_blocks_full(
    cfg, params, x, positions, caches, *, window_global, moe_path, impl, remat
):
    """Scan super-blocks; returns (x, new_caches, aux_sum)."""
    n_rep, pat, tail = _pattern_layout(cfg)
    aux_total = 0.0

    def superblock(x, slices):
        p_slices, c_slices = slices
        aux = 0.0
        new_cs = {}
        for i, kind in enumerate(pat):
            key = f"p{i}_{kind}"
            c_in = c_slices.get(key) if c_slices is not None else None
            x, new_c, a = _apply_block_full(
                cfg, kind, p_slices[key], x, positions, c_in,
                window_global=window_global, moe_path=moe_path, impl=impl,
            )
            if c_slices is not None:
                new_cs[key] = new_c
            aux = aux + a
        return x, (new_cs if c_slices is not None else None), aux

    body = _maybe_checkpoint(superblock, remat)

    def scan_body(carry, slices):
        x, aux = carry
        x, new_c, a = body(x, slices)
        return (x, aux + a), new_c

    block_params = {k: v for k, v in params["blocks"].items()}
    block_caches = caches["blocks"] if caches is not None else None
    xs = (block_params, block_caches)
    if block_caches is None:
        xs = (block_params, None)
        # jax.lax.scan needs a pytree with consistent leading dims; None ok
    (x, aux_total), new_block_caches = _scan(
        scan_body, (x, 0.0), xs, length=n_rep
    )

    new_tail = {}
    for j, kind in enumerate(tail):
        key = f"t{j}_{kind}"
        c_in = caches["tail"].get(key) if caches is not None else None
        x, new_c, a = _apply_block_full(
            cfg, kind, params["tail"][key], x, positions, c_in,
            window_global=window_global, moe_path=moe_path, impl=impl,
        )
        aux_total = aux_total + a
        if caches is not None:
            new_tail[key] = new_c

    new_caches = None
    if caches is not None:
        new_caches = {"blocks": new_block_caches, "tail": new_tail}
    return x, new_caches, aux_total


def forward(
    cfg: ModelConfig,
    params,
    inputs,                       # (B,S) tokens or (B,S,d) embeds
    *,
    enc_inputs=None,              # whisper: (B, Senc, d) frame embeddings
    window: int = 0,              # 0=full causal; >0 sliding (long-context)
    moe_path: str = "local",
    impl: Optional[str] = None,
    remat: bool = False,
):
    """Full-sequence forward -> logits (B, S, vocab)."""
    s = inputs.shape[1]
    positions = jnp.arange(s)
    x = _embed_in(cfg, params, inputs, positions)
    x = shard(x, "batch", "seq_act", None)
    aux = 0.0

    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, enc_inputs, impl=impl, remat=remat)
        x, _, aux = _run_dec_blocks_full(
            cfg, params, x, positions, enc_out, None, impl=impl, remat=remat,
            window=window,
        )
    else:
        x, _, aux = _run_blocks_full(
            cfg, params, x, positions, None,
            window_global=window, moe_path=moe_path, impl=impl, remat=remat,
        )
    x = layers.apply_norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x), aux


# ---------------------------------------------------------------------------
# Whisper encoder / decoder stacks.
# ---------------------------------------------------------------------------
def _encode(cfg: ModelConfig, params, enc_inputs, *, impl=None, remat=False):
    enc = params["encoder"]
    x = enc_inputs.astype(params["embed"].dtype)
    pe = _abs_pos(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
    x = x + pe[None]

    def body(x, p):
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, _ = attn_lib.attention_full(
            cfg, p["attn"], h, jnp.arange(x.shape[1]), causal=False, impl=impl
        )
        x = x + y
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.apply_mlp(cfg, p["mlp"], h2)
        return x, None

    body = _maybe_checkpoint(body, remat)
    x, _ = _scan(body, x, enc["blocks"])
    return layers.apply_norm(cfg, enc["final_norm"], x)


def _run_dec_blocks_full(cfg, params, x, positions, enc_out, caches, *, impl, remat,
                         window: int = 0):
    def body_fn(x, slices):
        p, c = slices
        h = layers.apply_norm(cfg, p["norm1"], x)
        attn_cache = c["attn"] if c is not None else None
        y, new_attn = attn_lib.attention_full(
            cfg, p["attn"], h, positions, window=window, impl=impl, cache=attn_cache
        )
        x = x + y
        hx = layers.apply_norm(cfg, p["norm_x"], x)
        x = x + attn_lib.cross_attention(
            cfg, p["xattn"], hx,
            *attn_lib.cross_attention_kv(cfg, p["xattn"], enc_out),
        )
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.apply_mlp(cfg, p["mlp"], h2)
        new_c = {"attn": new_attn} if c is not None else None
        return x, new_c

    body = _maybe_checkpoint(body_fn, remat)

    def scan_body(x, slices):
        return body(x, slices)

    caches_in = caches["blocks"]["dec"] if caches is not None else None
    x, new_caches = _scan(
        scan_body, x, (params["blocks"]["dec"], caches_in)
    )
    out_caches = None
    if caches is not None:
        out_caches = {"blocks": {"dec": new_caches}, "tail": {}}
    return x, out_caches, 0.0


# ---------------------------------------------------------------------------
# Loss.
# ---------------------------------------------------------------------------
def loss_fn(
    cfg: ModelConfig,
    params,
    batch: dict,
    *,
    window: int = 0,
    moe_path: str = "local",
    impl: Optional[str] = None,
    remat: bool = True,
    aux_weight: float = 0.01,
):
    """Next-token CE. batch: {"inputs": (B,S) or (B,S,d), "labels": (B,S)}."""
    logits, aux = forward(
        cfg, params, batch["inputs"],
        enc_inputs=batch.get("enc_inputs"),
        window=window, moe_path=moe_path, impl=impl, remat=remat,
    )
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    safe_labels = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Cache init / prefill / decode.
# ---------------------------------------------------------------------------
def _layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                 window_global: int, dtype):
    if kind in (ATTN, LOCAL_ATTN):
        if kind == LOCAL_ATTN:
            clen = min(cfg.local_window, cache_len)
        elif window_global:
            clen = min(window_global, cache_len)
        else:
            clen = cache_len
        return {"attn": attn_lib.init_layer_cache(cfg, batch, clen, dtype)}
    if kind == RGLRU:
        return {"rglru": griffin.init_rglru_state(cfg, batch, dtype)}
    if kind == RWKV:
        return {"rwkv": rwkv_lib.init_rwkv_state(cfg, batch, dtype)}
    raise ValueError(kind)


def init_cache(
    cfg: ModelConfig,
    batch: int,
    cache_len: int,
    *,
    window: int = 0,
    dtype=jnp.float32,
    enc_out: Optional[jax.Array] = None,
):
    """Decode cache. ``window`` > 0 = sliding-window mode for global-attn."""
    n_rep, pat, tail = _pattern_layout(cfg)
    cache: Dict[str, Any] = {"t": jnp.zeros((batch,), jnp.int32), "blocks": {}, "tail": {}}
    if cfg.is_encoder_decoder:
        clen = min(window, cache_len) if window else cache_len

        def one(_):
            return {"attn": attn_lib.init_layer_cache(cfg, batch, clen, dtype)}
        cache["blocks"]["dec"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one(i) for i in range(cfg.num_layers)],
        )
        return cache
    for i, kind in enumerate(pat):
        key = f"p{i}_{kind}"
        per = [
            _layer_cache(cfg, kind, batch, cache_len, window, dtype)
            for _ in range(n_rep)
        ]
        cache["blocks"][key] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    for j, kind in enumerate(tail):
        cache["tail"][f"t{j}_{kind}"] = _layer_cache(
            cfg, kind, batch, cache_len, window, dtype
        )
    return cache


def prefill(
    cfg: ModelConfig,
    params,
    inputs,
    cache,
    *,
    enc_inputs=None,
    window: int = 0,
    moe_path: str = "local",
    impl: Optional[str] = None,
):
    """Run the prompt through the model, populating ``cache``.

    Returns (last-token logits (B, vocab), new cache with cross-attn KV for
    enc-dec models stashed under ``cache["cross"]``)."""
    s = inputs.shape[1]
    positions = jnp.arange(s)
    x = _embed_in(cfg, params, inputs, positions)
    if cfg.is_encoder_decoder:
        enc_out = _encode(cfg, params, enc_inputs, impl=impl)
        x, new_caches, _ = _run_dec_blocks_full(
            cfg, params, x, positions, enc_out, cache, impl=impl, remat=False,
            window=window,
        )
        new_caches["cross"] = _all_cross_kv(cfg, params, enc_out)
    else:
        x, new_caches, _ = _run_blocks_full(
            cfg, params, x, positions, cache,
            window_global=window, moe_path=moe_path, impl=impl, remat=False,
        )
    new_caches["t"] = jnp.full((inputs.shape[0],), s, jnp.int32)
    x = layers.apply_norm(cfg, params["final_norm"], x[:, -1:, :])
    return _unembed(cfg, params, x)[:, 0], new_caches


def _all_cross_kv(cfg, params, enc_out):
    def kv_one(p):
        k, v = attn_lib.cross_attention_kv(cfg, p["xattn"], enc_out)
        return {"k": k, "v": v}
    return jax.vmap(
        lambda p: kv_one(p), in_axes=(0,)
    )(params["blocks"]["dec"])


def decode_step(
    cfg: ModelConfig,
    params,
    tokens,                     # (B,) int32 — next input token per sequence
    cache,
    *,
    window: int = 0,
    impl: Optional[str] = None,
):
    """One decode step. Returns (logits (B, vocab), new cache)."""
    t = cache["t"]
    x = layers.embed_tokens(params["embed"], tokens[:, None])
    if cfg.is_encoder_decoder:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        pe = jax.vmap(lambda tt: _abs_pos(tt[None], cfg.d_model)[0])(t)
        x = x + pe[:, None, :].astype(x.dtype)
        x, new_caches = _decode_dec_blocks(cfg, params, x, t, cache, impl=impl,
                                           window=window)
    else:
        n_rep, pat, tail = _pattern_layout(cfg)

        def scan_body(x, slices):
            p_slices, c_slices = slices
            new_cs = {}
            for i, kind in enumerate(pat):
                key = f"p{i}_{kind}"
                x, new_c, _ = _apply_block_decode(
                    cfg, kind, p_slices[key], x, t, c_slices[key],
                    window_global=window, impl=impl,
                )
                new_cs[key] = new_c
            return x, new_cs

        x, new_block_caches = _scan(
            scan_body, x, (params["blocks"], cache["blocks"])
        )
        new_tail = {}
        for j, kind in enumerate(tail):
            key = f"t{j}_{kind}"
            x, new_c, _ = _apply_block_decode(
                cfg, kind, params["tail"][key], x, t, cache["tail"][key],
                window_global=window, impl=impl,
            )
            new_tail[key] = new_c
        new_caches = {"blocks": new_block_caches, "tail": new_tail}

    new_caches["t"] = t + 1
    if "cross" in cache:
        new_caches["cross"] = cache["cross"]
    x = layers.apply_norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x)[:, 0], new_caches


def _decode_dec_blocks(cfg, params, x, t, cache, *, impl, window: int = 0):
    cross = cache["cross"]

    def scan_body(x, slices):
        p, c, xkv = slices
        h = layers.apply_norm(cfg, p["norm1"], x)
        y, new_attn = attn_lib.attention_decode(
            cfg, p["attn"], h, t, c["attn"], window=window, impl=impl
        )
        x = x + y
        hx = layers.apply_norm(cfg, p["norm_x"], x)
        x = x + attn_lib.cross_attention(cfg, p["xattn"], hx, xkv["k"], xkv["v"])
        h2 = layers.apply_norm(cfg, p["norm2"], x)
        x = x + layers.apply_mlp(cfg, p["mlp"], h2)
        return x, {"attn": new_attn}

    x, new_dec = _scan(
        scan_body, x, (params["blocks"]["dec"], cache["blocks"]["dec"], cross)
    )
    return x, {"blocks": {"dec": new_dec}, "tail": {}}
