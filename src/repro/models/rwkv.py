"""RWKV-6 "Finch" block — attention-free, data-dependent decay.

Two sub-blocks, each called by the model on a pre-normed input and added
residually (standard RWKV structure):

* ``time_mix``    — token-shift mixing, r/k/v/g projections, decay ``w_t``
  from a low-rank MLP (the Finch innovation), matrix-valued per-head WKV
  state with bonus ``u``.
* ``channel_mix`` — token-shift + squared-ReLU FFN with sigmoid gate.

Decode state per layer:
  ``shift_tm`` (B, d)        — previous (normed) token for time-mix shift
  ``shift_cm`` (B, d)        — previous (normed) token for channel-mix shift
  ``wkv``      (B, H, hd, hd) fp32 — recurrent state
Token-shift states hold the *normed* inputs, so prefill and decode agree.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.kernels import ops
from repro.models.params import boxed_normal, boxed_zeros

DECAY_LORA_RANK = 96


def init_rwkv(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    r = DECAY_LORA_RANK
    return {
        # time-mix
        "mu": boxed_zeros((5, d), (None, "embed"), jnp.float32),  # r,k,v,w,g shifts
        "wr": boxed_normal(ks[0], (d, d), ("embed", "heads_flat"), s, dtype),
        "wk": boxed_normal(ks[1], (d, d), ("embed", "heads_flat"), s, dtype),
        "wv": boxed_normal(ks[2], (d, d), ("embed", "heads_flat"), s, dtype),
        "wg": boxed_normal(ks[3], (d, d), ("embed", "heads_flat"), s, dtype),
        "wo": boxed_normal(ks[4], (d, d), ("heads_flat", "embed"), s, dtype),
        "decay_a": boxed_normal(ks[5], (d, r), ("embed", None), s, dtype),
        "decay_b": boxed_normal(ks[6], (r, d), (None, "heads_flat"), r ** -0.5, dtype),
        "w0": boxed_zeros((d,), ("heads_flat",), jnp.float32),
        "u": boxed_zeros((h, hd), ("heads_flat", None), jnp.float32),
        # channel-mix
        "cm_mu": boxed_zeros((d,), ("embed",), jnp.float32),
        "cm_k": boxed_normal(ks[7], (d, cfg.d_ff), ("embed", "ff"), s, dtype),
        "cm_v": boxed_normal(ks[8], (cfg.d_ff, d), ("ff", "embed"), cfg.d_ff ** -0.5, dtype),
        "cm_r": boxed_normal(ks[9], (d, d), ("embed", "embed_out"), s, dtype),
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """shifted[t] = x[t-1]; shifted[0] = prev (or 0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None, :].astype(x.dtype), x[:, :-1, :]], axis=1)


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """Data-dependent decay in (0, 1): exp(-exp(w0 + tanh(x A) B))."""
    lora = jnp.einsum(
        "btd,dr->btr", xw.astype(jnp.float32), p["decay_a"].astype(jnp.float32)
    )
    logw = p["w0"] + jnp.einsum(
        "btr,rd->btd", jnp.tanh(lora), p["decay_b"].astype(jnp.float32)
    )
    return jnp.exp(-jnp.exp(jnp.clip(logw, -8.0, 4.0)))


def time_mix(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                       # (B, T, d) — pre-normed
    shift_prev: Optional[jax.Array],    # (B, d) or None
    wkv0: Optional[jax.Array],          # (B, H, hd, hd) or None
    *,
    impl: Optional[str] = None,
):
    b, t, d = x.shape
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim

    shifted = _token_shift(x, shift_prev)
    mu = p["mu"]
    xr, xk, xv, xw, xg = [
        x + (shifted - x) * mu[i][None, None, :].astype(x.dtype) for i in range(5)
    ]
    r = jnp.einsum("btd,de->bte", xr, p["wr"]).reshape(b, t, h, hd)
    k = jnp.einsum("btd,de->bte", xk, p["wk"]).reshape(b, t, h, hd)
    v = jnp.einsum("btd,de->bte", xv, p["wv"]).reshape(b, t, h, hd)
    g = jnp.einsum("btd,de->bte", xg, p["wg"])
    w = _decay(p, xw).reshape(b, t, h, hd).astype(x.dtype)

    out, wkv = ops.rwkv6(r, k, v, w, p["u"], wkv0, impl=impl)   # (B,T,H,hd)
    out = out.reshape(b, t, d) * jax.nn.silu(g)
    y = jnp.einsum("bte,ed->btd", out, p["wo"])
    return y, x[:, -1, :], wkv


def channel_mix(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                       # (B, T, d) — pre-normed
    shift_prev: Optional[jax.Array],
):
    shifted = _token_shift(x, shift_prev)
    xk = x + (shifted - x) * p["cm_mu"][None, None, :].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(jnp.einsum("btd,df->btf", xk, p["cm_k"])))
    vv = jnp.einsum("btf,fd->btd", kk, p["cm_v"])
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", x, p["cm_r"]))
    return rr * vv, x[:, -1, :]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    return {
        "shift_tm": jnp.zeros((batch, d), dtype=dtype),
        "shift_cm": jnp.zeros((batch, d), dtype=dtype),
        "wkv": jnp.zeros((batch, h, hd, hd), dtype=jnp.float32),
    }
