"""Parameter trees with logical-axis annotations.

Init functions build ``{name: Boxed(value, axes)}`` trees.  ``unbox`` splits
them into a value tree (what jit sees) and an axes tree (what the dry-run
turns into NamedShardings).  Init is pure-traceable, so abstract init via
``jax.eval_shape`` never allocates the 72B/1T parameter sets.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Boxed:
    value: Any
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)


def is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def boxed_normal(key, shape, axes, scale: float, dtype) -> Boxed:
    assert len(shape) == len(axes), (shape, axes)
    return Boxed(scale * jax.random.normal(key, shape, dtype=dtype), tuple(axes))


def boxed_zeros(shape, axes, dtype) -> Boxed:
    return Boxed(jnp.zeros(shape, dtype=dtype), tuple(axes))


def boxed_ones(shape, axes, dtype) -> Boxed:
    return Boxed(jnp.ones(shape, dtype=dtype), tuple(axes))


def boxed_value(value, axes) -> Boxed:
    return Boxed(value, tuple(axes))


def unbox(tree):
    """Split a Boxed tree into (values, axes) trees of identical structure."""
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return values, axes


def values_of(tree):
    return jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)


def axes_of(tree):
    return jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
