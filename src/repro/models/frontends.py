"""Modality frontend STUBS (the one sanctioned carve-out).

The assigned ``[vlm]`` and ``[audio]`` architectures specify the
transformer BACKBONE; the vision encoder (ViT/SigLIP + projector,
anyres tiling) and the audio codec (mel-spectrogram + conv downsampler)
are stubs that emit embeddings of the correct shape/dtype — seeded and
deterministic so tests and examples are reproducible.

Shapes follow the real frontends:
  llava-next anyres  — base 576 patch tokens (24x24) + up to 4 tiles;
                       text tokens interleave after the image block.
  whisper            — 30 s of 16 kHz audio -> 3000 mel frames -> conv
                       stride 2 -> 1500 frame embeddings.
"""
from __future__ import annotations

import numpy as np

from repro.configs.registry import ModelConfig

VLM_BASE_PATCHES = 576          # 24 x 24 @ 336px, CLIP-L/14
WHISPER_FRAMES = 1500           # 30 s -> 1500 post-conv frames


def vision_embeddings(
    cfg: ModelConfig,
    batch: int,
    *,
    tiles: int = 1,
    seed: int = 0,
    dtype=np.float32,
) -> np.ndarray:
    """(B, tiles*576, d_model) patch embeddings — the projector output."""
    assert cfg.frontend == "vision", cfg.name
    rng = np.random.default_rng(seed)
    n = VLM_BASE_PATCHES * max(1, tiles)
    # unit-RMS embeddings, matching the projector's layernormed output
    x = rng.standard_normal((batch, n, cfg.d_model)).astype(dtype)
    return x / np.sqrt(cfg.d_model)


def multimodal_inputs(
    cfg: ModelConfig,
    text_tokens: np.ndarray,            # (B, S_text) int32
    text_embed: np.ndarray,             # (vocab, d) the model's embed table
    *,
    tiles: int = 1,
    seed: int = 0,
) -> np.ndarray:
    """Interleave [image patches; text] -> (B, S_img+S_text, d_model)."""
    img = vision_embeddings(cfg, text_tokens.shape[0], tiles=tiles, seed=seed)
    txt = np.asarray(text_embed)[text_tokens]           # (B, S_text, d)
    return np.concatenate([img, txt.astype(img.dtype)], axis=1)


def audio_frames(
    cfg: ModelConfig,
    batch: int,
    *,
    frames: int = 0,
    seed: int = 0,
    dtype=np.float32,
) -> np.ndarray:
    """(B, frames, d_model) post-conv mel-frame embeddings."""
    assert cfg.frontend == "audio", cfg.name
    rng = np.random.default_rng(seed)
    n = frames or min(cfg.encoder_seq, WHISPER_FRAMES)
    x = rng.standard_normal((batch, n, cfg.d_model)).astype(dtype)
    return x / np.sqrt(cfg.d_model)
