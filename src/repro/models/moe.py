"""Mixture-of-Experts layer.

Three execution paths, all computing the same routing semantics
(top-k, softmax-over-selected, capacity-factor token dropping):

1. ``moe_dense_oracle``  — O(E·T·d·ff) one-hot einsum.  Exact, tiny shapes
   only; the correctness oracle for the other two paths.
2. ``moe_sort_local``    — sort-based capacity dispatch in global-view jnp.
   O(T log T + E·C·d·ff).  XLA's SPMD partitioner chooses the collectives.
   This is the paper-faithful baseline path.
3. ``moe_ep_a2a``        — explicit expert parallelism: ``shard_map`` over the
   mesh, tokens exchanged to expert-owner shards with ``all_to_all``.  The
   beyond-paper optimized path for train/prefill (§Perf).

Routing: logits -> top-k -> softmax over the selected k logits (Mixtral
convention).  Aux output is the load-balance loss (Switch-style
E · Σ_e f_e·p_e) used by the training substrate.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ModelConfig
from repro.distributed.sharding import current_rules, shard
from repro.models.params import boxed_normal

# shard_map graduated from jax.experimental (and renamed check_rep ->
# check_vma) in newer jax; support both spellings
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:                                     # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_KW = {"check_rep": False}


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    e_ff = cfg.expert_d_ff or cfg.d_ff
    e = cfg.num_experts
    kr, kg, ku, ko = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, e_ff ** -0.5
    return {
        "router": boxed_normal(kr, (d, e), ("embed", None), s_in, jnp.float32),
        "wi_gate": boxed_normal(kg, (e, d, e_ff), ("experts", "embed", "ff"), s_in, dtype),
        "wi_up": boxed_normal(ku, (e, d, e_ff), ("experts", "embed", "ff"), s_in, dtype),
        "wo": boxed_normal(ko, (e, e_ff, d), ("experts", "ff", "embed"), s_out, dtype),
    }


def _route(cfg: ModelConfig, router_w: jax.Array, xf: jax.Array):
    """xf (T, d) -> (gates (T,k) fp32, expert_idx (T,k) int32, aux_loss)."""
    k = cfg.num_experts_per_tok
    e = cfg.num_experts
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router_w.astype(jnp.float32))
    topv, topi = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(topv, axis=-1)                     # (T, k)
    # Switch-style load balance: E * sum_e fraction_e * prob_e  (== 1 when
    # perfectly balanced)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    onehot = jax.nn.one_hot(topi[:, 0], e, dtype=jnp.float32)  # top-1 assignment share
    aux = e * jnp.sum(jnp.mean(onehot, axis=0) * jnp.mean(probs, axis=0))
    return gates, topi.astype(jnp.int32), aux


def _expert_ffn(cfg: ModelConfig, p: dict, buf: jax.Array) -> jax.Array:
    """buf (E, C, d) -> (E, C, d); batched per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", buf, p["wi_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wi_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(tokens * cfg.num_experts_per_tok * cfg.moe_capacity_factor
            / cfg.num_experts) + 1
    # MXU-friendly multiple of 8 (128 when big enough)
    return max(8, -(-c // 8) * 8)


# ---------------------------------------------------------------------------
# 1. Dense oracle.
# ---------------------------------------------------------------------------
def moe_dense_oracle(cfg: ModelConfig, p: dict, x: jax.Array):
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    gates, topi, aux = _route(cfg, p["router"], xf)
    y = jnp.zeros_like(xf, dtype=jnp.float32)
    for e in range(cfg.num_experts):
        pe = {
            "wi_gate": p["wi_gate"][e][None, :, :],
            "wi_up": p["wi_up"][e][None, :, :],
            "wo": p["wo"][e][None, :, :],
        }
        out_e = _expert_ffn(cfg, pe, xf[None, :, :])[0]        # (T, d)
        w_e = jnp.sum(jnp.where(topi == e, gates, 0.0), axis=-1)  # (T,)
        y = y + w_e[:, None] * out_e.astype(jnp.float32)
    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# 2. Sort-based capacity dispatch (global view).
# ---------------------------------------------------------------------------
def moe_sort_local(cfg: ModelConfig, p: dict, x: jax.Array,
                   capacity: Optional[int] = None):
    b, s, d = x.shape
    t = b * s
    k = cfg.num_experts_per_tok
    e = cfg.num_experts
    c = capacity or _capacity(cfg, t)

    xf = x.reshape(t, d)
    gates, topi, aux = _route(cfg, p["router"], xf)

    flat_e = topi.reshape(t * k)                               # (T·k,)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gates.reshape(t * k)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
    counts = jnp.bincount(se, length=e)                        # (E,)
    offsets = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - offsets[se]     # rank within expert
    keep = pos < c
    # out-of-range rows scatter with mode='drop'
    se_k = jnp.where(keep, se, e)
    pos_k = jnp.where(keep, pos, 0)

    buf = jnp.zeros((e, c, d), dtype=x.dtype)
    buf = buf.at[se_k, pos_k].set(xf[st], mode="drop")
    buf = shard(buf, "experts", None, None)
    out = _expert_ffn(cfg, p, buf)                             # (E, C, d)
    out = shard(out, "experts", None, None)

    rows = jnp.where(
        keep[:, None], out.at[(se_k, pos_k)].get(mode="fill", fill_value=0.0), 0.0
    )
    y = jnp.zeros((t, d), dtype=jnp.float32)
    y = y.at[st].add(sg[:, None] * rows.astype(jnp.float32))
    return y.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# 3. Explicit expert parallelism with all_to_all (shard_map).
# ---------------------------------------------------------------------------
def moe_ep_a2a(cfg: ModelConfig, p: dict, x: jax.Array):
    """Expert-parallel MoE. Requires active axis rules with an ``experts``
    mapping to a mesh axis, tokens divisible by that axis size."""
    rules = current_rules()
    if rules is None:
        return moe_sort_local(cfg, p, x)
    ep_axis = rules.mesh_axes("experts")
    if ep_axis is None:
        return moe_sort_local(cfg, p, x)
    if isinstance(ep_axis, tuple):
        ep_axis = ep_axis[0]
    mesh = rules.mesh
    n_ep = mesh.shape[ep_axis]
    if cfg.num_experts % n_ep or x.shape[1] % n_ep:
        return moe_sort_local(cfg, p, x)

    batch_axes = rules.mesh_axes("batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)

    e_loc = cfg.num_experts // n_ep
    d = x.shape[-1]
    k = cfg.num_experts_per_tok

    def local_fn(xs, router_w, wg, wu, wo):
        # xs: (B_loc, S_loc, d) — batch split over data axes, seq over ep axis
        b_loc, s_loc, _ = xs.shape
        t_loc = b_loc * s_loc
        c = _capacity(cfg, t_loc)
        xf = xs.reshape(t_loc, d)
        gates, topi, aux = _route(cfg, router_w, xf)

        flat_e = topi.reshape(t_loc * k)
        flat_tok = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32), k)
        flat_gate = gates.reshape(t_loc * k)
        order = jnp.argsort(flat_e, stable=True)
        se, st, sg = flat_e[order], flat_tok[order], flat_gate[order]
        counts = jnp.bincount(se, length=cfg.num_experts)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(t_loc * k, dtype=jnp.int32) - offsets[se]
        keep = pos < c
        se_k = jnp.where(keep, se, cfg.num_experts)
        pos_k = jnp.where(keep, pos, 0)

        # dispatch buffer grouped by destination shard: (E, C, d) == (n_ep·e_loc, C, d)
        buf = jnp.zeros((cfg.num_experts, c, d), dtype=xs.dtype)
        buf = buf.at[se_k, pos_k].set(xf[st], mode="drop")
        buf = buf.reshape(n_ep, e_loc, c, d)
        # exchange: dim0 = destination shard -> after a2a dim0 = source shard
        recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0, tiled=True)
        recv = recv.reshape(n_ep, e_loc, c, d).transpose(1, 0, 2, 3)  # (e_loc, n_src, C, d)
        recv = recv.reshape(e_loc, n_ep * c, d)
        p_loc = {"wi_gate": wg, "wi_up": wu, "wo": wo}
        out = _expert_ffn(cfg, p_loc, recv)                   # (e_loc, n_src·C, d)
        out = out.reshape(e_loc, n_ep, c, d).transpose(1, 0, 2, 3).reshape(n_ep * e_loc, c, d)
        back = jax.lax.all_to_all(
            out.reshape(n_ep, e_loc, c, d), ep_axis, split_axis=0, concat_axis=0, tiled=True
        ).reshape(cfg.num_experts, c, d)

        rows = jnp.where(
            keep[:, None], back.at[(se_k, pos_k)].get(mode="fill", fill_value=0.0), 0.0
        )
        y = jnp.zeros((t_loc, d), dtype=jnp.float32)
        y = y.at[st].add(sg[:, None] * rows.astype(jnp.float32))
        # aux is a local mean; average across shards
        aux = jax.lax.pmean(aux, ep_axis)
        for ax in batch_axes:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(b_loc, s_loc, d).astype(xs.dtype), aux

    x_spec = P(batch_axes if batch_axes else None, ep_axis, None)
    w_spec = P(ep_axis, None, None)
    out_specs = (x_spec, P())
    y, aux = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
        out_specs=out_specs,
        **_SHARD_MAP_KW,
    )(x, p["router"], p["wi_gate"], p["wi_up"], p["wo"])
    return y, aux


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array, path: str = "local"):
    if path == "dense":
        return moe_dense_oracle(cfg, p, x)
    if path == "ep_a2a":
        return moe_ep_a2a(cfg, p, x)
    return moe_sort_local(cfg, p, x)
