"""RecurrentGemma / Griffin recurrent block (RG-LRU + temporal conv).

Structure (pre-normed input, residual added by caller):
  branch a: x -> linear -> causal depthwise conv1d (kernel 4) -> RG-LRU
  branch b: x -> linear -> GeLU
  out     : (a * b) -> linear

RG-LRU:  a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t)),
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_i x_t) * x_t)
Gates use block-diagonal weights (NUM_BLOCKS blocks), as in the paper.

Decode state per layer:
  ``conv``  (B, K-1, w) — trailing conv window
  ``h``     (B, w) fp32 — recurrent state
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.kernels import ops
from repro.models.params import boxed_normal, boxed_zeros, boxed_value

CONV_K = 4
NUM_BLOCKS = 8
C_RGLRU = 8.0


def init_rglru(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    bs = w // NUM_BLOCKS
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    # Lambda init so that softplus(Lambda) gives decay a in [0.9, 0.999]^(1/c)
    lam0 = jnp.log(jnp.expm1(-jnp.log(jax.random.uniform(
        ks[5], (w,), minval=0.9, maxval=0.999)) / C_RGLRU))
    return {
        "wx": boxed_normal(ks[0], (d, w), ("embed", "ff"), s, dtype),
        "wgate": boxed_normal(ks[1], (d, w), ("embed", "ff"), s, dtype),
        "conv_w": boxed_normal(ks[2], (CONV_K, w), (None, "ff"), 0.5, dtype),
        "conv_b": boxed_zeros((w,), ("ff",), dtype),
        "gate_a": boxed_normal(ks[3], (NUM_BLOCKS, bs, bs), (None, "ff", None), bs ** -0.5, dtype),
        "gate_i": boxed_normal(ks[4], (NUM_BLOCKS, bs, bs), (None, "ff", None), bs ** -0.5, dtype),
        "lam": boxed_value(lam0, ("ff",)),
        "wo": boxed_normal(jax.random.fold_in(key, 7), (w, d), ("ff", "embed"), w ** -0.5, dtype),
    }


def _block_diag(x: jax.Array, wblk: jax.Array) -> jax.Array:
    """(B,T,w) x (NB, bs, bs) -> (B,T,w) block-diagonal matmul."""
    b, t, w = x.shape
    nb, bs, _ = wblk.shape
    xb = x.reshape(b, t, nb, bs)
    yb = jnp.einsum("btns,nsc->btnc", xb, wblk)
    return yb.reshape(b, t, w)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d, kernel K. prev: (B, K-1, w) trailing context."""
    k = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], k - 1, x.shape[2]), dtype=x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)       # (B, T+K-1, w)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :], xp[:, -(k - 1):, :]


def rglru_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                     # (B, T, d) pre-normed
    state: Optional[dict] = None,
) -> Tuple[jax.Array, dict]:
    xa = jnp.einsum("btd,dw->btw", x, p["wx"])
    xb = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["wgate"]))

    conv_prev = state["conv"] if state else None
    xa, conv_new = _causal_conv(xa, p["conv_w"], p["conv_b"], conv_prev)

    r = jax.nn.sigmoid(_block_diag(xa, p["gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(xa, p["gate_i"]).astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)                                            # (B,T,w) in (0,1)

    gated = (i * xa.astype(jnp.float32)).astype(x.dtype)
    h0 = state["h"] if state else None
    h, h_last = ops.rglru(gated, a.astype(x.dtype), h0)

    y = jnp.einsum("btw,wd->btd", h.astype(x.dtype) * xb, p["wo"])
    new_state = {"conv": conv_new, "h": h_last}
    return y, new_state


def init_rglru_state(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.rglru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, w), dtype=dtype),
        "h": jnp.zeros((batch, w), dtype=jnp.float32),
    }
