"""Attention blocks: full/causal, sliding-window, GQA, with KV cache decode.

Cache contract (per attention layer):
  ``k``/``v``      : (B, S_cache, n_kv, head_dim)
  ``slot_pos``     : (B, S_cache) int32 — absolute position held in each slot,
                     -1 when empty.  Full caches write slot = pos; windowed
                     caches write slot = pos % window (ring buffer).  RoPE is
                     applied at WRITE time, so ring overwrites are safe.
The per-sequence decode position ``t`` (B,) lives at the cache-tree top level
and is shared by all layers — per-sequence so continuous batching can decode
ragged batches in lockstep.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.distributed.sharding import shard
from repro.kernels import ops
from repro.models.layers import apply_rope
from repro.models.params import boxed_normal, boxed_zeros


def init_attention(key, cfg: ModelConfig, dtype, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": boxed_normal(kq, (d, nq, hd), ("embed", "heads", None), s, dtype),
        "wk": boxed_normal(kk, (d, nkv, hd), ("embed", "kv_heads", None), s, dtype),
        "wv": boxed_normal(kv, (d, nkv, hd), ("embed", "kv_heads", None), s, dtype),
        "wo": boxed_normal(ko, (nq, hd, d), ("heads", None, "embed"), (nq * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = boxed_zeros((nq, hd), ("heads", None), dtype)
        p["bk"] = boxed_zeros((nkv, hd), ("kv_heads", None), dtype)
        p["bv"] = boxed_zeros((nkv, hd), ("kv_heads", None), dtype)
    return p


def _project_qkv(cfg: ModelConfig, p: dict, x_q, x_kv):
    q = jnp.einsum("bsd,dnh->bsnh", x_q, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x_kv, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x_kv, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def init_layer_cache(
    cfg: ModelConfig, batch: int, cache_len: int, dtype
) -> dict:
    nkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, cache_len, nkv, hd), dtype=dtype),
        "v": jnp.zeros((batch, cache_len, nkv, hd), dtype=dtype),
        "slot_pos": jnp.full((batch, cache_len), -1, dtype=jnp.int32),
    }


def attention_full(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                  # (B, S, d)
    positions: jax.Array,          # (S,)
    *,
    window: int = 0,
    causal: bool = True,
    impl: Optional[str] = None,
    cache: Optional[dict] = None,  # if given, prefill: populate and return it
) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence attention (training / prefill)."""
    q, k, v = _project_qkv(cfg, p, x, x)
    q = shard(q, "batch", "seq_act", "heads", None)
    k = shard(k, "batch", "seq_act", "kv_heads", None)
    v = shard(v, "batch", "seq_act", "kv_heads", None)
    q = apply_rope(q, positions[None, :], cfg.rope_theta)
    k = apply_rope(k, positions[None, :], cfg.rope_theta)
    out = ops.flash_attention(q, k, v, causal=causal, window=window, impl=impl)
    out = shard(out, "batch", "seq_act", "heads", None)
    y = jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
    new_cache = None
    if cache is not None:
        new_cache = _write_prefill_cache(cache, k, v, positions, window)
    return y, new_cache


def _write_prefill_cache(cache, k, v, positions, window):
    """Write a prefilled sequence into the (possibly ring) cache."""
    cache_len = cache["k"].shape[1]
    b = k.shape[0]
    s = k.shape[1]
    if window and cache_len < s:
        # ring cache shorter than the sequence: only the tail survives
        k_tail = k[:, -cache_len:]
        v_tail = v[:, -cache_len:]
        pos_tail = positions[-cache_len:]
        order = jnp.argsort(pos_tail % cache_len)
        return {
            "k": k_tail[:, order].astype(cache["k"].dtype),
            "v": v_tail[:, order].astype(cache["v"].dtype),
            "slot_pos": jnp.broadcast_to(
                pos_tail[order].astype(jnp.int32)[None, :], (b, cache_len)
            ),
        }
    # full cache (or ring larger than seq): slot = pos (% cache_len)
    slots = positions % cache_len
    kc = cache["k"].at[:, slots].set(k.astype(cache["k"].dtype))
    vc = cache["v"].at[:, slots].set(v.astype(cache["v"].dtype))
    sp = cache["slot_pos"].at[:, slots].set(positions.astype(jnp.int32)[None, :])
    return {"k": kc, "v": vc, "slot_pos": sp}


def attention_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                  # (B, 1, d)
    t: jax.Array,                  # (B,) int32 — per-sequence absolute position
    cache: dict,
    *,
    window: int = 0,
    impl: Optional[str] = None,
) -> Tuple[jax.Array, dict]:
    """One-token decode against the cache; returns (out (B,1,d), new cache)."""
    b = x.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.int32), (b,))
    q, k, v = _project_qkv(cfg, p, x, x)
    q = apply_rope(q, t[:, None], cfg.rope_theta)
    k = apply_rope(k, t[:, None], cfg.rope_theta)

    cache_len = cache["k"].shape[1]
    slot = (t % cache_len).astype(jnp.int32)          # (B,)
    bidx = jnp.arange(b)
    kc = cache["k"].at[bidx, slot].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[bidx, slot].set(v[:, 0].astype(cache["v"].dtype))
    sp = cache["slot_pos"].at[bidx, slot].set(t)
    kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
    vc = shard(vc, "batch", "kv_seq", "kv_heads", None)

    valid = (sp >= 0) & (sp <= t[:, None])            # (B, S_cache)
    if window:
        valid &= sp > (t[:, None] - window)
    out = ops.decode_attention(q[:, 0], kc, vc, valid, impl=impl)  # (B,nq,hd)
    y = jnp.einsum("bnh,nhd->bd", out, p["wo"])[:, None, :]
    return y, {"k": kc, "v": vc, "slot_pos": sp}


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder). KV computed once from encoder output.
# ---------------------------------------------------------------------------
def cross_attention_kv(cfg: ModelConfig, p: dict, enc_out: jax.Array):
    k = jnp.einsum("bsd,dnh->bsnh", enc_out, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", enc_out, p["wv"])
    if "bk" in p:
        k = k + p["bk"]
        v = v + p["bv"]
    return k, v


def cross_attention(cfg: ModelConfig, p: dict, x: jax.Array, k: jax.Array, v: jax.Array):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    out = ops.flash_attention(q, k, v, causal=False)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"])
