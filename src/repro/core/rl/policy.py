"""The trained pool controller as a deployable vectorized scheduler.

:class:`RLPoolPolicy` speaks the engine's structure-of-arrays policy
interface (``vectorized = True``: ``PoolObs -> PoolAction``), so the PPO
controller lines up head-to-head with the six classical schedulers in
``VECTOR_SCHEDULERS`` — same benchmarks, same scenario zoo, same tick
loop.  Inference is NumPy-only (a two-layer tanh torso per arch row);
JAX stays on the training side.

Checkpoints are plain JSON (``save_policy_params`` /
``load_policy_params``): ``benchmarks/rl_vs_schemes.py`` trains the
controller and writes :data:`DEFAULT_CHECKPOINT`, which a bare
``RLPoolPolicy()`` — the form the benchmark grids instantiate — loads
by default.  Without a checkpoint the policy falls back to a seeded
random initialization: still a valid (if untrained) controller, so
grids never crash on a fresh clone.
"""
from __future__ import annotations

import json
import os
import warnings
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

from repro.core.rl.obs import (
    N_ACTIONS,
    OBS_DIM,
    pool_features,
    procurement_action,
)
from repro.core.sim import PoolAction, PoolObs

#: where the RL benchmark publishes the trained pool controller
DEFAULT_CHECKPOINT = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "..",
    "artifacts", "rl", "pool_policy.json",
)

_LAYERS = ("torso1", "torso2", "pi", "v")


def policy_logits(params: dict, feats, xp=np):
    """Two-layer tanh torso + action head, backend-parametric.

    The single definition of the controller's forward pass:
    :class:`RLPoolPolicy` runs it eagerly in NumPy, and the batched JAX
    engine / jitted rollout collector trace it with ``xp=jax.numpy`` —
    so deployment and training cannot drift on the math.
    """
    h = xp.tanh(feats @ params["torso1"]["w"] + params["torso1"]["b"])
    h = xp.tanh(h @ params["torso2"]["w"] + params["torso2"]["b"])
    return h @ params["pi"]["w"] + params["pi"]["b"]


def params_to_jsonable(params: dict) -> dict:
    """JAX/NumPy param pytree -> plain nested lists (for JSON)."""
    return {
        name: {k: np.asarray(v).tolist() for k, v in layer.items()}
        for name, layer in params.items()
    }


def save_policy_params(params: dict, path: str = DEFAULT_CHECKPOINT, *,
                       meta: Optional[dict] = None,
                       rate_scale: float = 100.0,
                       fleet_scale: float = 10.0) -> str:
    """Persist params + the feature-normalization constants the policy
    was trained with (a controller deployed with mismatched observation
    scales silently degrades)."""
    meta = dict(meta or {})
    meta.setdefault("rate_scale", rate_scale)
    meta.setdefault("fleet_scale", fleet_scale)
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"params": params_to_jsonable(params), "meta": meta}, f)
    return path


def load_policy_checkpoint(
    path: str = DEFAULT_CHECKPOINT,
) -> Tuple[Optional[dict], dict]:
    """Load ``(params, meta)`` — params as float64 arrays, None when absent."""
    path = os.path.abspath(path)
    if not os.path.exists(path):
        return None, {}
    with open(path) as f:
        payload = json.load(f)
    params = {
        name: {k: np.asarray(v, dtype=np.float64) for k, v in layer.items()}
        for name, layer in payload["params"].items()
    }
    return params, payload.get("meta", {})


def load_policy_params(path: str = DEFAULT_CHECKPOINT) -> Optional[dict]:
    """Params-only form of :func:`load_policy_checkpoint`."""
    return load_policy_checkpoint(path)[0]


def _fallback_params(seed: int = 0) -> dict:
    """Seeded random init matching the PPO net's shapes/scales."""
    rng = np.random.default_rng(seed)
    h = 64

    def lin(i, o, scale):
        return {
            "w": scale * rng.standard_normal((i, o)) / np.sqrt(i),
            "b": np.zeros(o),
        }

    return {
        "torso1": lin(OBS_DIM, h, 1.0),
        "torso2": lin(h, h, 1.0),
        "pi": lin(h, N_ACTIONS, 0.01),
        "v": lin(h, 1, 1.0),
    }


@dataclass
class RLPoolPolicy:
    """PPO pool controller behind the vectorized scheduler interface.

    ``params`` may be passed directly (fresh from ``train_ppo_pool``);
    otherwise the default checkpoint is loaded, falling back to a seeded
    random net.  Action selection is stochastic by default — that is
    the trained object (the policy hedges between procurement modes
    tick-by-tick) — but seeded, so every run of a benchmark cell is
    reproducible; ``greedy=True`` argmax-collapses it.
    """

    vectorized = True

    params: Optional[dict] = None
    checkpoint: str = DEFAULT_CHECKPOINT
    greedy: bool = False
    seed: int = 0
    trained: bool = field(default=False, init=False)
    _rng: np.random.Generator = field(default=None, init=False, repr=False)
    _prev_rate: Optional[np.ndarray] = field(default=None, init=False, repr=False)

    # feature normalization — must match the training env's EnvConfig
    rate_scale: float = 100.0
    fleet_scale: float = 10.0

    def __post_init__(self):
        if self.params is None:
            self.params, meta = load_policy_checkpoint(self.checkpoint)
            if self.params is not None and (
                self.params["torso1"]["w"].shape[0] != OBS_DIM
                or self.params["pi"]["w"].shape[1] != N_ACTIONS
            ):
                # a checkpoint trained under an older obs/action space
                # (e.g. pre-variant-head) cannot drive this policy
                warnings.warn(
                    f"RLPoolPolicy: checkpoint at {self.checkpoint!r} is "
                    f"STALE (obs {self.params['torso1']['w'].shape[0]} vs "
                    f"{OBS_DIM}, actions {self.params['pi']['w'].shape[1]} "
                    f"vs {N_ACTIONS}); falling back to seeded random "
                    "(UNTRAINED) weights — re-run `python -m benchmarks.run "
                    "--only rl` to retrain",
                    stacklevel=2,
                )
                self.params = None
            elif self.params is None:
                warnings.warn(
                    f"RLPoolPolicy: no checkpoint at {self.checkpoint!r}; "
                    "falling back to seeded random (UNTRAINED) weights — "
                    "run `python -m benchmarks.run --only rl` to train and "
                    "publish one",
                    stacklevel=2,
                )
            self.trained = self.params is not None
            if self.params is None:
                self.params = _fallback_params(self.seed)
            else:
                # deploy with the normalization the checkpoint trained under
                self.rate_scale = float(meta.get("rate_scale", self.rate_scale))
                self.fleet_scale = float(
                    meta.get("fleet_scale", self.fleet_scale)
                )
        else:
            self.params = {
                name: {k: np.asarray(v, dtype=np.float64)
                       for k, v in layer.items()}
                for name, layer in self.params.items()
            }
            self.trained = True
        assert set(self.params) == set(_LAYERS), sorted(self.params)
        self._rng = np.random.default_rng(self.seed)

    # -- inference ---------------------------------------------------------
    def logits(self, feats: np.ndarray) -> np.ndarray:
        return policy_logits(self.params, feats)

    def _select(self, logits: np.ndarray) -> np.ndarray:
        if self.greedy:
            return logits.argmax(axis=-1)
        # Gumbel-max: one vectorized categorical draw per arch row
        g = self._rng.gumbel(size=logits.shape)
        return (logits + g).argmax(axis=-1)

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        if tick == 0:
            # episode boundary: a reused policy instance must behave like a
            # fresh one (reproducible runs, trend feature restarts at 0)
            self._rng = np.random.default_rng(self.seed)
            self._prev_rate = None
        if self._prev_rate is None or len(self._prev_rate) != len(obs.keys):
            self._prev_rate = obs.rate.copy()       # trend feature = 0
        feats = pool_features(
            obs, self._prev_rate,
            rate_scale=self.rate_scale, fleet_scale=self.fleet_scale,
        )
        self._prev_rate = obs.rate.copy()
        return procurement_action(obs, self._select(self.logits(feats)))
