"""The control subsystem: the serving simulator as an RL problem.

  obs     — [A, OBS_DIM] feature construction + the factored per-arch
            action space (NumPy-only, shared by env and deployed policy)
  env     — PoolServingEnv (pool-wide, SoA, per-arch reward
            decomposition) and the single-arch ServingEnv wrapper
  ppo     — batched pool PPO in JAX ([T, A] rollouts, GAE over [T, A],
            jitted minibatch updates over the flattened batch)
  policy  — RLPoolPolicy: the trained controller as a ``vectorized``
            scheduler (registered in ``VECTOR_SCHEDULERS["rl_pool"]``)

The training half (``ppo``) is the only JAX dependency; its exports are
loaded lazily so that importing the package — which the classical
schedulers do to register ``rl_pool`` — stays NumPy-only.
"""
from repro.core.rl.env import (  # noqa: F401
    EnvConfig,
    PoolServingEnv,
    ServingEnv,
)
from repro.core.rl.obs import (  # noqa: F401
    HEADROOMS,
    N_ACTIONS,
    N_PROCURE,
    OBS_DIM,
    OFFLOADS,
    SPOT_MOVES,
    VARIANT_MOVES,
    decode_actions,
    pool_features,
    procurement_action,
    spot_targets,
    variant_targets,
)
from repro.core.rl.policy import (  # noqa: F401
    DEFAULT_CHECKPOINT,
    RLPoolPolicy,
    load_policy_params,
    save_policy_params,
)

#: lazily resolved from :mod:`repro.core.rl.ppo` (pulls in JAX)
_PPO_EXPORTS = (
    "PPOConfig",
    "PPOState",
    "evaluate_policy",
    "evaluate_pool_policy",
    "policy_action",
    "pool_policy_action",
    "train_ppo",
    "train_ppo_pool",
)


def __getattr__(name: str):
    if name in _PPO_EXPORTS:
        from repro.core.rl import ppo

        return getattr(ppo, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_PPO_EXPORTS))
