from repro.core.rl.env import ServingEnv, EnvConfig  # noqa: F401
from repro.core.rl.ppo import PPOConfig, PPOState, train_ppo, policy_action  # noqa: F401
