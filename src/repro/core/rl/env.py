"""The serving simulator as an RL environment (paper §V, Figure 10).

The agent observes the system state o_i, takes action a_i (a joint
procurement decision: fleet delta x offload mode), reaches actual state
f_{i+1}, and receives a transition reward blending the paper's reward
policies: cost, response latency (violations), and utilization.

Observation (per tick, single-arch fleet, normalized):
  [rate, ewma, peak/median, queue_strict, queue_relaxed,
   n_active, n_pending, utilization, trend]

Workloads: a fixed trace (seed behavior) or a pool of
:class:`~repro.core.workloads.Scenario` specs sampled per episode, so
the controller generalizes across heterogeneous load shapes instead of
overfitting one arrival sequence.

Action space (discrete, 4 headrooms x 3 offload modes = 12):
  headroom in {0.85, 1.0, 1.15, 1.4} — reserved target is
      ceil(headroom x demand / per-instance-throughput), where demand
      includes the queued backlog.  Bounded action -> stable credit
      assignment despite the 120 s provisioning lag (the paper's "adjusts
      its policy as long as it is within the desired policy target range").
  offload in {none, blind, slack_aware}
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import PRICING, FleetPricing
from repro.core.sim import Action, ArchLoad, ServingSim
from repro.core.workloads import Scenario

HEADROOMS = (0.85, 1.0, 1.15, 1.4)
OFFLOADS = ("none", "blind", "slack_aware")
N_ACTIONS = len(HEADROOMS) * len(OFFLOADS)
OBS_DIM = 10


@dataclass(frozen=True)
class EnvConfig:
    arch: str = "llama3-8b"
    strict_frac: float = 0.25
    mean_rps: float = 60.0
    duration_s: int = 1200
    violation_penalty: float = 0.005      # $ equivalent per violated request
    reward_scale: float = 100.0           # keep per-tick rewards O(0.1)
    pricing: FleetPricing = PRICING
    rate_scale: float = 100.0             # normalization constants
    fleet_scale: float = 10.0


class ServingEnv:
    """Gym-like wrapper over :class:`ServingSim` for a single-arch fleet.

    Two workload sources:

    * a fixed ``trace`` — every episode replays the same arrivals (the
      seed behavior, still what the deterministic eval harness wants);
    * ``scenarios`` — a pool of :class:`~repro.core.workloads.Scenario`
      specs; each ``reset()`` samples one and builds a *fresh seeded
      realization* of it, so the controller trains across heterogeneous
      load shapes instead of memorizing one trace.  Sampling is driven
      by ``scenario_seed`` and an episode counter: deterministic overall,
      different every episode.
    """

    def __init__(self, cfg: EnvConfig, trace: Optional[np.ndarray] = None, *,
                 scenarios: Optional[Sequence[Scenario]] = None,
                 scenario_seed: int = 0):
        assert trace is not None or scenarios, (
            "ServingEnv needs a fixed trace or a scenario pool"
        )
        self.cfg = cfg
        self.base_trace = trace
        self.scenarios = tuple(scenarios) if scenarios else ()
        self._scenario_rng = np.random.default_rng(scenario_seed)
        self._episode = 0
        self.last_scenario: Optional[Scenario] = None
        self.sim: Optional[ServingSim] = None
        self._target = 1
        self._prev_rate = 0.0
        self._last_violations = 0.0

    # ------------------------------------------------------------------
    def _sample_arrivals(self) -> np.ndarray:
        """One episode's arrivals: ``[1, T]`` from a sampled scenario."""
        sc = self.scenarios[self._scenario_rng.integers(len(self.scenarios))]
        self.last_scenario = sc
        self._episode += 1
        return sc.build(
            1,
            seed=sc.seed + self._episode,
            duration_s=self.cfg.duration_s,
            mean_rps=self.cfg.mean_rps,
        )

    def reset(self, trace: Optional[np.ndarray] = None) -> np.ndarray:
        if trace is not None:
            tr = trace
        elif self.scenarios:
            tr = self._sample_arrivals()
        else:
            tr = self.base_trace
        self.sim = ServingSim(
            tr,
            [ArchLoad(self.cfg.arch, 1.0, self.cfg.strict_frac)],
            pricing=self.cfg.pricing,
        )
        st = next(iter(self.sim.states.values()))
        self._target = st.n_active
        arr = np.asarray(tr, dtype=np.float64)
        self._prev_rate = float(arr[:, 0].sum() if arr.ndim == 2 else arr[0])
        self._last_violations = 0.0
        return self._obs_vector(self.sim.observe())

    def _obs_vector(self, obs_dict) -> np.ndarray:
        o = obs_dict[self.cfg.arch]
        st = self.sim.states[self.cfg.arch]
        rs, fs = self.cfg.rate_scale, self.cfg.fleet_scale
        vec = np.array(
            [
                o.rate / rs,
                o.ewma_rate / rs,
                min(o.peak_to_median, 5.0) / 5.0,
                st.queues["strict"].total / rs,
                st.queues["relaxed"].total / rs,
                o.n_active / fs,
                o.n_pending / fs,
                min(o.utilization, 2.0) / 2.0,
                (o.rate - self._prev_rate) / rs,
                self._last_violations / rs,
            ],
            dtype=np.float32,
        )
        self._prev_rate = o.rate
        return vec

    # ------------------------------------------------------------------
    def step(self, action: int) -> Tuple[np.ndarray, float, bool, dict]:
        assert self.sim is not None, "call reset() first"
        headroom = HEADROOMS[action // len(OFFLOADS)]
        offload = OFFLOADS[action % len(OFFLOADS)]
        st = self.sim.states[self.cfg.arch]
        backlog = st.queues["strict"].total + st.queues["relaxed"].total
        demand = st.monitor.rate + backlog / 5.0
        self._target = max(1, math.ceil(headroom * demand / st.throughput))
        metrics = self.sim.apply(
            {self.cfg.arch: Action(target=self._target, offload=offload)}
        )
        self._last_violations = metrics["violations"]
        reward = -self.cfg.reward_scale * (
            metrics["cost"] + self.cfg.violation_penalty * metrics["violations"]
        )
        done = self.sim.done
        obs = (
            np.zeros(OBS_DIM, dtype=np.float32)
            if done
            else self._obs_vector(self.sim.observe())
        )
        return obs, float(reward), done, metrics

    # ------------------------------------------------------------------
    def episode_result(self):
        return self.sim.res
