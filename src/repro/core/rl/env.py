"""The serving simulator as an RL environment (paper §V, Figure 10).

The agent observes the system state o_i, takes action a_i (a joint
procurement decision: fleet headroom x offload mode), reaches actual
state f_{i+1}, and receives a transition reward blending the paper's
reward policies: cost, response latency (violations), and utilization.

:class:`PoolServingEnv` is the pool-wide form the paper's end state
needs — one controller managing the *whole* heterogeneous pool:

* observations are structure-of-arrays ``[A, OBS_DIM]`` built straight
  from the engine's :class:`~repro.core.sim.PoolObs` (no per-arch dict
  construction anywhere on the rollout path);
* the action is factored per arch — every row picks one of
  ``N_ACTIONS`` (headroom x offload) decisions, so a policy whose
  parameters are applied row-wise controls any pool size;
* the reward is *decomposed per arch* from the engine's per-arch cost
  attribution and violation counts: ``step`` returns an ``[A]`` reward
  vector whose sum is the scalar pool reward, giving PPO per-arch
  credit assignment;
* episodes are driven by ``[A, T]`` arrival matrices — a fixed matrix,
  or a pool of :class:`~repro.core.workloads.Scenario` specs sampled
  per episode (fresh seeded realization each reset) so the controller
  trains across heterogeneous load shapes instead of memorizing one
  trace.

:class:`ServingEnv` is kept as a thin single-arch compatibility wrapper
(A=1, scalar reward, flat observation) — the seed-era interface the
existing tests and examples drive.

Action space per arch (discrete, 4 headrooms x 3 offload modes x 3
variant moves x 3 spot moves = 108):
  headroom in {0.85, 1.0, 1.15, 1.4} — reserved target is
      ceil(headroom x demand / per-instance-throughput), where demand
      includes the queued backlog and the targeted spot fleet's
      capacity offsets it.  Bounded action -> stable credit assignment
      despite the 120 s provisioning lag (the paper's "adjusts its
      policy as long as it is within the desired policy target range").
  offload in {none, blind, slack_aware}
  variant move in {hold, down, up} along the accuracy-ordered set
  spot move in {hold, grow, shrink} — steps the preemptible spot fleet
      (§VI resource heterogeneity); hold-first, so legacy action
      indices decode unchanged
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import PRICING, FleetPricing
from repro.core.rl.obs import (  # noqa: F401  (re-exported seed surface)
    HEADROOMS,
    N_ACTIONS,
    N_PROCURE,
    OBS_DIM,
    OFFLOADS,
    SPOT_MOVES,
    VARIANT_MOVES,
    pool_features,
    procurement_action,
)
from repro.core.sim import ArchLoad, ServingSim, VariantCatalog
from repro.core.workloads import Scenario


@dataclass(frozen=True)
class EnvConfig:
    arch: str = "llama3-8b"
    strict_frac: float = 0.25
    mean_rps: float = 60.0
    duration_s: int = 1200
    violation_penalty: float = 0.005      # $ equivalent per violated request
    accuracy_bonus: float = 0.0           # $ credit per answered request x
                                          # delivered accuracy — what makes
                                          # the variant head trade accuracy
                                          # against cost (0 = cost/SLO only)
    reward_scale: float = 100.0           # keep per-tick rewards O(0.1)
    pricing: FleetPricing = PRICING
    rate_scale: float = 100.0             # normalization constants
    fleet_scale: float = 10.0


class PoolServingEnv:
    """Pool-wide gym-like wrapper over :class:`ServingSim`.

    Three workload sources, in precedence order per ``reset``:

    * an explicit ``arrivals`` matrix passed to ``reset`` (eval runs);
    * ``scenarios`` — a pool of :class:`~repro.core.workloads.Scenario`
      specs; each ``reset()`` samples one and builds a fresh seeded
      ``[A, T]`` realization (sampling driven by ``scenario_seed`` and
      an episode counter: deterministic overall, different every
      episode);
    * the fixed ``arrivals`` the env was constructed with.

    ``step`` takes an ``[A]`` integer action vector and returns
    ``(obs [A, OBS_DIM], reward_arch [A], done, metrics)``; the scalar
    pool reward is ``reward_arch.sum()``.
    """

    def __init__(self, workload: Sequence[ArchLoad], cfg: EnvConfig = EnvConfig(),
                 arrivals: Optional[np.ndarray] = None, *,
                 scenarios: Optional[Sequence[Scenario]] = None,
                 scenario_seed: int = 0,
                 catalog: Optional[VariantCatalog] = None,
                 telemetry=None):
        assert arrivals is not None or scenarios, (
            "PoolServingEnv needs a fixed arrival matrix or a scenario pool"
        )
        self.workload: List[ArchLoad] = list(workload)
        self.n_archs = len(self.workload)
        self.cfg = cfg
        self.catalog = catalog         # opens the variant head's state space
        self.telemetry = telemetry     # rebound to the fresh sim each reset
        self.base_arrivals = arrivals
        self.scenarios = tuple(scenarios) if scenarios else ()
        self._scenario_rng = np.random.default_rng(scenario_seed)
        self._episode = 0
        self.last_scenario: Optional[Scenario] = None
        self.sim: Optional[ServingSim] = None
        self._prev_rate = np.zeros(self.n_archs)
        self._pobs = None

    # ------------------------------------------------------------------
    def _sample_arrivals(self) -> np.ndarray:
        """One episode's arrivals: ``[A, T]`` from a sampled scenario."""
        sc = self.scenarios[self._scenario_rng.integers(len(self.scenarios))]
        self.last_scenario = sc
        self._episode += 1
        return sc.build(
            self.n_archs,
            seed=sc.seed + self._episode,
            duration_s=self.cfg.duration_s,
            mean_rps=self.cfg.mean_rps,
        )

    def reset(self, arrivals: Optional[np.ndarray] = None) -> np.ndarray:
        if arrivals is not None:
            tr = arrivals
        elif self.scenarios:
            tr = self._sample_arrivals()
        else:
            tr = self.base_arrivals
        # per-episode sim seed: tier-internal draws (spot reclaims, the
        # harvest signal) are a pure function of (seed, tick), so a
        # fixed seed would replay the *same* stochastic realization
        # every episode and the policy would overfit to it; scenario
        # training advances the seed with the episode counter (fixed-
        # arrival envs keep seed 0 — eval stays reproducible)
        self.sim = ServingSim(tr, self.workload, pricing=self.cfg.pricing,
                              catalog=self.catalog, seed=self._episode,
                              telemetry=self.telemetry)
        return self._observe(first=True)

    def _observe(self, first: bool = False) -> np.ndarray:
        self._pobs = self.sim.observe_pool()
        if first:
            self._prev_rate = self._pobs.rate.copy()   # trend feature = 0
        feats = pool_features(
            self._pobs, self._prev_rate,
            rate_scale=self.cfg.rate_scale, fleet_scale=self.cfg.fleet_scale,
        )
        self._prev_rate = self._pobs.rate.copy()
        return feats

    # ------------------------------------------------------------------
    def step(self, actions: np.ndarray) -> Tuple[np.ndarray, np.ndarray, bool, dict]:
        """Apply per-arch factored actions; rewards decomposed per arch."""
        assert self.sim is not None, "call reset() first"
        metrics = self.sim.apply_pool(procurement_action(self._pobs, actions))
        reward_arch = -self.cfg.reward_scale * (
            metrics["cost_arch"]
            + self.cfg.violation_penalty * metrics["violations_arch"]
            - self.cfg.accuracy_bonus * metrics["accuracy_arch"]
        )
        done = self.sim.done
        obs = (
            np.zeros((self.n_archs, OBS_DIM), dtype=np.float32)
            if done else self._observe()
        )
        return obs, reward_arch, done, metrics

    # ------------------------------------------------------------------
    def episode_result(self):
        return self.sim.res


class ServingEnv:
    """Single-arch compatibility wrapper: the seed-era interface.

    A thin A=1 view over :class:`PoolServingEnv` — flat ``[OBS_DIM]``
    observations, one integer action, scalar reward — preserved so
    stepwise drivers (``train_ppo``, the examples, the seed tests) keep
    working and so the pool refactor stays regression-pinned to the
    pre-refactor episode results.
    """

    def __init__(self, cfg: EnvConfig, trace: Optional[np.ndarray] = None, *,
                 scenarios: Optional[Sequence[Scenario]] = None,
                 scenario_seed: int = 0,
                 catalog: Optional[VariantCatalog] = None,
                 telemetry=None):
        assert trace is not None or scenarios, (
            "ServingEnv needs a fixed trace or a scenario pool"
        )
        self.cfg = cfg
        self.base_trace = trace
        self.pool = PoolServingEnv(
            [ArchLoad(cfg.arch, 1.0, cfg.strict_frac)],
            cfg,
            arrivals=trace,
            scenarios=scenarios,
            scenario_seed=scenario_seed,
            catalog=catalog,
            telemetry=telemetry,
        )

    @property
    def sim(self) -> Optional[ServingSim]:
        return self.pool.sim

    @property
    def scenarios(self):
        return self.pool.scenarios

    @property
    def last_scenario(self) -> Optional[Scenario]:
        return self.pool.last_scenario

    def reset(self, trace: Optional[np.ndarray] = None) -> np.ndarray:
        return self.pool.reset(trace)[0]

    def step(self, action: int) -> Tuple[np.ndarray, float, bool, dict]:
        obs, reward_arch, done, metrics = self.pool.step(
            np.array([action], dtype=np.int64)
        )
        return obs[0], float(reward_arch.sum()), done, metrics

    def episode_result(self):
        return self.pool.episode_result()
