"""Observation features and the factored action space of the pool
controller (paper §V, Figure 10).

One place defines how a :class:`~repro.core.sim.types.PoolObs` becomes
the ``[A, OBS_DIM]`` feature matrix and how a per-arch discrete action
decodes into a procurement decision, so the training environment
(:mod:`repro.core.rl.env`) and the deployable scheduler
(:mod:`repro.core.rl.policy`) can never drift apart.

The action space is *factored per arch*: each row of the pool picks one
of ``N_ACTIONS = len(HEADROOMS) x len(OFFLOADS) x len(VARIANT_MOVES)``
joint (headroom, offload-mode, variant-move) decisions, and the policy
torso is applied row-wise — a single parameter set controls a pool of
any size A, which is what lets one trained controller generalize across
pool compositions.  The variant head is the model-heterogeneity half of
the paper's joint decision space: ``down`` / ``hold`` / ``up`` steps
along the arch's accuracy-ordered variant set (``hold`` first, so the
``N_PROCURE`` legacy actions ``0 .. 11`` decode exactly as the
pre-variant space did).

Everything here is NumPy-only (no JAX): the scheduler registered in
``VECTOR_SCHEDULERS`` runs inside the engine's hot tick loop.
"""
from __future__ import annotations

import numpy as np

from repro.core.sim import PoolAction, PoolObs

#: reserved-fleet headroom over smoothed demand (bounded action -> stable
#: credit assignment despite the provisioning lag)
HEADROOMS = (0.85, 1.0, 1.15, 1.4)
#: offload modes, index-aligned with ``repro.core.sim.OFFLOAD_MODES``
OFFLOADS = ("none", "blind", "slack_aware")
#: the variant head: hold-first so actions < N_PROCURE are the legacy space
VARIANT_MOVES = ("hold", "down", "up")
N_PROCURE = len(HEADROOMS) * len(OFFLOADS)
N_ACTIONS = N_PROCURE * len(VARIANT_MOVES)
OBS_DIM = 12

#: queued backlog is assumed drainable over this horizon when sizing the
#: reserved fleet (same knob the Paragon scheduler uses)
BACKLOG_DRAIN_S = 5.0

_HEADROOM_ARR = np.asarray(HEADROOMS, dtype=np.float64)
#: VARIANT_MOVES index -> signed step along the variant set
_VMOVE_DELTA = np.array([0, -1, 1], dtype=np.int64)


def pool_features(obs: PoolObs, prev_rate: np.ndarray, *,
                  rate_scale: float, fleet_scale: float) -> np.ndarray:
    """``[A, OBS_DIM]`` float32 feature matrix for one tick.

    Row ``a`` holds arch ``a``'s normalized load / fleet / feedback
    state plus the variant axis: the active variant's position in the
    arch's ordered set and the accuracy headroom over the stream's
    floor.  ``prev_rate`` is the caller-held previous-tick rate used for
    the trend feature.
    """
    rs, fs = rate_scale, fleet_scale
    f = np.empty((len(obs.keys), OBS_DIM), dtype=np.float32)
    f[:, 0] = obs.rate / rs
    f[:, 1] = obs.ewma_rate / rs
    f[:, 2] = np.minimum(obs.peak_to_median, 5.0) / 5.0
    f[:, 3] = obs.queue_strict / rs
    f[:, 4] = obs.queue_relaxed / rs
    f[:, 5] = obs.n_active / fs
    f[:, 6] = obs.n_pending / fs
    f[:, 7] = np.minimum(obs.utilization, 2.0) / 2.0
    f[:, 8] = (obs.rate - prev_rate) / rs
    f[:, 9] = obs.last_violations / rs
    f[:, 10] = obs.active_variant / np.maximum(obs.n_variants - 1, 1)
    f[:, 11] = np.clip(obs.accuracy - obs.accuracy_floor, 0.0, 1.0)
    return f


def decode_actions(actions: np.ndarray) -> tuple:
    """Split per-arch discrete actions into ``(headroom[A], offload[A],
    vmove[A])``.

    ``offload`` comes back as the engine's integer codes (``OFFLOADS``
    is index-aligned with ``OFFLOAD_MODES``); ``vmove`` is the signed
    variant step in ``{-1, 0, +1}``.
    """
    actions = np.asarray(actions, dtype=np.int64)
    proc = actions % N_PROCURE
    vmove = _VMOVE_DELTA[actions // N_PROCURE]
    return _HEADROOM_ARR[proc // len(OFFLOADS)], proc % len(OFFLOADS), vmove


def variant_targets(obs: PoolObs, vmove: np.ndarray) -> np.ndarray:
    """Signed variant steps -> engine ``variant_target`` codes.

    Steps are clipped to the arch's variant range; a step that lands on
    the active variant (hold, or a clipped edge move) becomes the
    engine's hold code (-1).
    """
    tgt = np.clip(obs.active_variant + vmove, 0, obs.n_variants - 1)
    return np.where(tgt == obs.active_variant, -1, tgt).astype(np.int64)


def procurement_action(obs: PoolObs, actions: np.ndarray) -> PoolAction:
    """Decode factored actions into the engine's :class:`PoolAction`.

    The reserved target is ``ceil(headroom x demand / throughput)`` with
    demand = smoothed rate + queued backlog drained over
    ``BACKLOG_DRAIN_S`` — the same sizing rule the legacy single-arch
    env applied per arch.  ``throughput`` is the ACTIVE variant's, so
    fleet sizing and variant choice stay coupled.
    """
    headroom, offload, vmove = decode_actions(actions)
    backlog = obs.queue_strict + obs.queue_relaxed
    demand = obs.ewma_rate + backlog / BACKLOG_DRAIN_S
    target = np.maximum(
        1, np.ceil(headroom * demand / obs.throughput)
    ).astype(np.int64)
    return PoolAction(target=target, offload=offload,
                      variant_target=variant_targets(obs, vmove))
