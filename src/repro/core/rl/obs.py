"""Observation features and the factored action space of the pool
controller (paper §V, Figure 10).

One place defines how a :class:`~repro.core.sim.types.PoolObs` becomes
the ``[A, OBS_DIM]`` feature matrix and how a per-arch discrete action
decodes into a procurement decision, so the training environment
(:mod:`repro.core.rl.env`) and the deployable scheduler
(:mod:`repro.core.rl.policy`) can never drift apart.

The action space is *factored per arch*: each row of the pool picks one
of ``N_ACTIONS = len(HEADROOMS) x len(OFFLOADS) x len(VARIANT_MOVES) x
len(SPOT_MOVES)`` joint (headroom, offload-mode, variant-move,
spot-move) decisions, and the policy torso is applied row-wise — a
single parameter set controls a pool of any size A, which is what lets
one trained controller generalize across pool compositions.  The
variant head is the model-heterogeneity half of the paper's joint
decision space: ``down`` / ``hold`` / ``up`` steps along the arch's
accuracy-ordered variant set.  The spot head is the
resource-heterogeneity half (§VI): ``grow`` / ``hold`` / ``shrink``
steps the arch's preemptible spot fleet, whose capacity then *offsets*
the reserved sizing rule — the controller can shift base load onto
discounted slices instead of only resizing the on-demand fleet.  Both
heads are hold-first, so the ``N_PROCURE`` legacy actions ``0 .. 11``
(and the pre-spot actions ``0 .. 35``) decode exactly as the earlier
spaces did.

Everything here defaults to NumPy (the scheduler registered in
``VECTOR_SCHEDULERS`` runs inside the engine's hot tick loop and must
not pay a JAX import), but the feature build and the action decode also
come in backend-parametric ``*_arrays`` forms (``xp`` = ``numpy`` or
``jax.numpy``) so the batched engine (``sim/jax_engine.py``) and the
jitted rollout collector trace the *same expressions* inside
``lax.scan`` — no jax import happens here; the backend is passed in.
"""
from __future__ import annotations

import numpy as np

from repro.core.sim import PoolAction, PoolObs

#: reserved-fleet headroom over smoothed demand (bounded action -> stable
#: credit assignment despite the provisioning lag)
HEADROOMS = (0.85, 1.0, 1.15, 1.4)
#: offload modes, index-aligned with ``repro.core.sim.OFFLOAD_MODES``
OFFLOADS = ("none", "blind", "slack_aware")
#: the variant head: hold-first so actions < N_PROCURE are the legacy space
VARIANT_MOVES = ("hold", "down", "up")
#: the spot head: hold-first so actions < N_PROCURE * len(VARIANT_MOVES)
#: are the pre-spot space (hold keeps the current spot fleet, which is 0
#: until the controller ever grows it — identical to the legacy decode)
SPOT_MOVES = ("hold", "grow", "shrink")
N_PROCURE = len(HEADROOMS) * len(OFFLOADS)
N_VARIANT_SPACE = N_PROCURE * len(VARIANT_MOVES)
N_ACTIONS = N_VARIANT_SPACE * len(SPOT_MOVES)
OBS_DIM = 16

#: queued backlog is assumed drainable over this horizon when sizing the
#: reserved fleet (same knob the Paragon scheduler uses)
BACKLOG_DRAIN_S = 5.0
#: feature scaling for the (tiny) per-tick spot reclaim probability
RISK_SCALE = 600.0

_HEADROOM_ARR = np.asarray(HEADROOMS, dtype=np.float64)
#: VARIANT_MOVES index -> signed step along the variant set
_VMOVE_DELTA = np.array([0, -1, 1], dtype=np.int64)
#: SPOT_MOVES index -> signed per-tick step of the spot fleet
_SMOVE_DELTA = np.array([0, 1, -1], dtype=np.int64)


def pool_features(obs: PoolObs, prev_rate: np.ndarray, *,
                  rate_scale: float, fleet_scale: float) -> np.ndarray:
    """``[A, OBS_DIM]`` float32 feature matrix for one tick.

    Row ``a`` holds arch ``a``'s normalized load / fleet / feedback
    state plus the variant axis (the active variant's position in the
    arch's ordered set, the accuracy headroom over the stream's floor)
    and the spot-tier state the spot head steers by (held / in-flight
    spot instances, reclaim risk, harvest availability).  ``prev_rate``
    is the caller-held previous-tick rate used for the trend feature.
    """
    rs, fs = rate_scale, fleet_scale
    f = np.empty((len(obs.keys), OBS_DIM), dtype=np.float32)
    f[:, 0] = obs.rate / rs
    f[:, 1] = obs.ewma_rate / rs
    f[:, 2] = np.minimum(obs.peak_to_median, 5.0) / 5.0
    f[:, 3] = obs.queue_strict / rs
    f[:, 4] = obs.queue_relaxed / rs
    f[:, 5] = obs.n_active / fs
    f[:, 6] = obs.n_pending / fs
    f[:, 7] = np.minimum(obs.utilization, 2.0) / 2.0
    f[:, 8] = (obs.rate - prev_rate) / rs
    f[:, 9] = obs.last_violations / rs
    f[:, 10] = obs.active_variant / np.maximum(obs.n_variants - 1, 1)
    f[:, 11] = np.clip(obs.accuracy - obs.accuracy_floor, 0.0, 1.0)
    f[:, 12] = obs.n_spot / fs
    f[:, 13] = obs.n_spot_pending / fs
    f[:, 14] = np.minimum(obs.spot_reclaim_risk * RISK_SCALE, 1.0)
    f[:, 15] = obs.harvest_level
    return f


def pool_features_arrays(o, prev_rate, *, rate_scale: float,
                         fleet_scale: float, xp=np):
    """Backend-parametric twin of :func:`pool_features`.

    ``o`` maps :class:`PoolObs` field names to ``[A]`` arrays (every
    field materialized per arch — scalars like ``spot_reclaim_risk``
    broadcast by the caller).  Column order and scaling are pinned to
    :func:`pool_features`; ``tests/test_jax_engine.py`` asserts the two
    builds agree elementwise.
    """
    rs, fs = rate_scale, fleet_scale
    cols = [
        o["rate"] / rs,
        o["ewma_rate"] / rs,
        xp.minimum(o["peak_to_median"], 5.0) / 5.0,
        o["queue_strict"] / rs,
        o["queue_relaxed"] / rs,
        o["n_active"] / fs,
        o["n_pending"] / fs,
        xp.minimum(o["utilization"], 2.0) / 2.0,
        (o["rate"] - prev_rate) / rs,
        o["last_violations"] / rs,
        o["active_variant"] / xp.maximum(o["n_variants"] - 1, 1),
        xp.clip(o["accuracy"] - o["accuracy_floor"], 0.0, 1.0),
        o["n_spot"] / fs,
        o["n_spot_pending"] / fs,
        xp.minimum(o["spot_reclaim_risk"] * RISK_SCALE, 1.0),
        o["harvest_level"],
    ]
    return xp.stack(cols, axis=1).astype(xp.float32)


def decode_actions_arrays(actions, xp=np) -> tuple:
    """Backend-parametric core of :func:`decode_actions` (``actions``
    already an integer array of the backend's kind)."""
    smove = xp.asarray(_SMOVE_DELTA)[actions // N_VARIANT_SPACE]
    rest = actions % N_VARIANT_SPACE
    proc = rest % N_PROCURE
    vmove = xp.asarray(_VMOVE_DELTA)[rest // N_PROCURE]
    headroom = xp.asarray(_HEADROOM_ARR)[proc // len(OFFLOADS)]
    return headroom, proc % len(OFFLOADS), vmove, smove


def decode_actions(actions: np.ndarray) -> tuple:
    """Split per-arch discrete actions into ``(headroom[A], offload[A],
    vmove[A], smove[A])``.

    ``offload`` comes back as the engine's integer codes (``OFFLOADS``
    is index-aligned with ``OFFLOAD_MODES``); ``vmove`` is the signed
    variant step and ``smove`` the signed spot-fleet step, both in
    ``{-1, 0, +1}``.
    """
    return decode_actions_arrays(np.asarray(actions, dtype=np.int64))


def variant_targets_arrays(active_variant, n_variants, vmove, xp=np):
    """Backend-parametric core of :func:`variant_targets`: signed steps
    clipped to the arch's variant range, hold (-1) where the step lands
    on the active variant — the expression the in-scan RL decode
    (``sim/jax_engine.py``) traces so the variant head acts identically
    in rollout collection and deployment."""
    tgt = xp.clip(active_variant + vmove, 0, n_variants - 1)
    return xp.where(tgt == active_variant, -1, tgt).astype(xp.int64)


def variant_targets(obs: PoolObs, vmove: np.ndarray) -> np.ndarray:
    """Signed variant steps -> engine ``variant_target`` codes.

    Steps are clipped to the arch's variant range; a step that lands on
    the active variant (hold, or a clipped edge move) becomes the
    engine's hold code (-1).
    """
    return variant_targets_arrays(obs.active_variant, obs.n_variants, vmove)


def spot_targets(obs: PoolObs, smove: np.ndarray) -> np.ndarray:
    """Signed spot steps -> engine ``spot_target`` instance counts.

    ``hold`` MAINTAINS the observed in-flight spot fleet (active +
    provisioning): instances reclaimed since the observation are
    re-launched toward the same size, so hold means "auto-heal at this
    level" and ``shrink`` is the only way the fleet decays — while a
    fleet that was never grown stays at 0, which is what keeps the
    legacy (pre-spot) action decode unchanged.  ``grow`` / ``shrink``
    step the level by one instance per tick (60 instances/min against a
    120 s provisioning pipeline), clipped at 0.
    """
    keep = obs.n_spot + obs.n_spot_pending
    return np.maximum(keep + smove, 0).astype(np.int64)


def procurement_targets_arrays(actions, *, ewma_rate, queue_strict,
                               queue_relaxed, throughput, n_spot,
                               n_spot_pending, xp=np) -> tuple:
    """Backend-parametric procurement decode: factored actions -> the
    ``(target, offload, spot, vmove)`` arrays behind
    :func:`procurement_action` (the variant step comes back raw — variant
    clipping needs the catalog fields the caller holds)."""
    headroom, offload, vmove, smove = decode_actions_arrays(actions, xp=xp)
    spot = xp.maximum(n_spot + n_spot_pending + smove, 0).astype(xp.int64)
    backlog = queue_strict + queue_relaxed
    demand = ewma_rate + backlog / BACKLOG_DRAIN_S
    residual = headroom * demand - spot * throughput
    target = xp.maximum(1, xp.ceil(residual / throughput)).astype(xp.int64)
    return target, offload, spot, vmove


def procurement_action(obs: PoolObs, actions: np.ndarray) -> PoolAction:
    """Decode factored actions into the engine's :class:`PoolAction`.

    The reserved target is ``ceil(headroom x demand / throughput)`` with
    demand = smoothed rate + queued backlog drained over
    ``BACKLOG_DRAIN_S`` — the same sizing rule the legacy single-arch
    env applied per arch — *minus the capacity of the targeted spot
    fleet*: spot instances substitute for reserved ones rather than
    stack on top, which is what makes the spot head a cost lever (at
    zero spot the rule is exactly the legacy one).  ``throughput`` is
    the ACTIVE variant's, so fleet sizing and variant choice stay
    coupled.
    """
    target, offload, spot, vmove = procurement_targets_arrays(
        np.asarray(actions, dtype=np.int64),
        ewma_rate=obs.ewma_rate, queue_strict=obs.queue_strict,
        queue_relaxed=obs.queue_relaxed, throughput=obs.throughput,
        n_spot=obs.n_spot, n_spot_pending=obs.n_spot_pending,
    )
    return PoolAction(target=target, offload=offload,
                      spot_target=spot,
                      variant_target=variant_targets(obs, vmove))
