"""Proximal Policy Optimization in pure JAX (paper §V), pool-wide.

The paper sketches a PPO controller with the clipped surrogate
L(theta) = E_t[min(r_t A_t, clip(r_t, 1-eps, 1+eps) A_t)] over scheduling
decisions; we implement the full loop over the *whole serving pool*:

* a shared MLP torso with policy+value heads, applied **per arch row**
  (the factored action space of :mod:`repro.core.rl.obs`) — the same
  parameters control any pool size, and one forward pass over the
  ``[A, OBS_DIM]`` observation matrix prices every arch's action;
* batched rollouts: buffers are ``[T, A, ...]`` arrays filled by the
  vectorized :class:`~repro.core.rl.env.PoolServingEnv`;
* GAE(lambda) computed over ``[T, A]`` reward/value arrays with
  *per-arch credit assignment* — each arch's advantage stream sees its
  own decomposed reward (engine cost attribution + violation counts),
  not the pool average;
* jitted minibatched clipped updates with Adam over the flattened
  ``[T*A, OBS_DIM]`` batch, entropy bonus included.

The single-arch ``train_ppo`` entry point survives as a thin shim: a
legacy :class:`~repro.core.rl.env.ServingEnv` is just the A=1 view of
the pool path.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rl.env import (
    N_ACTIONS,
    OBS_DIM,
    PoolServingEnv,
    ServingEnv,
)
from repro.core.sim import jax_engine
from repro.core.sim.telemetry import JsonlWriter


@dataclass(frozen=True)
class PPOConfig:
    hidden: int = 64
    lr: float = 5e-4
    gamma: float = 0.97
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    epochs: int = 4
    minibatches: int = 8
    rollout_len: int = 1200        # cover a full episode -> every update
                                   # sees flash-crowd segments
    iterations: int = 60
    max_grad_norm: float = 0.5
    seed: int = 0


# ---------------------------------------------------------------------------
# Networks.  The torso maps one arch's feature row to logits/value; JAX
# broadcasting applies it to [A, F] (a pool tick) and [N, F] (an update
# minibatch) alike — the per-arch head is vmap-free by construction.
# ---------------------------------------------------------------------------
def init_net(key, cfg: PPOConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h = cfg.hidden

    def lin(k, i, o, scale):
        return {
            "w": scale * jax.random.normal(k, (i, o)) / jnp.sqrt(i),
            "b": jnp.zeros((o,)),
        }

    return {
        "torso1": lin(k1, OBS_DIM, h, 1.0),
        "torso2": lin(k2, h, h, 1.0),
        "pi": lin(k3, h, N_ACTIONS, 0.01),
        "v": lin(k4, h, 1, 1.0),
    }


def _apply(p, x):
    h = jnp.tanh(x @ p["torso1"]["w"] + p["torso1"]["b"])
    h = jnp.tanh(h @ p["torso2"]["w"] + p["torso2"]["b"])
    logits = h @ p["pi"]["w"] + p["pi"]["b"]
    value = (h @ p["v"]["w"] + p["v"]["b"])[..., 0]
    return logits, value


@jax.jit
def policy_logits_value(params, obs):
    return _apply(params, obs)


@jax.jit
def _pool_action(params, obs, key):
    """Sample per-arch actions for one pool tick: obs [A, F] -> [A]."""
    logits, values = _apply(params, obs)
    actions = jax.random.categorical(key, logits)
    logp = jnp.take_along_axis(
        jax.nn.log_softmax(logits), actions[:, None], axis=1
    )[:, 0]
    return actions, logp, values


def pool_policy_action(params, obs: np.ndarray, key) -> Tuple[np.ndarray, ...]:
    a, logp, v = _pool_action(params, jnp.asarray(obs), key)
    return np.asarray(a), np.asarray(logp), np.asarray(v)


def policy_action(params, obs: np.ndarray, key) -> Tuple[int, float, float]:
    """Single-arch convenience form (seed interface)."""
    a, logp, v = pool_policy_action(params, np.asarray(obs)[None, :], key)
    return int(a[0]), float(logp[0]), float(v[0])


# ---------------------------------------------------------------------------
# GAE.
# ---------------------------------------------------------------------------
def compute_gae_pool(rewards, values, dones, last_value, gamma, lam):
    """GAE over ``[T, A]`` per-arch reward/value streams.

    ``dones[t]`` is the shared episode boundary (the whole pool resets
    together); advantages are otherwise accumulated independently per
    arch, which is the credit-assignment half of the factored action
    space.
    """
    T, A = rewards.shape
    adv = np.zeros((T, A), dtype=np.float32)
    lastgaelam = np.zeros(A, dtype=np.float32)
    for t in reversed(range(T)):
        nonterminal = 1.0 - float(dones[t])
        next_v = last_value if t == T - 1 else values[t + 1]
        delta = rewards[t] + gamma * next_v * nonterminal - values[t]
        lastgaelam = delta + gamma * lam * nonterminal * lastgaelam
        adv[t] = lastgaelam
    returns = adv + values
    return adv, returns


def compute_gae(rewards, values, dones, last_value, gamma, lam):
    """Single-stream GAE (seed interface): the A=1 column of the pool form."""
    adv, ret = compute_gae_pool(
        np.asarray(rewards, np.float32)[:, None],
        np.asarray(values, np.float32)[:, None],
        dones,
        np.float32(last_value),
        gamma,
        lam,
    )
    return adv[:, 0], ret[:, 0]


# ---------------------------------------------------------------------------
# Batched rollout collection: one whole episode inside the jitted engine
# scan instead of T host round-trips through env.step.
# ---------------------------------------------------------------------------
def _rewards_from_ys(cfg, ys, expired) -> np.ndarray:
    """Per-tick ``[..., T, A]`` rewards rebuilt from the engine's per-arch
    attribution, with the end-of-trace expired sweep booked on the last
    tick exactly as ``env.step`` does."""
    viol = np.array(ys["viol"], dtype=np.float64)    # owned: last tick edited
    viol[..., -1, :] += expired
    return -cfg.reward_scale * (
        ys["cost_arch"]
        + cfg.violation_penalty * viol
        - cfg.accuracy_bonus * ys["acc_w"]
    )


def collect_rollouts_jax(env: PoolServingEnv, params, key, *,
                         arrivals=None, seed: int = 0) -> dict:
    """Collect one full-episode ``[T, A]`` rollout in a single dispatch.

    Drives the batched engine (:mod:`repro.core.sim.jax_engine`) with
    the stochastic ``rl_sample`` policy: the net's forward pass, the
    categorical draw and the procurement decode all run *inside*
    ``lax.scan``, and the per-tick extras come back as exactly the
    buffers the host rollout loop fills — observation features,
    sampled actions, log-probs, values — plus rewards rebuilt from the
    engine's per-arch cost/violation/accuracy attribution under the
    env's :class:`~repro.core.rl.env.EnvConfig` weights (the end-of-
    trace expired sweep lands on the last tick, as ``env.step`` books
    it).  The per-tick key sequence is the host loop's own
    ``key, k_t = split(key)`` chain, so the sampling stream is shared
    with the step-wise collector, not merely analogous.

    Arrival precedence matches ``env.reset``: an explicit ``arrivals``
    matrix, else a fresh draw from the env's scenario pool, else the
    fixed matrix the env was built with.  Episodes are done-terminated
    only at the trace end, so ``dones`` is a one-hot tail and
    ``last_value`` is irrelevant to GAE (returned as zeros).
    """
    cfg = env.cfg
    if arrivals is not None:
        tr = arrivals
    elif env.scenarios:
        tr = env._sample_arrivals()
        seed = env._episode          # the per-episode sim seed env.reset uses
    else:
        tr = env.base_arrivals
    tr = np.asarray(tr, dtype=np.float64)
    A, T = tr.shape
    pol = jax_engine.JAX_POLICIES["rl_sample"]
    # the env's variant catalog rides into the scan, so the sampled
    # variant head EXECUTES during collection (swaps change served
    # accuracy and cost) instead of decaying to a no-op
    statics, state0, xs = jax_engine.build_sim_inputs(
        tr, env.workload, pricing=cfg.pricing, catalog=env.catalog,
        seed=seed, needs_stats=pol.needs_stats, needs_key=True, key=key,
    )
    variants = "var_smult" in statics
    statics["policy"] = {
        "net": params,
        "rate_scale": cfg.rate_scale,
        "fleet_scale": cfg.fleet_scale,
    }
    from jax.experimental import enable_x64
    with enable_x64():
        out = jax.tree.map(
            np.asarray,
            jax_engine._get_runner("rl_sample", mode="stack",
                                   variants=variants)(
                statics, state0, xs
            ),
        )
    ys = out["ys"]
    rewards = _rewards_from_ys(
        cfg, ys, out["expired_s"] + out["expired_r"]
    )
    dones = np.zeros(T, dtype=np.float32)
    dones[-1] = 1.0
    return {
        "obs": np.asarray(ys["obs"], dtype=np.float32),
        "actions": np.asarray(ys["action"], dtype=np.int32),
        "logp": np.asarray(ys["logp"], dtype=np.float32),
        "values": np.asarray(ys["value"], dtype=np.float32),
        "rewards": rewards.astype(np.float32),
        "dones": dones,
        "last_value": np.zeros(A, dtype=np.float32),
    }


def collect_rollouts_jax_zoo(env: PoolServingEnv, params, key) -> dict:
    """Collect ``[S, T, A]`` rollouts over the env's WHOLE scenario pool
    in one vmapped dispatch — the full-zoo form of
    :func:`collect_rollouts_jax`.

    Instead of sampling one scenario per iteration, every scenario in
    ``env.scenarios`` becomes a cell of the batched engine runner (the
    same ``vmap`` grid dispatch :func:`~repro.core.sim.jax_engine.run_grid`
    uses): per-cell arrival realizations, sim seeds and per-tick key
    streams are all distinct, the net's parameters are shared across
    cells, and the per-cell monitor streams run as one batched
    recurrence over the stacked ``[S*A, T]`` arrival matrix (rows are
    independent, so this is bit-identical to S per-cell passes).

    The returned buffers merge the cell axis into the arch axis —
    ``[T, S*A, ...]`` — so GAE and the PPO update treat the zoo batch
    exactly like a wider pool: ``dones`` is the shared one-hot tail
    (every cell ends at the trace end), per-column advantage streams
    never mix cells, and the flattened update batch has ``T*S*A`` rows.
    One PPO iteration therefore trains on every load shape in the zoo
    at once instead of memorizing this episode's draw.
    """
    cfg = env.cfg
    assert env.scenarios, "full-zoo collection needs a scenario pool"
    S, A = len(env.scenarios), env.n_archs
    env._episode += 1              # one zoo sweep advances the episode clock
    ep = env._episode
    arrs = np.stack([
        np.asarray(
            sc.build(A, seed=sc.seed + ep, duration_s=cfg.duration_s,
                     mean_rps=cfg.mean_rps),
            dtype=np.float64,
        )
        for sc in env.scenarios
    ])                             # [S, A, T]
    T = arrs.shape[2]
    # distinct per-cell sim seeds across cells AND iterations (tier
    # noise must not replay), distinct per-cell key streams
    seeds = [ep * S + i for i in range(S)]
    keys = jax.random.split(key, S)
    sim_tmpl = jax_engine.ServingSim(
        arrs[0], env.workload, pricing=cfg.pricing, seed=seeds[0],
        catalog=env.catalog,
    )
    variants = sim_tmpl._variants_live
    ew, _, p2 = jax_engine.pool_stats_trajectory(arrs.reshape(S * A, T))
    cells = [
        jax_engine.build_sim_inputs(
            arrs[i], env.workload, pricing=cfg.pricing, seed=seeds[i],
            needs_stats=True, needs_key=True, key=keys[i],
            stats=(ew[:, i * A:(i + 1) * A], p2[:, i * A:(i + 1) * A]),
            lazy_rings=False, _sim=sim_tmpl,
        )
        for i in range(S)
    ]
    statics = cells[0][0]
    state0_b = jax_engine._tree_stack([c[1] for c in cells])
    xs_b = jax_engine._tree_stack([c[2] for c in cells])
    policy_b = jax_engine._tree_stack([{
        "net": params,
        "rate_scale": cfg.rate_scale,
        "fleet_scale": cfg.fleet_scale,
    }] * S)
    from jax.experimental import enable_x64
    with enable_x64():
        out = jax.tree.map(
            np.asarray,
            jax_engine._get_runner("rl_sample", mode="stack", batched=True,
                                   variants=variants)(
                statics, policy_b, state0_b, xs_b
            ),
        )
    ys = out["ys"]                 # leaves [S, T, A, ...]
    rewards = _rewards_from_ys(
        cfg, ys, out["expired_s"] + out["expired_r"]
    )

    def merge(x, dtype):           # [S, T, A, ...] -> [T, S*A, ...]
        x = np.asarray(x)
        return np.swapaxes(x, 0, 1).reshape(
            (T, S * A) + x.shape[3:]
        ).astype(dtype)

    dones = np.zeros(T, dtype=np.float32)
    dones[-1] = 1.0
    return {
        "obs": merge(ys["obs"], np.float32),
        "actions": merge(ys["action"], np.int32),
        "logp": merge(ys["logp"], np.float32),
        "values": merge(ys["value"], np.float32),
        "rewards": merge(rewards, np.float32),
        "dones": dones,
        "last_value": np.zeros(S * A, dtype=np.float32),
        "n_cells": S,
    }


# ---------------------------------------------------------------------------
# Update.
# ---------------------------------------------------------------------------
def _loss(params, batch, clip_eps, entropy_coef, value_coef):
    logits, values = _apply(params, batch["obs"])
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, batch["actions"][:, None], axis=1)[:, 0]
    ratio = jnp.exp(logp - batch["logp_old"])
    adv = batch["adv"]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    pi_loss = -jnp.mean(jnp.minimum(unclipped, clipped))
    v_loss = jnp.mean((values - batch["returns"]) ** 2)
    entropy = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1))
    # the standard sampled KL(old || new) estimator over the batch — the
    # health signal telemetry tracks per iteration (a spike means the
    # clipped surrogate stopped trusting the rollout distribution)
    approx_kl = jnp.mean(batch["logp_old"] - logp)
    total = pi_loss + value_coef * v_loss - entropy_coef * entropy
    return total, {"pi_loss": pi_loss, "v_loss": v_loss, "entropy": entropy,
                   "approx_kl": approx_kl}


@partial(jax.jit, static_argnames=("cfg",))
def ppo_update(params, opt_state, batch, cfg: PPOConfig):
    (loss, aux), grads = jax.value_and_grad(
        _loss, has_aux=True
    )(params, batch, cfg.clip_eps, cfg.entropy_coef, cfg.value_coef)
    # global-norm clip + Adam
    gnorm = jnp.sqrt(
        sum(jnp.sum(g**2) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-8))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step, m, v = opt_state
    step = step + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1**step), m)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2**step), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - cfg.lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, (step, m, v), loss, aux


@dataclass
class PPOState:
    params: dict                 # best-seen policy (by rollout reward)
    final_params: dict           # last-iteration policy
    opt_state: tuple
    history: List[dict]
    best_reward: float = float("-inf")


def train_ppo_pool(
    env: Union[PoolServingEnv, ServingEnv],
    cfg: PPOConfig = PPOConfig(),
    *,
    verbose: bool = False,
    jax_rollouts: bool = False,
    full_zoo: bool = False,
    log_path: Optional[str] = None,
) -> PPOState:
    """Train the pool controller with batched ``[T, A]`` rollouts.

    ``jax_rollouts=True`` swaps the step-wise env loop for
    :func:`collect_rollouts_jax`: each iteration collects exactly one
    full episode in a single jitted dispatch (``cfg.rollout_len`` is
    superseded by the episode length on that path); the update math is
    identical.

    ``full_zoo=True`` (requires ``jax_rollouts`` and a scenario pool)
    swaps the per-iteration scenario *sample* for the whole pool:
    :func:`collect_rollouts_jax_zoo` runs every scenario as a cell of
    one vmapped engine dispatch and each update trains on the merged
    ``[T, S*A]`` batch.

    ``log_path`` streams the per-iteration training curve (reward,
    loss components, entropy, approx-KL — the fields ``history`` keeps)
    to a JSONL file as it trains, e.g.
    ``artifacts/rl/training_log.jsonl``.
    """
    if isinstance(env, ServingEnv):
        env = env.pool
    assert not full_zoo or (jax_rollouts and env.scenarios), (
        "full_zoo needs jax_rollouts=True and a scenario pool"
    )
    A = env.n_archs
    key = jax.random.key(cfg.seed)
    key, knet = jax.random.split(key)
    params = init_net(knet, cfg)
    opt_state = (jnp.zeros((), jnp.int32),
                 jax.tree.map(jnp.zeros_like, params),
                 jax.tree.map(jnp.zeros_like, params))

    obs = env.reset()
    history: List[dict] = []
    ep_reward, ep_rewards = 0.0, []
    best_reward, best_params = float("-inf"), params
    log = JsonlWriter(log_path) if log_path else None

    for it in range(cfg.iterations):
        if jax_rollouts:
            key, kroll = jax.random.split(key)
            buf = (collect_rollouts_jax_zoo(env, params, kroll) if full_zoo
                   else collect_rollouts_jax(env, params, kroll))
            obs_buf, act_buf = buf["obs"], buf["actions"]
            logp_buf, val_buf = buf["logp"], buf["values"]
            rew_buf, done_buf = buf["rewards"], buf["dones"]
            T = rew_buf.shape[0]
            last_v = buf["last_value"]
            ep_rewards.append(float(rew_buf.sum()))
        else:
            T = cfg.rollout_len
            obs_buf = np.zeros((T, A, OBS_DIM), np.float32)
            act_buf = np.zeros((T, A), np.int32)
            logp_buf = np.zeros((T, A), np.float32)
            val_buf = np.zeros((T, A), np.float32)
            rew_buf = np.zeros((T, A), np.float32)
            done_buf = np.zeros((T,), np.float32)

            for t in range(T):
                key, kact = jax.random.split(key)
                a, logp, v = pool_policy_action(params, obs, kact)
                obs_buf[t], act_buf[t], logp_buf[t], val_buf[t] = (
                    obs, a, logp, v
                )
                obs, r_arch, done, _ = env.step(a)
                rew_buf[t], done_buf[t] = r_arch, float(done)
                ep_reward += float(r_arch.sum())
                if done:
                    ep_rewards.append(ep_reward)
                    ep_reward = 0.0
                    obs = env.reset()

            _, last_v = policy_logits_value(params, jnp.asarray(obs))
        adv, rets = compute_gae_pool(
            rew_buf, val_buf, done_buf, np.asarray(last_v, np.float32),
            cfg.gamma, cfg.gae_lambda,
        )
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)

        # flatten [T, W] -> [T*W] and update on shuffled minibatches
        # (W = A, or S*A when a full-zoo batch merged the cell axis)
        W = obs_buf.shape[1]
        flat = {
            "obs": obs_buf.reshape(T * W, OBS_DIM),
            "actions": act_buf.reshape(T * W),
            "logp_old": logp_buf.reshape(T * W),
            "adv": adv.reshape(T * W),
            "returns": rets.reshape(T * W),
        }
        idx = np.arange(T * W)
        rng = np.random.default_rng(cfg.seed + it)
        mb_stats = []          # device scalars; one host sync per iteration
        for _ in range(cfg.epochs):
            rng.shuffle(idx)
            for mb in np.array_split(idx, cfg.minibatches):
                batch = {k: jnp.asarray(v[mb]) for k, v in flat.items()}
                params, opt_state, loss, aux = ppo_update(
                    params, opt_state, batch, cfg
                )
                mb_stats.append(jnp.stack([
                    loss, aux["pi_loss"], aux["v_loss"], aux["entropy"],
                    aux["approx_kl"],
                ]))
        it_mean = np.asarray(jnp.stack(mb_stats)).mean(axis=0)

        roll_r = float(rew_buf.sum())
        if roll_r > best_reward:
            # PPO can catastrophically forget a good procurement policy on a
            # later unlucky rollout; keep the best-seen snapshot.
            best_reward = roll_r
            best_params = jax.tree.map(lambda x: x, params)

        mean_ep = float(np.mean(ep_rewards[-5:])) if ep_rewards else float("nan")
        history.append(
            {
                "iter": it,
                "rollout_reward": roll_r,
                "mean_episode_reward": mean_ep,
                # last-minibatch values (seed-era fields), plus the
                # iteration means the telemetry curve tracks
                "loss": float(loss),
                "entropy": float(aux["entropy"]),
                "loss_mean": float(it_mean[0]),
                "pi_loss": float(it_mean[1]),
                "v_loss": float(it_mean[2]),
                "entropy_mean": float(it_mean[3]),
                "approx_kl": float(it_mean[4]),
            }
        )
        if log is not None:
            log.write(history[-1])
        if verbose and it % 5 == 0:
            print(
                f"[ppo] it={it:3d} rollout_r={roll_r:9.4f} "
                f"ep_r={mean_ep:9.3f} H={history[-1]['entropy']:.3f}",
                flush=True,
            )
    if log is not None:
        log.close()
    return PPOState(
        params=best_params,
        final_params=params,
        opt_state=opt_state,
        history=history,
        best_reward=best_reward,
    )


def train_ppo(env: ServingEnv, cfg: PPOConfig = PPOConfig(), *,
              verbose: bool = False) -> PPOState:
    """Seed entry point: single-arch training is the A=1 pool path."""
    return train_ppo_pool(env, cfg, verbose=verbose)


def evaluate_pool_policy(env: PoolServingEnv, params, *,
                         arrivals=None, greedy: bool = False, seed: int = 1):
    """Run one full pool episode; return the SimResult.

    Stochastic evaluation (the default) is the trained object: the policy
    hedges between procurement modes tick-by-tick, and argmax-collapsing
    it discards the offload behaviour it actually learned."""
    key = jax.random.key(seed)
    obs = env.reset(arrivals)
    done = False
    while not done:
        logits, _ = policy_logits_value(params, jnp.asarray(obs))
        if greedy:
            a = np.asarray(jnp.argmax(logits, axis=-1))
        else:
            key, k = jax.random.split(key)
            a = np.asarray(jax.random.categorical(k, logits))
        obs, _, done, _ = env.step(a)
    return env.episode_result()


def evaluate_policy(env: ServingEnv, params, *, greedy: bool = False, seed: int = 1):
    """Single-arch evaluation (seed interface)."""
    return evaluate_pool_policy(env.pool, params, greedy=greedy, seed=seed)
