"""Proximal Policy Optimization in pure JAX (paper §V).

The paper sketches a PPO controller with the clipped surrogate
L(theta) = E_t[min(r_t A_t, clip(r_t, 1-eps, 1+eps) A_t)] over scheduling
decisions; we implement the full loop: MLP policy+value nets, GAE(lambda)
advantages, minibatched clipped updates with Adam, entropy bonus.

The environment is the Python-side serving simulator; the nets, GAE and
the update step are jitted JAX.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rl.env import N_ACTIONS, OBS_DIM, ServingEnv


@dataclass(frozen=True)
class PPOConfig:
    hidden: int = 64
    lr: float = 5e-4
    gamma: float = 0.97
    gae_lambda: float = 0.95
    clip_eps: float = 0.2
    entropy_coef: float = 0.01
    value_coef: float = 0.5
    epochs: int = 4
    minibatches: int = 8
    rollout_len: int = 1200        # cover a full episode -> every update
                                   # sees flash-crowd segments
    iterations: int = 60
    max_grad_norm: float = 0.5
    seed: int = 0


# ---------------------------------------------------------------------------
# Networks.
# ---------------------------------------------------------------------------
def init_net(key, cfg: PPOConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    h = cfg.hidden

    def lin(k, i, o, scale):
        return {
            "w": scale * jax.random.normal(k, (i, o)) / jnp.sqrt(i),
            "b": jnp.zeros((o,)),
        }

    return {
        "torso1": lin(k1, OBS_DIM, h, 1.0),
        "torso2": lin(k2, h, h, 1.0),
        "pi": lin(k3, h, N_ACTIONS, 0.01),
        "v": lin(k4, h, 1, 1.0),
    }


def _apply(p, x):
    h = jnp.tanh(x @ p["torso1"]["w"] + p["torso1"]["b"])
    h = jnp.tanh(h @ p["torso2"]["w"] + p["torso2"]["b"])
    logits = h @ p["pi"]["w"] + p["pi"]["b"]
    value = (h @ p["v"]["w"] + p["v"]["b"])[..., 0]
    return logits, value


@jax.jit
def policy_logits_value(params, obs):
    return _apply(params, obs)


def policy_action(params, obs: np.ndarray, key) -> Tuple[int, float, float]:
    logits, value = policy_logits_value(params, jnp.asarray(obs))
    a = jax.random.categorical(key, logits)
    logp = jax.nn.log_softmax(logits)[a]
    return int(a), float(logp), float(value)


# ---------------------------------------------------------------------------
# GAE.
# ---------------------------------------------------------------------------
def compute_gae(rewards, values, dones, last_value, gamma, lam):
    """Numpy GAE over one rollout."""
    T = len(rewards)
    adv = np.zeros(T, dtype=np.float32)
    lastgaelam = 0.0
    for t in reversed(range(T)):
        nonterminal = 1.0 - float(dones[t])
        next_v = last_value if t == T - 1 else values[t + 1]
        delta = rewards[t] + gamma * next_v * nonterminal - values[t]
        lastgaelam = delta + gamma * lam * nonterminal * lastgaelam
        adv[t] = lastgaelam
    returns = adv + values
    return adv, returns


# ---------------------------------------------------------------------------
# Update.
# ---------------------------------------------------------------------------
def _loss(params, batch, clip_eps, entropy_coef, value_coef):
    logits, values = _apply(params, batch["obs"])
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, batch["actions"][:, None], axis=1)[:, 0]
    ratio = jnp.exp(logp - batch["logp_old"])
    adv = batch["adv"]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
    pi_loss = -jnp.mean(jnp.minimum(unclipped, clipped))
    v_loss = jnp.mean((values - batch["returns"]) ** 2)
    entropy = -jnp.mean(jnp.sum(jnp.exp(logp_all) * logp_all, axis=-1))
    total = pi_loss + value_coef * v_loss - entropy_coef * entropy
    return total, {"pi_loss": pi_loss, "v_loss": v_loss, "entropy": entropy}


@partial(jax.jit, static_argnames=("cfg",))
def ppo_update(params, opt_state, batch, cfg: PPOConfig):
    (loss, aux), grads = jax.value_and_grad(
        _loss, has_aux=True
    )(params, batch, cfg.clip_eps, cfg.entropy_coef, cfg.value_coef)
    # global-norm clip + Adam
    gnorm = jnp.sqrt(
        sum(jnp.sum(g**2) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, cfg.max_grad_norm / (gnorm + 1e-8))
    grads = jax.tree.map(lambda g: g * scale, grads)

    step, m, v = opt_state
    step = step + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda m_: m_ / (1 - b1**step), m)
    vhat = jax.tree.map(lambda v_: v_ / (1 - b2**step), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - cfg.lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, (step, m, v), loss, aux


@dataclass
class PPOState:
    params: dict                 # best-seen policy (by rollout reward)
    final_params: dict           # last-iteration policy
    opt_state: tuple
    history: List[dict]
    best_reward: float = float("-inf")


def train_ppo(env: ServingEnv, cfg: PPOConfig = PPOConfig(), *, verbose: bool = False) -> PPOState:
    key = jax.random.key(cfg.seed)
    key, knet = jax.random.split(key)
    params = init_net(knet, cfg)
    opt_state = (jnp.zeros((), jnp.int32),
                 jax.tree.map(jnp.zeros_like, params),
                 jax.tree.map(jnp.zeros_like, params))

    obs = env.reset()
    history: List[dict] = []
    ep_reward, ep_rewards = 0.0, []
    best_reward, best_params = float("-inf"), params

    for it in range(cfg.iterations):
        T = cfg.rollout_len
        obs_buf = np.zeros((T, OBS_DIM), np.float32)
        act_buf = np.zeros((T,), np.int32)
        logp_buf = np.zeros((T,), np.float32)
        val_buf = np.zeros((T,), np.float32)
        rew_buf = np.zeros((T,), np.float32)
        done_buf = np.zeros((T,), np.float32)

        for t in range(T):
            key, kact = jax.random.split(key)
            a, logp, v = policy_action(params, obs, kact)
            obs_buf[t], act_buf[t], logp_buf[t], val_buf[t] = obs, a, logp, v
            obs, r, done, _ = env.step(a)
            rew_buf[t], done_buf[t] = r, float(done)
            ep_reward += r
            if done:
                ep_rewards.append(ep_reward)
                ep_reward = 0.0
                obs = env.reset()

        _, last_v = policy_logits_value(params, jnp.asarray(obs))
        adv, rets = compute_gae(
            rew_buf, val_buf, done_buf, float(last_v), cfg.gamma, cfg.gae_lambda
        )
        adv = (adv - adv.mean()) / (adv.std() + 1e-8)

        idx = np.arange(T)
        rng = np.random.default_rng(cfg.seed + it)
        for _ in range(cfg.epochs):
            rng.shuffle(idx)
            for mb in np.array_split(idx, cfg.minibatches):
                batch = {
                    "obs": jnp.asarray(obs_buf[mb]),
                    "actions": jnp.asarray(act_buf[mb]),
                    "logp_old": jnp.asarray(logp_buf[mb]),
                    "adv": jnp.asarray(adv[mb]),
                    "returns": jnp.asarray(rets[mb]),
                }
                params, opt_state, loss, aux = ppo_update(
                    params, opt_state, batch, cfg
                )

        roll_r = float(rew_buf.sum())
        if roll_r > best_reward:
            # PPO can catastrophically forget a good procurement policy on a
            # later unlucky rollout; keep the best-seen snapshot.
            best_reward = roll_r
            best_params = jax.tree.map(lambda x: x, params)

        mean_ep = float(np.mean(ep_rewards[-5:])) if ep_rewards else float("nan")
        history.append(
            {
                "iter": it,
                "rollout_reward": float(rew_buf.sum()),
                "mean_episode_reward": mean_ep,
                "loss": float(loss),
                "entropy": float(aux["entropy"]),
            }
        )
        if verbose and it % 5 == 0:
            print(
                f"[ppo] it={it:3d} rollout_r={history[-1]['rollout_reward']:9.4f} "
                f"ep_r={mean_ep:9.3f} H={history[-1]['entropy']:.3f}",
                flush=True,
            )
    return PPOState(
        params=best_params,
        final_params=params,
        opt_state=opt_state,
        history=history,
        best_reward=best_reward,
    )


def evaluate_policy(env: ServingEnv, params, *, greedy: bool = False, seed: int = 1):
    """Run one full episode; return the SimResult.

    Stochastic evaluation (the default) is the trained object: the policy
    hedges between procurement modes tick-by-tick, and argmax-collapsing
    it discards the offload behaviour it actually learned."""
    key = jax.random.key(seed)
    obs = env.reset()
    done = False
    while not done:
        logits, _ = policy_logits_value(params, jnp.asarray(obs))
        if greedy:
            a = int(jnp.argmax(logits))
        else:
            key, k = jax.random.split(key)
            a = int(jax.random.categorical(k, logits))
        obs, _, done, _ = env.step(a)
    return env.episode_result()
