"""TPU v5e machine model — the single source of hardware truth.

Every latency/cost number in the serving layer and every roofline term in
the benchmarks is derived from these constants; nothing is wall-clocked on
this CPU-only container.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12        # FLOP/s per chip
    hbm_bandwidth: float = 819e9           # B/s per chip
    hbm_bytes: float = 16e9                # HBM capacity per chip
    ici_bandwidth: float = 50e9            # B/s per ICI link
    ici_links: int = 4                     # links per chip (2D torus)
    # achievable fractions (serving-engine planning numbers, not marketing)
    mfu_serving: float = 0.45              # matmul-heavy prefill
    mbu_serving: float = 0.70              # HBM-bound decode


V5E = ChipSpec()


@dataclass(frozen=True)
class FleetPricing:
    """Public-cloud pricing for the two procurement kinds (paper §II).

    ``reserved``  — long-lived slice, billed per chip-hour while held
                    (the paper's VM).
    ``burst``     — per-invocation multiplexed warm pool, billed per
                    chip-second of use at a premium + a per-request fee
                    (the paper's serverless function).  The premium is the
                    Lambda-vs-EC2 compute-cost ratio (~4-8x); we use 5x.
    """

    reserved_chip_hour: float = 1.20       # $/chip-hour (v5e on-demand)
    burst_premium: float = 5.0             # burst $/chip-s = reserved rate x this
    burst_invocation_fee: float = 2e-6     # $/request (API gateway analog)
    object_store_bandwidth: float = 2.5e9  # B/s weight fetch (cold start)
    reserved_provision_s: float = 120.0    # slice acquisition latency
    burst_spinup_s: float = 1.0            # warm-pool dispatch latency
    burst_idle_timeout_s: float = 600.0    # pool recycles idle model images
    # --- spot tier (paper §VI future work, implemented beyond-paper) ----
    spot_discount: float = 0.3             # spot $/chip-hour = reserved x this
    spot_preempt_rate: float = 1.0 / 1800  # Poisson reclaim: ~1 per 30 min
    spot_provision_s: float = 120.0        # same slice acquisition latency
    # --- harvest-VM tier (spare capacity carved from running hosts) -----
    harvest_discount: float = 0.15         # deepest discount of the portfolio
    harvest_provision_s: float = 60.0      # no slice boot: host already runs
    harvest_cap_per_arch: int = 16         # provider ceiling at full harvest
                                           # availability (level 1.0)
    # --- multi-region reserved tier (second region, cheaper, farther) ---
    remote_discount: float = 0.85          # remote $/chip-hour = reserved x this
    remote_provision_s: float = 300.0      # cross-region slice acquisition
    remote_egress_s: float = 0.25          # per-request network egress adder
                                           # (why strict traffic prefers local)
    # --- model-variant swaps (INFaaS-style model-less serving) ----------
    variant_swap_s: float = 60.0           # weight reload onto held slices;
                                           # faster than acquiring a slice,
                                           # not free (serves at the OLD
                                           # variant's rate meanwhile)

    @property
    def reserved_chip_s(self) -> float:
        return self.reserved_chip_hour / 3600.0

    @property
    def burst_chip_s(self) -> float:
        return self.reserved_chip_s * self.burst_premium


PRICING = FleetPricing()
