"""Model profiles — the paper's "offline profiling" table, derived.

The paper profiles every (model x resource) pair on AWS and stores
latency/accuracy/memory in an offline cache that the scheduler consults.
We derive the same table analytically from the TPU v5e machine model
(:mod:`repro.core.hardware`) and each architecture's config: FLOPs and
bytes per prefill/decode step -> roofline latency; published model quality
-> the accuracy axis.  The dry-run artifacts (compiled HLO statistics) can
recalibrate these numbers when present, exactly like the paper's
"results from previous executions".
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.registry import (
    ATTN,
    LOCAL_ATTN,
    RGLRU,
    RWKV,
    ModelConfig,
    get_config,
    list_architectures,
)
from repro.core.hardware import PRICING, V5E, ChipSpec, FleetPricing

BYTES_PER_PARAM = 2  # bf16 serving weights


@dataclass(frozen=True)
class RequestClass:
    """A unit of work: one inference query (paper's "request")."""

    name: str = "standard"
    prompt_tokens: int = 512
    decode_tokens: int = 64
    slo_s: float = 1.0            # response-latency SLO (paper: sub-second)
    strict: bool = True           # strict vs relaxed latency class (§IV.B)


STANDARD = RequestClass()
RELAXED = RequestClass("relaxed", 512, 64, slo_s=4.0, strict=False)


@dataclass(frozen=True)
class ModelProfile:
    """Latency/cost/accuracy characterization of one arch on one slice."""

    cfg: ModelConfig
    chips: int
    chip: ChipSpec = V5E
    pricing: FleetPricing = PRICING

    # ------------------------------------------------------------------ sizes
    @property
    def weight_bytes(self) -> float:
        return BYTES_PER_PARAM * self.cfg.params_total

    @property
    def active_bytes(self) -> float:
        return BYTES_PER_PARAM * self.cfg.params_active

    def kv_bytes_per_token(self) -> float:
        """Decode-state bytes per cached token (0 for pure-SSM archs)."""
        cfg = self.cfg
        per_tok = 0.0
        for kind in cfg.layer_kinds():
            if kind in (ATTN, LOCAL_ATTN):
                per_tok += 2 * cfg.num_kv_heads * cfg.resolved_head_dim * BYTES_PER_PARAM
        return per_tok

    def state_bytes(self, context: int) -> float:
        """Total decode state for one sequence with ``context`` live tokens."""
        cfg = self.cfg
        fixed = 0.0
        per_tok = 0.0
        for kind in cfg.layer_kinds():
            if kind == ATTN:
                per_tok += 2 * cfg.num_kv_heads * cfg.resolved_head_dim * BYTES_PER_PARAM
            elif kind == LOCAL_ATTN:
                w = min(cfg.local_window or context, context)
                fixed += 2 * cfg.num_kv_heads * cfg.resolved_head_dim * BYTES_PER_PARAM * w
            elif kind == RGLRU:
                fixed += 4 * (cfg.rglru_width or cfg.d_model) * 4  # conv + h fp32
            elif kind == RWKV:
                hd = cfg.rwkv_head_dim
                fixed += (cfg.d_model // hd) * hd * hd * 4 + 2 * cfg.d_model * 2
        return fixed + per_tok * context

    @property
    def min_chips(self) -> int:
        """Smallest slice whose HBM holds weights + ~30% headroom."""
        need = self.weight_bytes * 1.3
        return max(1, math.ceil(need / self.chip.hbm_bytes))

    # --------------------------------------------------------------- latency
    def _collective_step_s(self, batch: int) -> float:
        """Per-decode-step tensor-parallel all-reduce cost on this slice."""
        if self.chips == 1:
            return 0.0
        cfg = self.cfg
        # 2 all-reduces per layer (attn out + ffn out) of (B, 1, d) activations
        bytes_per = 2 * cfg.num_layers * batch * cfg.d_model * BYTES_PER_PARAM
        ring = 2.0 * (self.chips - 1) / self.chips
        links = self.chip.ici_bandwidth * self.chip.ici_links / 2
        return bytes_per * ring / links + 2 * cfg.num_layers * 1e-6  # + launch

    def prefill_latency(self, prompt: int, batch: int = 1) -> float:
        flops = 2.0 * self.cfg.params_active * prompt * batch
        compute = flops / (self.chips * self.chip.peak_flops_bf16 * self.chip.mfu_serving)
        memory = self.active_bytes / (self.chips * self.chip.hbm_bandwidth * self.chip.mbu_serving)
        coll = self._collective_step_s(batch) * max(1, prompt // 512)
        return max(compute, memory) + coll

    def decode_step_latency(self, batch: int, context: int = 576) -> float:
        """One token for every sequence in a batch of ``batch``."""
        flops = 2.0 * self.cfg.params_active * batch
        compute = flops / (self.chips * self.chip.peak_flops_bf16 * self.chip.mfu_serving)
        state = self.state_bytes(context) * batch
        memory = (self.active_bytes + state) / (
            self.chips * self.chip.hbm_bandwidth * self.chip.mbu_serving
        )
        return max(compute, memory) + self._collective_step_s(batch)

    def request_latency(self, req: RequestClass = STANDARD, batch: int = 1) -> float:
        """End-to-end latency of one request in a continuous batch of ``batch``.

        The request runs its own prefill once (prefills are staggered, so
        batch=1 for that term) and then decodes in lockstep with the other
        ``batch-1`` residents — the decode-step batch is what congestion
        costs (paper §II-B: 'number of concurrent requests a VM can execute
        without violating response latency')."""
        ctx = req.prompt_tokens + req.decode_tokens
        return self.prefill_latency(req.prompt_tokens, 1) + req.decode_tokens * (
            self.decode_step_latency(batch, ctx)
        )

    # ------------------------------------------------------------- capacity
    def max_concurrency(self, req: RequestClass = STANDARD) -> int:
        """Paper §II-B: requests a slice executes in parallel within SLO."""
        ctx = req.prompt_tokens + req.decode_tokens
        hbm_free = self.chips * self.chip.hbm_bytes - self.weight_bytes * 1.1
        if hbm_free <= 0:
            return 0
        state = max(self.state_bytes(ctx), 1.0)
        mem_cap = int(hbm_free / state)
        b = 1
        while b <= 4096:
            if self.request_latency(req, b * 2) > req.slo_s or b * 2 > mem_cap:
                break
            b *= 2
        while b < mem_cap and self.request_latency(req, b + max(1, b // 8)) <= req.slo_s:
            b += max(1, b // 8)
        return 0 if self.request_latency(req, 1) > req.slo_s else min(b, mem_cap)

    def throughput(self, req: RequestClass = STANDARD) -> float:
        """Steady-state requests/s of one slice at max concurrency."""
        b = self.max_concurrency(req)
        if b == 0:
            return 0.0
        return b / self.request_latency(req, b)

    # ----------------------------------------------------------------- cost
    def reserved_cost_per_hour(self) -> float:
        return self.chips * self.pricing.reserved_chip_hour

    def burst_cost_per_request(self, req: RequestClass = STANDARD) -> float:
        """$/invocation on the burst pool.

        Hardware-adaptation note (DESIGN.md A6): Lambda bills memory x
        duration of a function that is busy for the whole CNN inference.
        A TPU burst pool is internally batched by the provider (that is
        what makes a multiplexed warm pool viable at all), so the billable
        chip-seconds per invocation are the *amortized* slice time at the
        pool's serving batch, marked up by the burst premium.  The premium
        (5x) is the Lambda-vs-EC2 compute-cost ratio; the invocation still
        *observes* batch-1 latency + spin-up."""
        thr = self.throughput(req)
        if thr <= 0:
            return float("inf")
        busy_chip_s = self.chips / thr
        return busy_chip_s * self.pricing.burst_chip_s + self.pricing.burst_invocation_fee

    def cold_start_s(self) -> float:
        """Burst cold start: weight fetch from the object store + dispatch."""
        return (
            self.pricing.burst_spinup_s
            + self.weight_bytes / self.pricing.object_store_bandwidth
        )


# ---------------------------------------------------------------------------
# The offline model cache (paper §IV-A).
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def get_profile(
    arch: str, chips: Optional[int] = None, req: RequestClass = STANDARD
) -> ModelProfile:
    """Profile of ``arch`` on a slice.  With ``chips=None`` the slice is
    right-sized (paper Observation 2): the smallest multiple of the
    HBM-minimum that meets the request class's SLO at batch 1."""
    cfg = get_config(arch)
    if chips is not None:
        return ModelProfile(cfg, chips)
    base = ModelProfile(cfg, 1).min_chips
    for mult in (1, 2, 4, 8):
        prof = ModelProfile(cfg, base * mult)
        if prof.request_latency(req, 1) <= req.slo_s:
            return prof
    return ModelProfile(cfg, base * 8)


@functools.lru_cache(maxsize=None)
def model_pool(req: RequestClass = STANDARD) -> Dict[str, dict]:
    """Fig-2 style pool: accuracy / latency / cost per architecture.

    Latency is the batch-1 request latency on the model's minimal slice;
    cost is $/1k requests when served on fully-utilized reserved slices.
    """
    pool: Dict[str, dict] = {}
    for arch in list_architectures():
        prof = get_profile(arch)
        thr = prof.throughput(req)
        cost_1k = (
            prof.reserved_cost_per_hour() / max(thr * 3600.0, 1e-9) * 1000.0
            if thr > 0
            else float("inf")
        )
        pool[arch] = {
            "arch": arch,
            "family": prof.cfg.family,
            "chips": prof.chips,
            "accuracy": prof.cfg.quality,
            "latency_s": prof.request_latency(req, 1),
            "throughput_rps": thr,
            "concurrency": prof.max_concurrency(req),
            "cost_per_1k": cost_1k,
            "burst_cost_per_req": prof.burst_cost_per_request(req),
            "cold_start_s": prof.cold_start_s(),
            "params_total": prof.cfg.params_total,
            "params_active": prof.cfg.params_active,
        }
    return pool


def iso_latency_set(max_latency_s: float, req: RequestClass = STANDARD):
    return {
        a: e for a, e in model_pool(req).items() if e["latency_s"] <= max_latency_s
    }


def iso_accuracy_set(min_accuracy: float, req: RequestClass = STANDARD):
    return {
        a: e for a, e in model_pool(req).items() if e["accuracy"] >= min_accuracy
    }
