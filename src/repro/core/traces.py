"""Statistical twins of the paper's four request-arrival traces.

The originals (UC-Berkeley Home-IP, Wikipedia, WITS, Twitter — paper
[18]-[21]) are not redistributable, so we generate seeded surrogates whose
*shape statistics* match what the paper exploits: Fig 7's peak-to-median
ratios (Wiki low ~1.3, the others >2) and the burst structure each scheme
reacts to.  Observation-4 behaviour (mixed procurement helps iff
peak/median is large) must EMERGE from these, it is not hard-coded.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

DEFAULT_DURATION_S = 3600
DEFAULT_MEAN_RPS = 100.0


def _normalize(rate: np.ndarray, mean_rps: float) -> np.ndarray:
    rate = np.maximum(rate, 0.0)
    return rate * (mean_rps / max(rate.mean(), 1e-9))


def berkeley(duration_s: int = DEFAULT_DURATION_S, mean_rps: float = DEFAULT_MEAN_RPS,
             seed: int = 0) -> np.ndarray:
    """Home-IP dialup: strong diurnal swell + evening flash crowds."""
    rng = np.random.default_rng(seed + 101)
    t = np.arange(duration_s)
    base = 1.0 + 0.55 * np.sin(2 * np.pi * t / duration_s - 0.7)
    # two flash crowds, sharp rise / exponential drain
    for start, scale, tau in ((duration_s * 0.35, 1.7, 180.0), (duration_s * 0.7, 1.3, 140.0)):
        base += scale * np.exp(-np.maximum(t - start, 0) / tau) * (t >= start)
    noise = rng.gamma(shape=24.0, scale=1 / 24.0, size=duration_s)
    return _normalize(base * noise, mean_rps)


def wiki(duration_s: int = DEFAULT_DURATION_S, mean_rps: float = DEFAULT_MEAN_RPS,
         seed: int = 0) -> np.ndarray:
    """Wikipedia: smooth, low-variance diurnal — peak/median ~1.3 (Fig 7)."""
    rng = np.random.default_rng(seed + 202)
    t = np.arange(duration_s)
    base = 1.0 + 0.18 * np.sin(2 * np.pi * t / duration_s) + 0.06 * np.sin(
        6 * np.pi * t / duration_s + 1.1
    )
    noise = rng.gamma(shape=120.0, scale=1 / 120.0, size=duration_s)
    return _normalize(base * noise, mean_rps)


def wits(duration_s: int = DEFAULT_DURATION_S, mean_rps: float = DEFAULT_MEAN_RPS,
         seed: int = 0) -> np.ndarray:
    """WITS ISP backbone: heavy-tailed bursts on a shallow diurnal."""
    rng = np.random.default_rng(seed + 303)
    t = np.arange(duration_s)
    base = 1.0 + 0.25 * np.sin(2 * np.pi * t / duration_s + 2.0)
    # Pareto-amplitude bursts arriving as a Poisson process, AR(1)-smeared
    bursts = np.zeros(duration_s)
    n_bursts = rng.poisson(duration_s / 400)
    starts = rng.integers(0, duration_s, n_bursts)
    amps = np.minimum(rng.pareto(2.2, n_bursts) * 0.7, 3.0)
    for s0, a in zip(starts, amps):
        dur = int(rng.integers(20, 120))
        bursts[s0 : s0 + dur] += a
    noise = rng.gamma(shape=30.0, scale=1 / 30.0, size=duration_s)
    return _normalize((base + bursts) * noise, mean_rps)


def twitter(duration_s: int = DEFAULT_DURATION_S, mean_rps: float = DEFAULT_MEAN_RPS,
            seed: int = 0) -> np.ndarray:
    """Twitter firehose: spiky retweet cascades, highest peak/median."""
    rng = np.random.default_rng(seed + 404)
    t = np.arange(duration_s)
    base = np.full(duration_s, 0.8) + 0.15 * np.sin(2 * np.pi * t / duration_s)
    spikes = np.zeros(duration_s)
    n_spikes = rng.poisson(duration_s / 450)
    starts = rng.integers(0, duration_s, max(n_spikes, 4))
    for s0 in starts:
        amp = 1.4 + min(rng.pareto(2.0) * 1.2, 5.0)
        tau = rng.uniform(30.0, 90.0)
        spikes += amp * np.exp(-np.maximum(t - s0, 0) / tau) * (t >= s0)
    noise = rng.gamma(shape=18.0, scale=1 / 18.0, size=duration_s)
    return _normalize((base + spikes) * noise, mean_rps)


TRACES = {
    "berkeley": berkeley,
    "wiki": wiki,
    "wits": wits,
    "twitter": twitter,
}


def get_trace(name: str, duration_s: int = DEFAULT_DURATION_S,
              mean_rps: float = DEFAULT_MEAN_RPS, seed: int = 0) -> np.ndarray:
    """Per-second request rate (req/s), length ``duration_s``."""
    return TRACES[name](duration_s, mean_rps, seed)


def peak_to_median(rate: np.ndarray, peak_q: float = 0.99, axis=None):
    """Fig-7 statistic (p99 peak guards against one-sample outliers).

    1-D input returns a float; an ``[A, T]`` arrival matrix with
    ``axis=1`` returns the per-arch statistic ``[A]`` — the spread of
    these over a heterogeneous scenario is exactly what share-scaling a
    single pool trace flattens away.
    """
    peak = np.quantile(rate, peak_q, axis=axis)
    med = np.maximum(np.median(rate, axis=axis), 1e-9)
    out = peak / med
    return float(out) if out.ndim == 0 else out


def trace_stats(duration_s: int = DEFAULT_DURATION_S, seed: int = 0) -> Dict[str, dict]:
    out = {}
    for name in TRACES:
        r = get_trace(name, duration_s, seed=seed)
        out[name] = {
            "mean": float(r.mean()),
            "median": float(np.median(r)),
            "peak_p99": float(np.quantile(r, 0.99)),
            "peak_to_median": peak_to_median(r),
        }
    return out
