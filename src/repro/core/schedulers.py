"""Resource-procurement policies (paper §II-C, §II-D, §IV).

All five schemes share one interface: ``policy(tick, obs) -> {arch: Action}``.

  reactive    — scale to the smoothed current demand; no burst.  The
                paper's normalization baseline.
  util_aware  — spawn when utilization crosses 80% (prior work [14]-[16]);
                equivalently holds capacity at demand/0.8.
  exascale    — provision ABOVE a windowed peak prediction (Tributary-style
                [17]): headroom x recent peak.
  mixed       — reactive VM fleet + blind burst offload of ANY query about
                to miss its SLO (MArk [12] / Spock [13]).
  paragon     — this paper's scheme: latency-class-aware offload (strict
                queries only; relaxed ones ride out the spike in queue) on
                top of reactive scaling, consulting the load monitor.

Beyond-paper tiers ride the same interface: ``spot_paragon`` (on-demand
floor + preemptible spot base) and ``portfolio`` (reserved floor +
remote-region relaxed base + harvest VMs split by reclaim risk + spot
churn buffer — the full tier portfolio).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict

import numpy as np

from repro.core.sim import (
    OFFLOAD_BLIND,
    OFFLOAD_SLACK_AWARE,
    Action,
    ArchObs,
    PoolAction,
    PoolObs,
)


def _scale_target(o: ArchObs, demand: float, headroom: float = 1.0) -> int:
    return max(1, math.ceil(demand * headroom / o.throughput))


@dataclass
class ReactivePolicy:
    """Track smoothed demand 1:1 — cheap, but spikes hit the provisioning
    latency window and violate SLOs."""

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        return {
            a: Action(target=_scale_target(o, o.ewma_rate)) for a, o in obs.items()
        }


@dataclass
class UtilAwarePolicy:
    """Spawn when utilization reaches ``util_target`` (80% in most prior
    work [14]); release only when it falls below ``scale_down_util``.
    The hysteresis is the over-provisioning the paper measures in Fig 5:
    utilization is a lagging, spike-inflated indicator, so VMs spawned for
    a burst linger long after it drains."""

    util_target: float = 0.8
    scale_down_util: float = 0.4
    up_cooldown_s: int = 30        # scale up eagerly on sustained pressure
    down_cooldown_s: int = 120     # release conservatively (the paper's point:
                                   # spike-spawned VMs linger -> over-provision)
    _targets: Dict[str, int] = field(default_factory=dict)
    _last_up: Dict[str, int] = field(default_factory=dict)
    _last_down: Dict[str, int] = field(default_factory=dict)

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        out = {}
        for a, o in obs.items():
            cur = self._targets.get(a, max(o.n_active + o.n_pending, 1))
            if (
                o.utilization > self.util_target
                and tick - self._last_up.get(a, -10**9) >= self.up_cooldown_s
            ):
                # spawn enough to bring utilization back under target
                cur = max(
                    cur + 1, _scale_target(o, o.ewma_rate, 1.0 / self.util_target)
                )
                self._last_up[a] = tick
            elif (
                o.utilization < self.scale_down_util
                and cur > 1
                and tick - self._last_down.get(a, -10**9) >= self.down_cooldown_s
            ):
                cur -= 1
                self._last_down[a] = tick
            self._targets[a] = cur
            out[a] = Action(target=cur)
        return out


@dataclass
class ExascalePolicy:
    """Provision for the windowed peak plus headroom ("spawn additional VMs
    than predicted demand")."""

    headroom: float = 1.15

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        return {
            a: Action(
                target=_scale_target(
                    o, max(o.window_peak, o.ewma_rate), self.headroom
                )
            )
            for a, o in obs.items()
        }


@dataclass
class MixedPolicy:
    """Reactive fleet + blind offload: every query about to miss its SLO is
    handed to a burst instance, regardless of its latency class."""

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        return {
            a: Action(target=_scale_target(o, o.ewma_rate), offload="blind")
            for a, o in obs.items()
        }


@dataclass
class ParagonPolicy:
    """The paper's scheme (§IV): constraint-aware procurement.

    * strict-latency queries offload to burst when the VM queue would
      violate them;
    * relaxed-latency queries NEVER pay the burst premium — their slack
      absorbs the spike while reactive scaling catches up;
    * when the load-monitor window says the trace is flat
      (peak/median < ``bursty_threshold``, Observation 4), provisioning
      gets a small cushion instead, because burst would not pay off.
    """

    bursty_threshold: float = 1.5
    flat_cushion: float = 1.1
    drain_horizon_s: float = 5.0   # drain relaxed backlog within its slack

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        out = {}
        for a, o in obs.items():
            bursty = o.peak_to_median >= self.bursty_threshold
            headroom = 1.0 if bursty else self.flat_cushion
            # right-size for demand PLUS queued (relaxed) work: the backlog
            # must drain within the relaxed slack, on VMs, not on burst
            demand = o.ewma_rate + o.queue_len / self.drain_horizon_s
            out[a] = Action(
                target=_scale_target(o, demand, headroom),
                offload="slack_aware",
            )
        return out


SCHEDULERS = {
    "reactive": ReactivePolicy,
    "util_aware": UtilAwarePolicy,
    "exascale": ExascalePolicy,
    "mixed": MixedPolicy,
    "paragon": ParagonPolicy,
}


def get_scheduler(name: str, **kw):
    return SCHEDULERS[name](**kw)


@dataclass
class SpotParagonPolicy(ParagonPolicy):
    """Beyond-paper (§VI "Limitations"): Paragon + a SPOT tier.

    The steady base load runs on preemptible spot slices at
    ``spot_discount`` x the on-demand price; an on-demand floor sized for
    the strict-class share guarantees SLO-critical capacity through
    preemptions, and the class-aware burst offload (inherited) covers the
    transient dips a reclaim leaves behind.
    """

    strict_share: float = 0.25     # workload's strict fraction (floor sizing)
    spot_buffer: float = 1.25      # spot over-provision vs residual demand
                                   # (preemption churn absorber)

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        out = {}
        for a, o in obs.items():
            demand = o.ewma_rate + o.queue_len / self.drain_horizon_s
            floor = max(1, math.ceil(demand * self.strict_share / o.throughput))
            residual = max(0.0, demand - floor * o.throughput)
            spot = math.ceil(residual * self.spot_buffer / o.throughput)
            out[a] = Action(target=floor, spot_target=spot, offload="slack_aware")
        return out


SCHEDULERS["spot_paragon"] = SpotParagonPolicy


@dataclass
class PortfolioPolicy(ParagonPolicy):
    """Beyond-paper: the full TIER PORTFOLIO over Paragon's class-aware
    offload — the paper's "confounding array of resource types" under
    one procurement rule.

    Capacity is layered by reliability and price:

    * an on-demand **reserved** floor sized for the strict-class share
      (SLO-critical capacity that survives any reclaim wave);
    * a **remote**-region reserved slice for a fraction of the steady
      relaxed base (cheaper, slower to provision, pays a per-request
      egress adder — which is fine for relaxed traffic, and the engine
      serves strict from local capacity first anyway);
    * **harvest** VMs for the bulk of the residual base load — the
      deepest discount, sized *by reclaim risk*: the harvest share
      follows the provider's availability signal (level high -> lean on
      harvest; level sagging -> shift toward spot before the ceiling
      evicts), and is capped by the granted ceiling;
    * **spot** for whatever the harvest grant leaves uncovered, with a
      churn buffer against its i.i.d. reclaims;
    * class-aware burst offload (inherited) absorbs the transient dips
      any reclaim leaves behind.
    """

    strict_share: float = 0.25     # reserved floor = strict-class share
    remote_frac: float = 0.3       # fraction of the steady relaxed base
                                   # placed in the remote region
    harvest_margin: float = 0.15   # risk margin under the harvest signal
    harvest_max_frac: float = 0.8  # never bet more of the residual on
                                   # harvest than this
    harvest_buffer: float = 1.1    # small headroom on the harvest slice
    spot_buffer: float = 1.25      # preemption churn absorber

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        out = {}
        for a, o in obs.items():
            demand = o.ewma_rate + o.queue_len / self.drain_horizon_s
            floor = max(1, math.ceil(demand * self.strict_share / o.throughput))
            remote = int(
                self.remote_frac * (1 - self.strict_share) * o.ewma_rate
                / o.throughput
            )
            residual = max(
                0.0, demand - (floor + remote) * o.throughput
            )
            h_frac = min(
                max(o.harvest_level - self.harvest_margin, 0.0),
                self.harvest_max_frac,
            )
            h_want = math.ceil(
                residual * h_frac * self.harvest_buffer / o.throughput
            )
            harvest = min(h_want, o.harvest_ceiling)
            spot_resid = max(0.0, residual - harvest * o.throughput)
            spot = math.ceil(spot_resid * self.spot_buffer / o.throughput)
            out[a] = Action(
                target=floor, spot_target=spot, harvest_target=harvest,
                remote_target=remote, offload="slack_aware",
            )
        return out


SCHEDULERS["portfolio"] = PortfolioPolicy


# ---------------------------------------------------------------------------
# Variant-aware policies (the model-heterogeneity half of the paper's
# joint model x resource decision space).  Both ride on Paragon's
# class-aware procurement and add a ``variant`` decision per arch; on a
# variant-blind engine run (single-variant catalog) they degrade to
# exactly Paragon.
# ---------------------------------------------------------------------------
def _swap_aware_target_scalar(o: ArchObs, bursty_threshold: float,
                              flat_cushion: float,
                              drain_horizon_s: float) -> int:
    """Paragon sizing against the slower of the active / in-flight
    variant's service rate — the dict-form analog of the vector
    :func:`_swap_aware_target`, shared by both variant-aware dict
    policies so the rule cannot diverge between them."""
    bursty = o.peak_to_median >= bursty_threshold
    headroom = 1.0 if bursty else flat_cushion
    demand = o.ewma_rate + o.queue_len / drain_horizon_s
    thr = o.throughput * min(1.0, o.variant_pending_ratio)
    return max(1, math.ceil(demand * headroom / thr))


@dataclass
class InfaasVariantPolicy(ParagonPolicy):
    """INFaaS-style variant tuning: upgrade on slack, downgrade on queue
    pressure (along the accuracy-ordered variant set, never below the
    stream's accuracy floor), with a per-arch cooldown so the swap
    pipeline is not thrashed.

    Swap-aware guards: a downgrade must land on a strictly *faster*
    variant (pressure wants service rate, and accuracy order does not
    imply rate order), an upgrade must keep the projected post-swap
    utilization under ``post_swap_util``, and while a swap is in flight
    the fleet is sized for the slower of the old/new service rates (the
    reload lands before provisioning could catch up otherwise)."""

    up_util: float = 0.55          # upgrade only when the fleet has slack
    down_util: float = 0.9         # downgrade when saturated / backlogged
    post_swap_util: float = 0.75   # projected utilization bound after an
                                   # upgrade lands
    queue_pressure_s: float = 2.0  # backlog worth this many seconds of
                                   # service counts as pressure
    cooldown_s: int = 120
    _last_move: Dict[str, int] = field(default_factory=dict)

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        out = super().__call__(tick, obs)
        for a, o in obs.items():
            out[a].target = _swap_aware_target_scalar(
                o, self.bursty_threshold, self.flat_cushion,
                self.drain_horizon_s,
            )
            if (
                o.variant_in_flight
                or tick - self._last_move.get(a, -(10**9)) < self.cooldown_s
            ):
                continue
            cap = max(o.n_active, 1) * o.throughput
            # queue_len includes this tick's (not yet served) arrivals;
            # pressure / slack are about the carried-over backlog
            backlog = o.queue_len - o.rate
            pressure = (
                o.utilization >= self.down_util
                or backlog > self.queue_pressure_s * cap
            )
            slack = o.utilization <= self.up_util and backlog <= 1e-6
            if (
                pressure
                and o.active_variant > o.variant_lo
                and o.variant_down_ratio > 1.0 + 1e-9
            ):
                out[a].variant = o.active_variant - 1
                self._last_move[a] = tick
            elif (
                slack
                and not pressure
                and o.active_variant < o.n_variants - 1
                and o.utilization / o.variant_up_ratio <= self.post_swap_util
            ):
                out[a].variant = o.active_variant + 1
                self._last_move[a] = tick
        return out


@dataclass
class AccuracyFloorPolicy(ParagonPolicy):
    """Constraint-first variant choice: pin every arch to the cheapest
    variant meeting its accuracy floor (the runtime form of the paper's
    least-cost selection, recomputed as swaps land).  Sizing is
    swap-aware: while a reload is in flight the fleet covers the slower
    of the old/new service rates."""

    def __call__(self, tick: int, obs: Dict[str, ArchObs]) -> Dict[str, Action]:
        out = super().__call__(tick, obs)
        for a, o in obs.items():
            out[a].target = _swap_aware_target_scalar(
                o, self.bursty_threshold, self.flat_cushion,
                self.drain_horizon_s,
            )
            if not o.variant_in_flight and o.active_variant != o.variant_cheapest:
                out[a].variant = o.variant_cheapest
        return out


SCHEDULERS["infaas_variant"] = InfaasVariantPolicy
SCHEDULERS["accuracy_floor"] = AccuracyFloorPolicy


# ---------------------------------------------------------------------------
# Vectorized policies (structure-of-arrays, for pool-scale simulations).
#
# Same decision rules as their dict counterparts above, expressed over
# ``PoolObs`` arrays so a 50-100 arch pool costs a handful of NumPy ops
# per tick instead of a Python loop.  ``vectorized = True`` routes them
# through the engine's SoA interface in ``simulate``.
# ---------------------------------------------------------------------------
def _scale_target_vec(
    throughput: np.ndarray, demand: np.ndarray, headroom=1.0
) -> np.ndarray:
    return np.maximum(1, np.ceil(demand * headroom / throughput)).astype(np.int64)


@dataclass
class VectorReactivePolicy:
    """Vector form of :class:`ReactivePolicy`."""

    vectorized = True

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        return PoolAction(target=_scale_target_vec(obs.throughput, obs.ewma_rate))


@dataclass
class VectorParagonPolicy:
    """Vector form of :class:`ParagonPolicy` (same knobs, same decisions)."""

    vectorized = True
    bursty_threshold: float = 1.5
    flat_cushion: float = 1.1
    drain_horizon_s: float = 5.0

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        bursty = obs.peak_to_median >= self.bursty_threshold
        headroom = np.where(bursty, 1.0, self.flat_cushion)
        demand = obs.ewma_rate + obs.queue_len / self.drain_horizon_s
        return PoolAction(
            target=_scale_target_vec(obs.throughput, demand, headroom),
            offload=np.full(len(obs.keys), OFFLOAD_SLACK_AWARE, dtype=np.int64),
        )


@dataclass
class VectorUtilAwarePolicy:
    """Vector form of :class:`UtilAwarePolicy`: the per-arch target /
    cooldown dicts become ``[A]`` arrays initialized on the first call."""

    vectorized = True
    util_target: float = 0.8
    scale_down_util: float = 0.4
    up_cooldown_s: int = 30
    down_cooldown_s: int = 120
    _targets: np.ndarray = None
    _last_up: np.ndarray = None
    _last_down: np.ndarray = None

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        n = len(obs.keys)
        if self._targets is None:
            self._targets = np.maximum(obs.n_active + obs.n_pending, 1).astype(
                np.int64
            )
            self._last_up = np.full(n, -10**9, dtype=np.int64)
            self._last_down = np.full(n, -10**9, dtype=np.int64)
        cur = self._targets
        up = (obs.utilization > self.util_target) & (
            tick - self._last_up >= self.up_cooldown_s
        )
        down = (
            ~up
            & (obs.utilization < self.scale_down_util)
            & (cur > 1)
            & (tick - self._last_down >= self.down_cooldown_s)
        )
        up_target = np.maximum(
            cur + 1,
            _scale_target_vec(obs.throughput, obs.ewma_rate, 1.0 / self.util_target),
        )
        cur = np.where(up, up_target, np.where(down, cur - 1, cur))
        self._last_up = np.where(up, tick, self._last_up)
        self._last_down = np.where(down, tick, self._last_down)
        self._targets = cur
        return PoolAction(target=cur)


@dataclass
class VectorExascalePolicy:
    """Vector form of :class:`ExascalePolicy`."""

    vectorized = True
    headroom: float = 1.15

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        return PoolAction(
            target=_scale_target_vec(
                obs.throughput,
                np.maximum(obs.window_peak, obs.ewma_rate),
                self.headroom,
            )
        )


@dataclass
class VectorMixedPolicy:
    """Vector form of :class:`MixedPolicy`."""

    vectorized = True

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        return PoolAction(
            target=_scale_target_vec(obs.throughput, obs.ewma_rate),
            offload=np.full(len(obs.keys), OFFLOAD_BLIND, dtype=np.int64),
        )


@dataclass
class VectorSpotParagonPolicy(VectorParagonPolicy):
    """Vector form of :class:`SpotParagonPolicy` (same knobs, same
    decisions: on-demand floor for the strict share, spot for the rest)."""

    strict_share: float = 0.25
    spot_buffer: float = 1.25

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        demand = obs.ewma_rate + obs.queue_len / self.drain_horizon_s
        floor = _scale_target_vec(obs.throughput, demand, self.strict_share)
        residual = np.maximum(0.0, demand - floor * obs.throughput)
        spot = np.ceil(residual * self.spot_buffer / obs.throughput).astype(
            np.int64
        )
        return PoolAction(
            target=floor,
            spot_target=spot,
            offload=np.full(len(obs.keys), OFFLOAD_SLACK_AWARE, dtype=np.int64),
        )


@dataclass
class VectorPortfolioPolicy(VectorParagonPolicy):
    """Vector form of :class:`PortfolioPolicy` (same knobs, same
    decisions: reserved floor, remote relaxed base, harvest by reclaim
    risk under the granted ceiling, spot for the rest)."""

    strict_share: float = 0.25
    remote_frac: float = 0.3
    harvest_margin: float = 0.15
    harvest_max_frac: float = 0.8
    harvest_buffer: float = 1.1
    spot_buffer: float = 1.25

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        thr = obs.throughput
        demand = obs.ewma_rate + obs.queue_len / self.drain_horizon_s
        floor = _scale_target_vec(thr, demand, self.strict_share)
        remote = (
            self.remote_frac * (1 - self.strict_share) * obs.ewma_rate / thr
        ).astype(np.int64)
        residual = np.maximum(0.0, demand - (floor + remote) * thr)
        h_frac = np.minimum(
            np.maximum(obs.harvest_level - self.harvest_margin, 0.0),
            self.harvest_max_frac,
        )
        h_want = np.ceil(residual * h_frac * self.harvest_buffer / thr)
        harvest = np.minimum(h_want, obs.harvest_ceiling).astype(np.int64)
        spot_resid = np.maximum(0.0, residual - harvest * thr)
        spot = np.ceil(spot_resid * self.spot_buffer / thr).astype(np.int64)
        return PoolAction(
            target=floor,
            spot_target=spot,
            harvest_target=harvest,
            remote_target=remote,
            offload=np.full(len(obs.keys), OFFLOAD_SLACK_AWARE, dtype=np.int64),
        )


# ---------------------------------------------------------------------------
# Variant decision math, backend-parametric (``xp`` = numpy or jax.numpy).
# These are the single source of truth for the variant-aware policies:
# the dict schedulers, the vectorized schedulers below AND the in-scan
# ``JAX_POLICIES`` twins (``sim/jax_engine.py``) all evaluate the same
# expressions, so the three implementations cannot drift.  ``o`` maps
# :class:`PoolObs` field names to ``[A]`` arrays (same convention as
# ``repro.core.rl.obs.pool_features_arrays``); no jax import happens
# here — the backend is passed in.
# ---------------------------------------------------------------------------
def swap_aware_target_arrays(o, *, bursty_threshold: float,
                             flat_cushion: float, drain_horizon_s: float,
                             xp=np):
    """Paragon sizing against the slower of the active / in-flight
    variant's service rate (shared by the variant-aware policies)."""
    bursty = o["peak_to_median"] >= bursty_threshold
    headroom = xp.where(bursty, 1.0, flat_cushion)
    demand = o["ewma_rate"] + o["queue_len"] / drain_horizon_s
    thr = o["throughput"] * xp.minimum(1.0, o["variant_pending_ratio"])
    return xp.maximum(1, xp.ceil(demand * headroom / thr)).astype(xp.int64)


def infaas_variant_move_arrays(o, tick, last_move, *, up_util: float,
                               down_util: float, post_swap_util: float,
                               queue_pressure_s: float, cooldown_s: int,
                               xp=np):
    """The INFaaS-style up/down variant move as one branchless pass.

    Returns ``(variant_target, new_last_move)``: ``variant_target`` in
    engine codes (-1 = hold), ``new_last_move`` the updated per-arch
    cooldown state the caller carries between ticks.  ``down`` and
    ``up`` are mutually exclusive (pressure vs ~pressure), so the
    where-chain reproduces the masked-assignment form exactly."""
    cap = xp.maximum(o["n_active"], 1) * o["throughput"]
    # queue_len includes this tick's (not yet served) arrivals;
    # pressure / slack are about the carried-over backlog
    backlog = o["queue_len"] - o["rate"]
    pressure = (o["utilization"] >= down_util) | (
        backlog > queue_pressure_s * cap
    )
    slack = (o["utilization"] <= up_util) & (backlog <= 1e-6)
    ready = (~o["variant_in_flight"]) & (tick - last_move >= cooldown_s)
    down = (
        pressure & ready
        & (o["active_variant"] > o["variant_lo"])
        & (o["variant_down_ratio"] > 1.0 + 1e-9)
    )
    up = (
        slack & ~pressure & ready
        & (o["active_variant"] < o["n_variants"] - 1)
        & (o["utilization"] / o["variant_up_ratio"] <= post_swap_util)
    )
    tgt = xp.where(
        down, o["active_variant"] - 1,
        xp.where(up, o["active_variant"] + 1, -1),
    ).astype(xp.int64)
    new_last_move = xp.where(down | up, tick, last_move)
    return tgt, new_last_move


def accuracy_floor_move_arrays(o, xp=np):
    """Cocktail-style least-cost selection: move to the cheapest variant
    meeting the stream's floor (hold while a swap is in flight)."""
    return xp.where(
        (~o["variant_in_flight"])
        & (o["active_variant"] != o["variant_cheapest"]),
        o["variant_cheapest"],
        -1,
    ).astype(xp.int64)


def _variant_obs_dict(obs: PoolObs) -> dict:
    """The ``[A]``-array view of a :class:`PoolObs` the ``*_arrays``
    variant math consumes."""
    return {
        "rate": obs.rate,
        "ewma_rate": obs.ewma_rate,
        "peak_to_median": obs.peak_to_median,
        "queue_len": obs.queue_len,
        "n_active": obs.n_active,
        "utilization": obs.utilization,
        "throughput": obs.throughput,
        "active_variant": obs.active_variant,
        "n_variants": obs.n_variants,
        "variant_lo": obs.variant_lo,
        "variant_cheapest": obs.variant_cheapest,
        "variant_in_flight": obs.variant_in_flight,
        "variant_up_ratio": obs.variant_up_ratio,
        "variant_down_ratio": obs.variant_down_ratio,
        "variant_pending_ratio": obs.variant_pending_ratio,
    }


def _swap_aware_target(obs: PoolObs, bursty_threshold: float,
                       flat_cushion: float, drain_horizon_s: float) -> np.ndarray:
    return swap_aware_target_arrays(
        _variant_obs_dict(obs), bursty_threshold=bursty_threshold,
        flat_cushion=flat_cushion, drain_horizon_s=drain_horizon_s,
    )


@dataclass
class VectorInfaasVariantPolicy(VectorParagonPolicy):
    """Vector form of :class:`InfaasVariantPolicy` (same knobs, same
    decisions, the per-arch cooldown dict an ``[A]`` array)."""

    up_util: float = 0.55
    down_util: float = 0.9
    post_swap_util: float = 0.75
    queue_pressure_s: float = 2.0
    cooldown_s: int = 120
    _last_move: np.ndarray = None

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        act = super().__call__(tick, obs)
        act.target = _swap_aware_target(
            obs, self.bursty_threshold, self.flat_cushion, self.drain_horizon_s
        )
        n = len(obs.keys)
        if self._last_move is None:
            self._last_move = np.full(n, -(10**9), dtype=np.int64)
        tgt, self._last_move = infaas_variant_move_arrays(
            _variant_obs_dict(obs), tick, self._last_move,
            up_util=self.up_util, down_util=self.down_util,
            post_swap_util=self.post_swap_util,
            queue_pressure_s=self.queue_pressure_s,
            cooldown_s=self.cooldown_s,
        )
        act.variant_target = tgt
        return act


@dataclass
class VectorAccuracyFloorPolicy(VectorParagonPolicy):
    """Vector form of :class:`AccuracyFloorPolicy`."""

    def __call__(self, tick: int, obs: PoolObs) -> PoolAction:
        act = super().__call__(tick, obs)
        act.target = _swap_aware_target(
            obs, self.bursty_threshold, self.flat_cushion, self.drain_horizon_s
        )
        act.variant_target = accuracy_floor_move_arrays(_variant_obs_dict(obs))
        return act


VECTOR_SCHEDULERS = {
    "reactive": VectorReactivePolicy,
    "util_aware": VectorUtilAwarePolicy,
    "exascale": VectorExascalePolicy,
    "mixed": VectorMixedPolicy,
    "paragon": VectorParagonPolicy,
    "spot_paragon": VectorSpotParagonPolicy,
    "portfolio": VectorPortfolioPolicy,
    "infaas_variant": VectorInfaasVariantPolicy,
    "accuracy_floor": VectorAccuracyFloorPolicy,
}

# The learned pool controller (paper §V) rides the same vectorized
# interface so benchmarks evaluate it head-to-head with the classical
# schemes.  Imported late: repro.core.rl reuses the sim types above.
from repro.core.rl.policy import RLPoolPolicy  # noqa: E402

VECTOR_SCHEDULERS["rl_pool"] = RLPoolPolicy
