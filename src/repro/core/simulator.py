"""Discrete-event serving simulator (paper §II-C / §IV methodology).

Time-stepped fluid simulation at 1 s ticks: trace-driven arrivals fan out
over a model pool, each (arch, latency-class) pair keeps an age-bucketed
FIFO queue, reserved slices serve at their profiled throughput, and a
procurement policy decides — every tick — the reserved-fleet targets and
which queued requests to offload to burst instances.

Faithful to the paper's methodology section: profiled values (here from
:mod:`repro.core.profiles`, the analytical TPU characterization) drive a
trace simulation; requests are associated with models from the pool; cost,
SLO violations and over-provisioning are the reported metrics.
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.hardware import PRICING, FleetPricing
from repro.core.load_monitor import LoadMonitor
from repro.core.profiles import (
    STANDARD,
    ModelProfile,
    RequestClass,
    get_profile,
)

STRICT = RequestClass("strict", 512, 64, slo_s=2.0, strict=True)
RELAXED = RequestClass("relaxed", 512, 64, slo_s=20.0, strict=False)


# ---------------------------------------------------------------------------
# Workload description.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ArchLoad:
    arch: str
    share: float                   # fraction of total arrivals
    strict_frac: float = 0.5       # strict vs relaxed query mix (workload-1)


def uniform_pool_workload(archs: List[str], strict_frac: float = 0.5) -> List[ArchLoad]:
    return [ArchLoad(a, 1.0 / len(archs), strict_frac) for a in archs]


# ---------------------------------------------------------------------------
# Policy interface.
# ---------------------------------------------------------------------------
@dataclass
class ArchObs:
    arch: str
    rate: float                    # this tick's arrivals (req/s)
    ewma_rate: float
    window_peak: float
    peak_to_median: float
    queue_len: float
    n_active: int
    n_pending: int
    n_spot: int
    throughput: float              # per-instance req/s
    utilization: float             # served / capacity, last tick


@dataclass
class Action:
    """Per-arch procurement decision for this tick.

    ``offload`` semantics (who may go to burst, and when):
      ``none``        — VM-only procurement (reactive / util_aware / exascale)
      ``blind``       — ANY request not served this tick is offloaded
                        immediately (MArk/Spock: one global SLO assumption)
      ``slack_aware`` — a request offloads only when its own latency class
                        is about to violate (paper's Paragon: relaxed
                        queries ride out the spike in queue first)
    """

    target: int                    # desired reserved (on-demand) instances
    offload: str = "none"          # none | blind | slack_aware
    spot_target: int = 0           # desired SPOT instances (preemptible,
                                   # spot_discount x price — §VI extension)


Policy = Callable[[int, Dict[str, ArchObs]], Dict[str, Action]]


# ---------------------------------------------------------------------------
# Per-(arch, class) FIFO queue with age buckets.
# ---------------------------------------------------------------------------
class _Queue:
    __slots__ = ("buckets",)

    def __init__(self) -> None:
        self.buckets: Deque[List[float]] = deque()  # [arrival_tick, count]

    def push(self, tick: int, count: float) -> None:
        if count > 0:
            self.buckets.append([tick, count])

    def __len__(self) -> int:
        return int(sum(c for _, c in self.buckets))

    @property
    def total(self) -> float:
        return sum(c for _, c in self.buckets)

    def pop(self, amount: float) -> List[Tuple[int, float]]:
        """Serve ``amount`` oldest-first; returns [(arrival_tick, count)]."""
        out: List[Tuple[int, float]] = []
        while amount > 1e-9 and self.buckets:
            t0, c = self.buckets[0]
            take = min(c, amount)
            out.append((t0, take))
            amount -= take
            if take >= c - 1e-12:
                self.buckets.popleft()
            else:
                self.buckets[0][1] = c - take
        return out

    def pop_older_than(self, tick: int, max_age: int) -> float:
        """Remove and return the count of entries with age > max_age."""
        n = 0.0
        while self.buckets and tick - self.buckets[0][0] > max_age:
            n += self.buckets.popleft()[1]
        return n


# ---------------------------------------------------------------------------
# Per-arch serving state.
# ---------------------------------------------------------------------------
class _ArchState:
    def __init__(self, load: ArchLoad, pricing: FleetPricing, prewarm: bool):
        self.load = load
        self.prof: ModelProfile = get_profile(load.arch, req=STRICT)
        self.throughput = self.prof.throughput(STRICT)
        assert self.throughput > 0, f"{load.arch} cannot meet the strict SLO"
        self.lat_b1 = self.prof.request_latency(STRICT, 1)
        self.slack = {
            "strict": max(0, int(STRICT.slo_s - self.lat_b1)),
            "relaxed": max(0, int(RELAXED.slo_s - self.lat_b1)),
        }
        self.queues = {"strict": _Queue(), "relaxed": _Queue()}
        self.n_active = 0
        self.pending: List[int] = []           # ready ticks
        self.n_spot = 0
        self.spot_pending: List[int] = []
        self.monitor = LoadMonitor()
        self.last_util = 0.0
        # burst pool warmth: last tick the pool saw this model
        self.burst_last_used = 0.0 if prewarm else -math.inf
        self.pricing = pricing
        # provider-batched burst billing (see ModelProfile.burst_cost_per_request)
        self.burst_per_req = (
            self.prof.chips / self.throughput
        ) * pricing.burst_chip_s + pricing.burst_invocation_fee

    # -- burst ----------------------------------------------------------------
    def burst_latency(self, tick: int) -> float:
        cold = (tick - self.burst_last_used) > self.pricing.burst_idle_timeout_s
        lat = self.pricing.burst_spinup_s + self.lat_b1
        if cold:
            lat += self.prof.cold_start_s()
        return lat


# ---------------------------------------------------------------------------
# Result record.
# ---------------------------------------------------------------------------
@dataclass
class SimResult:
    cost_reserved: float = 0.0
    cost_spot: float = 0.0
    cost_burst: float = 0.0
    served_vm: float = 0.0
    served_burst: float = 0.0
    violations: float = 0.0
    violations_strict: float = 0.0
    total_requests: float = 0.0
    chip_seconds: float = 0.0
    chip_seconds_needed: float = 0.0
    chip_seconds_over: float = 0.0
    timeline: List[dict] = field(default_factory=list)

    preemptions: int = 0

    @property
    def cost_total(self) -> float:
        return self.cost_reserved + self.cost_spot + self.cost_burst

    @property
    def violation_rate(self) -> float:
        return self.violations / max(self.total_requests, 1e-9)

    @property
    def overprovision_ratio(self) -> float:
        """Idle-capacity chip-seconds as a fraction of needed chip-seconds."""
        return self.chip_seconds_over / max(self.chip_seconds_needed, 1e-9)

    def summary(self) -> dict:
        return {
            "cost_total": round(self.cost_total, 4),
            "cost_reserved": round(self.cost_reserved, 4),
            "cost_spot": round(self.cost_spot, 4),
            "cost_burst": round(self.cost_burst, 4),
            "preemptions": self.preemptions,
            "violation_rate": round(self.violation_rate, 5),
            "violations_strict": round(self.violations_strict, 1),
            "served_vm": round(self.served_vm, 1),
            "served_burst": round(self.served_burst, 1),
            "overprovision_ratio": round(self.overprovision_ratio, 4),
            "chip_seconds": round(self.chip_seconds, 1),
        }


# ---------------------------------------------------------------------------
# The simulator: stepwise core (RL env drives it tick-by-tick) + the
# closed-loop ``simulate()`` wrapper used by benchmarks and tests.
# ---------------------------------------------------------------------------
class ServingSim:
    """Stepwise serving simulator: ``observe() -> actions -> apply()``."""

    def __init__(
        self,
        trace: np.ndarray,
        workload: List[ArchLoad],
        *,
        pricing: FleetPricing = PRICING,
        prewarm: bool = True,
        warm_start: bool = True,
        seed: int = 0,
    ):
        self.trace = trace
        self.pricing = pricing
        self.rng = np.random.default_rng(seed)   # spot preemption draws
        self.states = {w.arch: _ArchState(w, pricing, prewarm) for w in workload}
        self.res = SimResult()
        self.tick = 0
        if warm_start:
            for st in self.states.values():
                st.n_active = max(
                    1, math.ceil(trace[0] * st.load.share / st.throughput)
                )

    @property
    def done(self) -> bool:
        return self.tick >= len(self.trace)

    def observe(self) -> Dict[str, ArchObs]:
        """Admit this tick's arrivals and return per-arch observations."""
        tick = self.tick
        rate = float(self.trace[tick])
        obs: Dict[str, ArchObs] = {}
        for arch, st in self.states.items():
            a_rate = rate * st.load.share
            st.monitor.observe(a_rate)
            n_strict = a_rate * st.load.strict_frac
            st.queues["strict"].push(tick, n_strict)
            st.queues["relaxed"].push(tick, a_rate - n_strict)
            self.res.total_requests += a_rate
            obs[arch] = ArchObs(
                arch=arch,
                rate=a_rate,
                ewma_rate=st.monitor.rate,
                window_peak=st.monitor.peak,
                peak_to_median=st.monitor.peak_to_median,
                queue_len=st.queues["strict"].total + st.queues["relaxed"].total,
                n_active=st.n_active,
                n_pending=len(st.pending),
                n_spot=st.n_spot,
                throughput=st.throughput,
                utilization=st.last_util,
            )
        self._last_obs = obs
        return obs

    def apply(self, actions: Dict[str, Action]) -> dict:
        """Apply procurement actions, serve the tick, advance time.

        Returns this tick's marginal metrics (for RL rewards)."""
        tick = self.tick
        res = self.res
        pricing = self.pricing
        obs = self._last_obs
        cost0, viol0 = res.cost_total, res.violations
        for arch, st in self.states.items():
            act = actions.get(arch, Action(target=st.n_active))

            # provisioning pipeline
            ready = [r for r in st.pending if r <= tick]
            st.n_active += len(ready)
            st.pending = [r for r in st.pending if r > tick]
            in_flight = st.n_active + len(st.pending)
            if act.target > in_flight:
                st.pending.extend(
                    [tick + int(pricing.reserved_provision_s)]
                    * (act.target - in_flight)
                )
            elif act.target < in_flight:
                # cancel not-yet-ready slices first, then release active ones
                cancel = min(len(st.pending), in_flight - act.target)
                if cancel:
                    st.pending = st.pending[: len(st.pending) - cancel]
                st.n_active = min(st.n_active, max(act.target, 0))

            # --- spot tier (§VI extension): Poisson reclaim, then scale ---
            if st.n_spot > 0:
                p_reclaim = 1.0 - math.exp(-pricing.spot_preempt_rate)
                reclaimed = int(self.rng.binomial(st.n_spot, p_reclaim))
                if reclaimed:
                    st.n_spot -= reclaimed
                    res.preemptions += reclaimed
            ready_s = [r for r in st.spot_pending if r <= tick]
            st.n_spot += len(ready_s)
            st.spot_pending = [r for r in st.spot_pending if r > tick]
            spot_in_flight = st.n_spot + len(st.spot_pending)
            if act.spot_target > spot_in_flight:
                st.spot_pending.extend(
                    [tick + int(pricing.spot_provision_s)]
                    * (act.spot_target - spot_in_flight)
                )
            elif act.spot_target < spot_in_flight:
                cancel = min(len(st.spot_pending), spot_in_flight - act.spot_target)
                if cancel:
                    st.spot_pending = st.spot_pending[: len(st.spot_pending) - cancel]
                st.n_spot = min(st.n_spot, max(act.spot_target, 0))

            # serve from queues, strict first
            capacity = (st.n_active + st.n_spot) * st.throughput
            served = 0.0
            for cls in ("strict", "relaxed"):
                take = st.queues[cls].pop(capacity - served)
                for t0, cnt in take:
                    if tick - t0 > st.slack[cls]:
                        res.violations += cnt
                        if cls == "strict":
                            res.violations_strict += cnt
                    served += cnt
                    res.served_vm += cnt
            st.last_util = served / capacity if capacity > 0 else 1.0

            # offload decision: what leaves the queue for burst instances.
            #   blind       — anything unserved goes now, both classes
            #                 (MArk/Spock assume one global SLO)
            #   slack_aware — Paragon: strict queries offload when a VM
            #                 slot is unavailable; relaxed queries NEVER
            #                 pay the burst premium ("does not offload to
            #                 lambdas for relaxed latency queries", §IV-B)
            if act.offload in ("blind", "slack_aware"):
                classes = ("strict", "relaxed") if act.offload == "blind" else ("strict",)
                for cls in classes:
                    slo = STRICT.slo_s if cls == "strict" else RELAXED.slo_s
                    offl = st.queues[cls].pop_older_than(tick, -1)
                    if offl <= 0:
                        continue
                    blat = st.burst_latency(tick)
                    st.burst_last_used = tick
                    res.cost_burst += st.burst_per_req * offl
                    res.served_burst += offl
                    if blat > slo:
                        res.violations += offl
                        if cls == "strict":
                            res.violations_strict += offl

            # abandon hopeless VM-only waiters (count violation once):
            # anything older than 3x its SLO is recorded and dropped so
            # queues cannot grow without bound under sustained shortfall.
            for cls in ("strict", "relaxed"):
                slo = STRICT.slo_s if cls == "strict" else RELAXED.slo_s
                dropped = st.queues[cls].pop_older_than(tick, int(3 * slo))
                if dropped > 0:
                    res.violations += dropped
                    if cls == "strict":
                        res.violations_strict += dropped
                    res.served_vm += dropped   # still answered, just very late

            # accounting
            chips = st.n_active * st.prof.chips
            spot_chips = st.n_spot * st.prof.chips
            res.cost_reserved += chips * pricing.reserved_chip_s
            res.cost_spot += (
                spot_chips * pricing.reserved_chip_s * pricing.spot_discount
            )
            res.chip_seconds += chips + spot_chips
            need = math.ceil(obs[arch].rate / st.throughput) * st.prof.chips
            res.chip_seconds_needed += need
            res.chip_seconds_over += max(0, chips + spot_chips - need)

        self.tick += 1
        if self.done:
            self._finalize()
        return {
            "cost": res.cost_total - cost0,
            "violations": res.violations - viol0,
        }

    def _finalize(self) -> None:
        # end-of-trace: whatever is still queued past its slack violates
        for st in self.states.values():
            for cls in ("strict", "relaxed"):
                late = st.queues[cls].pop_older_than(len(self.trace), st.slack[cls])
                self.res.violations += late
                if cls == "strict":
                    self.res.violations_strict += late

    def snapshot(self) -> dict:
        return {
            "t": self.tick,
            "rate": float(self.trace[min(self.tick, len(self.trace) - 1)]),
            "active": {a: s.n_active for a, s in self.states.items()},
            "queued": {
                a: s.queues["strict"].total + s.queues["relaxed"].total
                for a, s in self.states.items()
            },
        }


def simulate(
    trace: np.ndarray,                       # per-second arrival rate (req/s)
    workload: List[ArchLoad],
    policy: Policy,
    *,
    pricing: FleetPricing = PRICING,
    prewarm: bool = True,
    warm_start: bool = True,                 # fleet starts sized for t=0 load
    record_timeline: bool = False,
) -> SimResult:
    """Closed-loop run: the policy drives ``ServingSim`` over the trace."""
    sim = ServingSim(
        trace, workload, pricing=pricing, prewarm=prewarm, warm_start=warm_start
    )
    while not sim.done:
        obs = sim.observe()
        actions = policy(sim.tick, obs)
        if record_timeline:
            sim.res.timeline.append(sim.snapshot())
        sim.apply(actions)
    return sim.res
