"""Compatibility shim — the simulator now lives in :mod:`repro.core.sim`.

The seed's monolithic ``ServingSim`` was decomposed into composable
subsystems (queues / fleet tiers / accounting / engine); this module
re-exports the public surface so seed-era imports keep working:

    from repro.core.simulator import ServingSim, simulate, Action, ArchObs

New code should import from :mod:`repro.core.sim` directly.
"""
from repro.core.sim import (  # noqa: F401
    Action,
    ArchLoad,
    ArchObs,
    BucketQueue,
    Policy,
    PoolAction,
    PoolObs,
    RELAXED,
    STRICT,
    ServingSim,
    SimResult,
    simulate,
    uniform_pool_workload,
)

# seed-era private name for the scalar queue, kept so old imports of
# ``repro.core.simulator._Queue`` keep resolving
_Queue = BucketQueue
