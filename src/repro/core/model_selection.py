"""Model selection (paper §III-A, §IV-C2).

A query arrives with an (accuracy, latency) constraint pair; the selector
maps it to a member of the model pool:

  ``naive``   — constraint-blind default: grab the most accurate model that
                responds within the latency bound, cost be damned (the
                paper's "naive constraints-unaware" baseline, Fig 9c).
  ``paragon`` — the paper's scheme: among ALL models satisfying both the
                accuracy and the latency constraints, pick the one with the
                least serving cost ("chooses the least costing model").

The accuracy/latency candidate filter itself lives with the runtime
variant axis (:func:`repro.core.sim.types.filter_pool_candidates`) — the
offline selector here and the engine's :class:`~repro.core.sim.types.VariantCatalog`
are two consumers of the same predicate, so the offline and runtime
accuracy axes cannot drift.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from repro.core.profiles import RequestClass, STANDARD, model_pool
from repro.core.sim.types import filter_pool_candidates


@dataclass(frozen=True)
class Constraint:
    min_accuracy: float = 0.0
    max_latency_s: float = float("inf")


class NoFeasibleModel(Exception):
    pass


def feasible_set(c: Constraint, req: RequestClass = STANDARD) -> Dict[str, dict]:
    return filter_pool_candidates(
        model_pool(req),
        min_accuracy=c.min_accuracy,
        max_latency_s=c.max_latency_s,
    )


def select_naive(c: Constraint, req: RequestClass = STANDARD) -> str:
    """Max-accuracy-within-latency, oblivious to cost and to the accuracy
    constraint actually requested (it always over-delivers)."""
    cands = filter_pool_candidates(
        model_pool(req), max_latency_s=c.max_latency_s
    )
    if not cands:
        raise NoFeasibleModel(str(c))
    return max(cands, key=lambda a: cands[a]["accuracy"])


def select_paragon(c: Constraint, req: RequestClass = STANDARD) -> str:
    """Least-cost model satisfying BOTH constraints (paper Fig 9c)."""
    cands = feasible_set(c, req)
    if not cands:
        raise NoFeasibleModel(str(c))
    return min(cands, key=lambda a: cands[a]["cost_per_1k"])


SELECTORS = {"naive": select_naive, "paragon": select_paragon}


def selection_cost(
    constraints: List[Constraint],
    selector: str,
    req: RequestClass = STANDARD,
    requests_per_constraint: float = 1000.0,
) -> dict:
    """Serve each constraint's stream with the selector's model choice and
    report aggregate cost + delivered accuracy/latency."""
    pool = model_pool(req)
    pick = SELECTORS[selector]
    total_cost = 0.0
    accs, lats = [], []
    choices = []
    for c in constraints:
        arch = pick(c, req)
        e = pool[arch]
        total_cost += e["cost_per_1k"] * requests_per_constraint / 1000.0
        accs.append(e["accuracy"])
        lats.append(e["latency_s"])
        choices.append(arch)
    return {
        "selector": selector,
        "cost": total_cost,
        "mean_accuracy": sum(accs) / len(accs),
        "mean_latency": sum(lats) / len(lats),
        "choices": choices,
    }


def selection_workload(
    constraints: List[Constraint],
    selector: str,
    *,
    strict_frac: float = 0.25,
    req: RequestClass = STANDARD,
):
    """Route a constraint stream through a selector into per-arch traffic
    shares (the paper's workload-2 as a *dynamic* workload: each query's
    model is chosen by the selection policy, and the resulting shares
    drive the fleet simulator).

    Returns (ArchLoad list, skipped) where ``skipped`` counts constraints
    no model satisfies (dropped from the stream).
    """
    from repro.core.sim import ArchLoad  # local: avoid import cycle

    pick = SELECTORS[selector]
    counts: Dict[str, int] = {}
    skipped = 0
    for c in constraints:
        try:
            arch = pick(c, req)
        except NoFeasibleModel:
            skipped += 1
            continue
        counts[arch] = counts.get(arch, 0) + 1
    total = max(sum(counts.values()), 1)
    loads = [
        ArchLoad(arch, n / total, strict_frac)
        for arch, n in sorted(counts.items())
    ]
    return loads, skipped
