"""Resource tiers — the procurement side of the serving fleet.

The paper's system buys capacity from heterogeneous cloud offerings:
long-lived reserved slices (VMs), preemptible spot slices (§VI), and a
per-invocation burst pool (serverless functions).  Each offering is one
:class:`ResourceTier`: it owns its pool-wide instance counts as arrays,
runs its provisioning pipeline each tick, and knows its price.  Adding a
new offering (harvest VMs, a second region, ...) is one subclass — the
engine only speaks the tier interface.

All state is structure-of-arrays over the pool: ``active[a]`` instances
per arch, and a :class:`ProvisionPipeline` ring buffer of launches in
flight, so a tick is O(A) NumPy work regardless of pool size.
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np

from repro.core.hardware import FleetPricing
from repro.core.sim.accounting import Ledger


# ---------------------------------------------------------------------------
# Fixed-latency provisioning pipeline, vectorized over the pool.
# ---------------------------------------------------------------------------
class ProvisionPipeline:
    """Launches become ready exactly ``latency_s`` ticks later.

    ``buf[a, t % L]`` counts instances arch ``a`` launched at tick ``t``;
    cancellations remove the *newest* launches first (matching the seed
    semantics: not-yet-ready slices are cancelled before active ones are
    released).
    """

    def __init__(self, n_archs: int, latency_s: float):
        self.lat = max(int(latency_s), 1)
        self.buf = np.zeros((n_archs, self.lat), dtype=np.int64)
        self.total = np.zeros(n_archs, dtype=np.int64)

    def pop_ready(self, tick: int) -> np.ndarray:
        """Instances launched ``lat`` ticks ago come online now."""
        col = tick % self.lat
        ready = self.buf[:, col].copy()
        self.buf[:, col] = 0
        self.total -= ready
        return ready

    def launch(self, tick: int, counts: np.ndarray) -> None:
        self.buf[:, tick % self.lat] += counts
        self.total += counts

    def cancel_newest(self, tick: int, counts: np.ndarray) -> None:
        """Cancel up to ``counts[a]`` in-flight launches, newest first."""
        launch_ticks = np.arange(tick, tick - self.lat, -1)   # newest -> oldest
        idx = launch_ticks % self.lat
        pending = self.buf[:, idx]
        before = np.cumsum(pending, axis=1) - pending
        take = np.minimum(pending, np.clip(counts[:, None] - before, 0, None))
        self.buf[:, idx] = pending - take
        self.total -= take.sum(axis=1)


# ---------------------------------------------------------------------------
# Model-variant swap pipeline (INFaaS-style runtime variant switching).
# ---------------------------------------------------------------------------
class SwapPipeline:
    """Variant swaps in flight, vectorized over the pool.

    A swap requested at tick ``t`` becomes effective at ``t + lat``; the
    arch keeps serving (and billing) at the **old** variant until then —
    the weight reload occupies the held slices, like a provisioning
    pipeline occupies the lead time.  At most one swap per arch is in
    flight; semantics mirror provisioning's cancel-newest-first:

    * a request for a *different* target replaces the in-flight swap and
      restarts the clock (the newest decision wins, the not-yet-ready
      one is cancelled);
    * re-requesting the in-flight target leaves its clock alone;
    * re-requesting the *current* variant cancels the in-flight swap
      outright (nothing ever becomes ready).
    """

    def __init__(self, current: np.ndarray, latency_s: float):
        self.lat = max(int(latency_s), 1)
        self.current = np.asarray(current, dtype=np.int64).copy()
        n = len(self.current)
        self.pending = np.full(n, -1, dtype=np.int64)
        self.ready_at = np.zeros(n, dtype=np.int64)
        self.completed = 0                     # lifetime swap count

    @property
    def in_flight(self) -> np.ndarray:
        return self.pending >= 0

    def pop_ready(self, tick: int) -> np.ndarray:
        """Complete due swaps; returns the boolean completion mask."""
        done = (self.pending >= 0) & (self.ready_at <= tick)
        if done.any():
            self.current[done] = self.pending[done]
            self.pending[done] = -1
            self.completed += int(done.sum())
        return done

    def request(self, tick: int, target: np.ndarray) -> None:
        """Apply per-arch swap requests (``target[a] = -1`` means hold)."""
        t = np.asarray(target, dtype=np.int64)
        cancel = (t >= 0) & (t == self.current)
        self.pending[cancel] = -1
        start = (t >= 0) & (t != self.current) & (t != self.pending)
        if start.any():
            self.pending[start] = t[start]
            self.ready_at[start] = tick + self.lat


# ---------------------------------------------------------------------------
# Tier base: reserved (on-demand) slices.
# ---------------------------------------------------------------------------
class ResourceTier:
    """A pool of slices with a provisioning pipeline and a price.

    Tick protocol (driven by the engine):
      ``begin_tick``  — tier-internal events (e.g. spot reclaims)
      ``set_target``  — provisioning: admit ready launches, then grow or
                        shrink toward the policy's per-arch target
      ``account``     — bill this tick's held capacity into the ledger
    """

    name = "reserved"

    def __init__(self, n_archs: int, pricing: FleetPricing):
        self.pricing = pricing
        self.active = np.zeros(n_archs, dtype=np.int64)
        self.pipeline = ProvisionPipeline(n_archs, self.provision_latency_s())

    # -- per-tier knobs ------------------------------------------------------
    def provision_latency_s(self) -> float:
        return self.pricing.reserved_provision_s

    def price_per_chip_s(self) -> float:
        return self.pricing.reserved_chip_s

    # -- tick protocol -------------------------------------------------------
    def begin_tick(self, tick: int, rng: np.random.Generator, ledger: Ledger) -> None:
        """Tier-internal events before provisioning (default: none)."""

    def set_target(self, tick: int, target: np.ndarray) -> None:
        self.active += self.pipeline.pop_ready(tick)
        in_flight = self.active + self.pipeline.total
        grow = np.maximum(target - in_flight, 0)
        if grow.any():
            self.pipeline.launch(tick, grow)
        shrink = in_flight - target
        if (shrink > 0).any():
            cancel = np.clip(np.minimum(self.pipeline.total, shrink), 0, None)
            if cancel.any():
                self.pipeline.cancel_newest(tick, cancel)
            self.active = np.where(
                shrink > 0,
                np.minimum(self.active, np.maximum(target, 0)),
                self.active,
            )

    def account(self, ledger: Ledger, chips_per_instance: np.ndarray) -> np.ndarray:
        """Bill held capacity; returns this tier's chip-seconds per arch."""
        chip_s = self.active * chips_per_instance
        ledger.add_tier_cost(self.name, float(chip_s.sum()) * self.price_per_chip_s())
        return chip_s

    @property
    def pending_total(self) -> np.ndarray:
        return self.pipeline.total


# ---------------------------------------------------------------------------
# Spot tier: cheap, preemptible (paper §VI future work, implemented).
# ---------------------------------------------------------------------------
class SpotTier(ResourceTier):
    name = "spot"

    def provision_latency_s(self) -> float:
        return self.pricing.spot_provision_s

    def price_per_chip_s(self) -> float:
        return self.pricing.reserved_chip_s * self.pricing.spot_discount

    def begin_tick(self, tick: int, rng: np.random.Generator, ledger: Ledger) -> None:
        if self.active.any():
            p_reclaim = 1.0 - math.exp(-self.pricing.spot_preempt_rate)
            reclaimed = rng.binomial(self.active, p_reclaim)
            self.active -= reclaimed
            ledger.add_preemptions(int(reclaimed.sum()))


# ---------------------------------------------------------------------------
# Burst tier: per-invocation serverless pool (no instances held).
# ---------------------------------------------------------------------------
class BurstTier:
    """The serverless analog: requests offloaded here never queue — they
    pay a premium per invocation and a spin-up (plus cold-start when the
    pool has not seen the model within the idle timeout)."""

    name = "burst"

    def __init__(
        self,
        pricing: FleetPricing,
        lat_b1: np.ndarray,            # batch-1 model latency per arch
        cold_start_s: np.ndarray,      # weight-fetch cold start per arch
        cost_per_request: np.ndarray,  # provider-batched billing per arch
        prewarm: bool,
    ):
        n = len(lat_b1)
        self.pricing = pricing
        self.lat_b1 = np.asarray(lat_b1, dtype=np.float64)
        self.cold_start_s = np.asarray(cold_start_s, dtype=np.float64)
        self.cost_per_request = np.asarray(cost_per_request, dtype=np.float64)
        self.last_used = np.zeros(n) if prewarm else np.full(n, -math.inf)

    def latency(self, tick: int) -> np.ndarray:
        cold = (tick - self.last_used) > self.pricing.burst_idle_timeout_s
        return self.pricing.burst_spinup_s + self.lat_b1 + cold * self.cold_start_s

    def offload(
        self, tick: int, counts: np.ndarray, slo_s: float, strict: bool,
        ledger: Ledger,
    ) -> np.ndarray:
        """Send ``counts[a]`` requests to the burst pool right now;
        returns the per-arch violation counts (requests whose burst
        latency exceeded the class SLO)."""
        lat = self.latency(tick)
        viol = counts * (lat > slo_s)
        ledger.add_burst(
            cost=float((self.cost_per_request * counts).sum()),
            served=float(counts.sum()),
            violations=float(viol.sum()),
            strict=strict,
        )
        self.last_used = np.where(counts > 0, float(tick), self.last_used)
        return viol
