"""Resource tiers — the procurement side of the serving fleet.

The paper's system buys capacity from heterogeneous cloud offerings —
its "confounding array of resource types": long-lived reserved slices
(VMs), preemptible spot slices (§VI), deeply-discounted harvest VMs
whose availability follows a pool-correlated signal, a second reserved
region behind a network-egress adder, and a per-invocation burst pool
(serverless functions).  Each offering is one :class:`ResourceTier`: it
owns its pool-wide instance counts as arrays, runs its provisioning
pipeline each tick, and knows its price.  Adding a new offering is one
subclass — the engine only speaks the tier interface
(:class:`HarvestVMTier` and :class:`MultiRegionReservedTier` are
exactly that: zero engine-tick-loop changes beyond registration).

All state is structure-of-arrays over the pool: ``active[a]`` instances
per arch, and a :class:`ProvisionPipeline` ring buffer of launches in
flight, so a tick is O(A) NumPy work regardless of pool size.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.hardware import FleetPricing
from repro.core.sim.accounting import Ledger

# ---------------------------------------------------------------------------
# Explicit randomness for tier-internal events.
#
# The stochastic tiers (spot reclaims, the harvest signal) draw from
# *tier-owned seeded streams whose position is a pure function of the
# tick index*, never from shared engine RNG state.  That makes every
# random trajectory reproducible from ``(seed, tick)`` alone, so the
# batched JAX engine (``sim/jax_engine.py``) can precompute the exact
# same draws host-side and stay in lockstep with this engine — reclaim
# for reclaim — instead of only matching in distribution.
# ---------------------------------------------------------------------------

#: shared cap on the inverse-CDF walk in :func:`binomial_from_uniform`.
#: Both the NumPy and the JAX twin stop after this many CDF terms, so the
#: two implementations return identical counts for identical uniforms.
#: 64 is > mean + 8 sigma for every reclaim regime the simulator uses
#: (p = 1 - exp(-1/1800) at fleet sizes, p = 0.05 in the stress tests).
BINOMIAL_KMAX = 64

_SPOT_STREAM_TAG = 0x5907  # domain-separates the spot uniform stream


def binomial_from_uniform(n: np.ndarray, p: float, u: np.ndarray) -> np.ndarray:
    """Exact inverse-CDF Binomial(n, p) sample from one uniform per row.

    Deterministic given ``u``: walks the CDF with the pmf recurrence
    ``pmf(k+1) = pmf(k) * (n-k)/(k+1) * p/(1-p)`` and returns the number
    of CDF terms <= u, capped at :data:`BINOMIAL_KMAX` (and at ``n``).
    ``p >= 1`` returns ``n`` exactly, ``p <= 0`` returns zeros — the
    degenerate cases the tier tests pin must not depend on float walks.
    """
    n = np.asarray(n, dtype=np.int64)
    if p <= 0.0:
        return np.zeros_like(n)
    if p >= 1.0:
        return n.copy()
    u = np.asarray(u, dtype=np.float64)
    nf = n.astype(np.float64)
    q = 1.0 - p
    pmf = q ** nf                       # P(X = 0)
    cdf = pmf.copy()
    k = (u >= cdf).astype(np.int64)
    for j in range(1, BINOMIAL_KMAX + 1):
        still = u >= cdf
        if not still.any():
            break
        pmf = np.maximum(pmf * ((nf - (j - 1)) / j) * (p / q), 0.0)
        cdf = cdf + pmf
        k += (u >= cdf).astype(np.int64)
    return np.minimum(k, n)


def spot_uniform_stream(seed: int) -> np.random.Generator:
    """The seeded stream behind a :class:`SpotTier`'s reclaim draws."""
    return np.random.default_rng((_SPOT_STREAM_TAG, seed))


def spot_reclaim_uniforms(seed: int, ticks: int, n_archs: int) -> np.ndarray:
    """Precompute the ``[ticks, 2, n_archs]`` uniform schedule a
    :class:`SpotTier` with this seed consumes: slot 0 drives the active
    reclaim draw, slot 1 the in-flight (pipeline) one.  A single bulk
    ``random()`` fill is bitwise-identical to the tier's one-draw-per-tick
    consumption of the same stream."""
    return spot_uniform_stream(seed).random((ticks, 2, n_archs))


def harvest_level_trajectory(
    seed: int, ticks: int, *, level0: float = 1.0,
) -> np.ndarray:
    """Precompute ``ticks`` steps of the harvest availability signal.

    ``out[t]`` is the level a :class:`HarvestVMTier` with this seed holds
    *during* engine tick ``t`` (after its per-tick advance), replayed
    from the same seeded stream — the signal is a pure function of time,
    so the batched engine materializes it host-side."""
    rng = np.random.default_rng(seed + 0x9A27)
    noise = rng.standard_normal(ticks)
    out = np.empty(ticks, dtype=np.float64)
    level = level0
    for t in range(ticks):
        level = float(np.clip(
            level
            + HarvestVMTier.LEVEL_KAPPA * (HarvestVMTier.LEVEL_MEAN - level)
            + HarvestVMTier.LEVEL_SIGMA * noise[t],
            HarvestVMTier.LEVEL_MIN, 1.0,
        ))
        out[t] = level
    return out


# ---------------------------------------------------------------------------
# Fixed-latency provisioning pipeline, vectorized over the pool.
# ---------------------------------------------------------------------------
class ProvisionPipeline:
    """Launches become ready exactly ``latency_s`` ticks later.

    ``buf[a, t % L]`` counts instances arch ``a`` launched at tick ``t``;
    cancellations remove the *newest* launches first (matching the seed
    semantics: not-yet-ready slices are cancelled before active ones are
    released).
    """

    def __init__(self, n_archs: int, latency_s: float):
        self.lat = max(int(latency_s), 1)
        self.buf = np.zeros((n_archs, self.lat), dtype=np.int64)
        self.total = np.zeros(n_archs, dtype=np.int64)

    def pop_ready(self, tick: int) -> np.ndarray:
        """Instances launched ``lat`` ticks ago come online now."""
        col = tick % self.lat
        ready = self.buf[:, col].copy()
        self.buf[:, col] = 0
        self.total -= ready
        return ready

    def launch(self, tick: int, counts: np.ndarray) -> None:
        self.buf[:, tick % self.lat] += counts
        self.total += counts

    def cancel_newest(self, tick: int, counts: np.ndarray) -> None:
        """Cancel up to ``counts[a]`` in-flight launches, newest first."""
        launch_ticks = np.arange(tick, tick - self.lat, -1)   # newest -> oldest
        idx = launch_ticks % self.lat
        pending = self.buf[:, idx]
        before = np.cumsum(pending, axis=1) - pending
        take = np.minimum(pending, np.clip(counts[:, None] - before, 0, None))
        self.buf[:, idx] = pending - take
        self.total -= take.sum(axis=1)


# ---------------------------------------------------------------------------
# Model-variant swap pipeline (INFaaS-style runtime variant switching).
# ---------------------------------------------------------------------------
class SwapPipeline:
    """Variant swaps in flight, vectorized over the pool.

    A swap requested at tick ``t`` becomes effective at ``t + lat``; the
    arch keeps serving (and billing) at the **old** variant until then —
    the weight reload occupies the held slices, like a provisioning
    pipeline occupies the lead time.  At most one swap per arch is in
    flight; semantics mirror provisioning's cancel-newest-first:

    * a request for a *different* target replaces the in-flight swap and
      restarts the clock (the newest decision wins, the not-yet-ready
      one is cancelled);
    * re-requesting the in-flight target leaves its clock alone;
    * re-requesting the *current* variant cancels the in-flight swap
      outright (nothing ever becomes ready).
    """

    def __init__(self, current: np.ndarray, latency_s: float):
        self.lat = max(int(latency_s), 1)
        self.current = np.asarray(current, dtype=np.int64).copy()
        n = len(self.current)
        self.pending = np.full(n, -1, dtype=np.int64)
        self.ready_at = np.zeros(n, dtype=np.int64)
        self.completed = 0                     # lifetime swap count

    @property
    def in_flight(self) -> np.ndarray:
        return self.pending >= 0

    def pop_ready(self, tick: int) -> np.ndarray:
        """Complete due swaps; returns the boolean completion mask."""
        done = (self.pending >= 0) & (self.ready_at <= tick)
        if done.any():
            self.current[done] = self.pending[done]
            self.pending[done] = -1
            self.completed += int(done.sum())
        return done

    def request(self, tick: int, target: np.ndarray) -> np.ndarray:
        """Apply per-arch swap requests (``target[a] = -1`` means hold);
        returns the boolean mask of swaps that newly entered the
        pipeline (telemetry's swap-request events)."""
        t = np.asarray(target, dtype=np.int64)
        cancel = (t >= 0) & (t == self.current)
        self.pending[cancel] = -1
        start = (t >= 0) & (t != self.current) & (t != self.pending)
        if start.any():
            self.pending[start] = t[start]
            self.ready_at[start] = tick + self.lat
        return start


# ---------------------------------------------------------------------------
# Tier base: reserved (on-demand) slices.
# ---------------------------------------------------------------------------
class ResourceTier:
    """A pool of slices with a provisioning pipeline and a price.

    Tick protocol (driven by the engine):
      ``begin_tick``  — tier-internal events (e.g. spot reclaims)
      ``set_target``  — provisioning: admit ready launches, then grow or
                        shrink toward the policy's per-arch target
      ``account``     — bill this tick's held capacity into the ledger
    """

    name = "reserved"

    #: optional :class:`~repro.core.sim.telemetry.Telemetry` hook the
    #: engine attaches; ``None`` (the default) keeps every tick on the
    #: pre-telemetry fast path
    telemetry = None

    def __init__(self, n_archs: int, pricing: FleetPricing):
        self.pricing = pricing
        self.active = np.zeros(n_archs, dtype=np.int64)
        self.pipeline = ProvisionPipeline(n_archs, self.provision_latency_s())

    # -- per-tier knobs ------------------------------------------------------
    def provision_latency_s(self) -> float:
        return self.pricing.reserved_provision_s

    def price_per_chip_s(self) -> float:
        return self.pricing.reserved_chip_s

    def egress_latency_s(self) -> float:
        """Per-request latency adder for capacity served from this tier
        (0 for in-region tiers; the engine serves strict-class traffic
        from zero-egress capacity first)."""
        return 0.0

    # -- tick protocol -------------------------------------------------------
    def begin_tick(self, tick: int, rng: np.random.Generator, ledger: Ledger) -> None:
        """Tier-internal events before provisioning (default: none)."""

    def idle_tick(self, tick: int) -> None:
        """Called on ticks the tier is neither held nor targeted, so
        provider-side state (e.g. an availability signal) keeps evolving
        as a function of time, not of usage history (default: none)."""

    def set_target(self, tick: int, target: np.ndarray) -> None:
        tel = self.telemetry
        ready = self.pipeline.pop_ready(tick)
        self.active += ready
        in_flight = self.active + self.pipeline.total
        grow = np.maximum(target - in_flight, 0)
        if grow.any():
            self.pipeline.launch(tick, grow)
        shrink = in_flight - target
        cancel = released = None
        if (shrink > 0).any():
            cancel = np.clip(np.minimum(self.pipeline.total, shrink), 0, None)
            if cancel.any():
                self.pipeline.cancel_newest(tick, cancel)
            active = np.where(
                shrink > 0,
                np.minimum(self.active, np.maximum(target, 0)),
                self.active,
            )
            if tel is not None:
                released = self.active - active
            self.active = active
        if tel is not None:
            tel.on_provision(tick, self.name, ready, grow, cancel, released)

    def account(self, ledger: Ledger, chips_per_instance: np.ndarray) -> np.ndarray:
        """Bill held capacity; returns this tier's chip-seconds per arch."""
        chip_s = self.active * chips_per_instance
        ledger.add_tier_cost(self.name, float(chip_s.sum()) * self.price_per_chip_s())
        return chip_s

    @property
    def pending_total(self) -> np.ndarray:
        return self.pipeline.total


# ---------------------------------------------------------------------------
# Spot tier: cheap, preemptible (paper §VI future work, implemented).
# ---------------------------------------------------------------------------
class SpotTier(ResourceTier):
    """Reclaim draws come from a tier-owned seeded uniform stream that
    advances exactly one ``[2, A]`` block per engine tick (``begin_tick``
    while engaged, ``idle_tick`` otherwise), so the uniforms consumed at
    tick ``t`` are a pure function of ``(seed, t)`` — the batched JAX
    engine precomputes the identical schedule with
    :func:`spot_reclaim_uniforms` and reproduces reclaims exactly.  The
    ``rng`` argument of ``begin_tick`` is part of the tier protocol but
    unused here."""

    name = "spot"

    def __init__(self, n_archs: int, pricing: FleetPricing, seed: int = 0):
        super().__init__(n_archs, pricing)
        self._u_rng = spot_uniform_stream(seed)

    def provision_latency_s(self) -> float:
        return self.pricing.spot_provision_s

    def price_per_chip_s(self) -> float:
        return self.pricing.reserved_chip_s * self.pricing.spot_discount

    def reclaim_probability(self) -> float:
        """Per-instance per-tick reclaim probability (policy observable)."""
        return 1.0 - math.exp(-self.pricing.spot_preempt_rate)

    def idle_tick(self, tick: int) -> None:
        # keep the stream position a function of the tick, not of usage
        self._u_rng.random((2, len(self.active)))

    def begin_tick(self, tick: int, rng: np.random.Generator, ledger: Ledger) -> None:
        p_reclaim = self.reclaim_probability()
        u = self._u_rng.random((2, len(self.active)))
        if self.active.any():
            reclaimed = binomial_from_uniform(self.active, p_reclaim, u[0])
            self.active -= reclaimed
            ledger.add_preemptions(int(reclaimed.sum()))
            if self.telemetry is not None:
                self.telemetry.on_reclaim(
                    tick, "spot_reclaim", self.name, reclaimed)
        if self.pipeline.total.any():
            # in-flight launches are NOT immune: the provider reclaims
            # provisioning slices at the same rate, so a policy cannot
            # hide capacity in the pipeline through a reclaim wave.  The
            # loss is drawn on the per-arch in-flight total and lands on
            # the newest launches first (the ones a same-tick reprovision
            # would re-request anyway).
            lost = binomial_from_uniform(self.pipeline.total, p_reclaim, u[1])
            self.pipeline.cancel_newest(tick, lost)
            ledger.add_preemptions(int(lost.sum()))
            if self.telemetry is not None:
                self.telemetry.on_reclaim(
                    tick, "spot_reclaim_pending", self.name, lost)


# ---------------------------------------------------------------------------
# Harvest-VM tier: spare capacity carved from running hosts — the deepest
# discount, but availability follows a pool-correlated harvest signal.
# ---------------------------------------------------------------------------
class HarvestVMTier(ResourceTier):
    """Deeply discounted instances built from harvested spare capacity.

    The provider's harvestable capacity is a seeded mean-reverting signal
    ``level(t)`` in ``[LEVEL_MIN, 1]`` shared by the whole pool: each arch
    may hold at most ``floor(level x harvest_cap_per_arch)`` instances,
    and when the signal drops, every arch's excess above the new ceiling
    is evicted in the same tick — reclaims are *correlated across the
    pool* (the datacenter got busy), unlike the spot tier's i.i.d.
    per-instance draws.  The signal advances exactly once per engine
    tick (``begin_tick`` while the tier is engaged, ``idle_tick``
    otherwise) from the tier's own seeded generator, so the trajectory
    is a pure function of time — deterministic, independent of both the
    engine's spot-reclaim stream and of which policy happens to use the
    tier.
    """

    name = "harvest"

    LEVEL_MIN = 0.25               # deepest harvest trough
    LEVEL_MEAN = 0.85              # long-run availability
    LEVEL_KAPPA = 0.02             # mean reversion per tick
    LEVEL_SIGMA = 0.03             # per-tick signal noise

    def __init__(self, n_archs: int, pricing: FleetPricing, seed: int = 0):
        super().__init__(n_archs, pricing)
        self.level = 1.0
        self._sig_rng = np.random.default_rng(seed + 0x9A27)

    def provision_latency_s(self) -> float:
        return self.pricing.harvest_provision_s

    def price_per_chip_s(self) -> float:
        return self.pricing.reserved_chip_s * self.pricing.harvest_discount

    def ceiling(self) -> int:
        """Per-arch instance ceiling at the current harvest level."""
        return int(self.level * self.pricing.harvest_cap_per_arch)

    def _advance(self) -> None:
        self.level = float(np.clip(
            self.level
            + self.LEVEL_KAPPA * (self.LEVEL_MEAN - self.level)
            + self.LEVEL_SIGMA * self._sig_rng.standard_normal(),
            self.LEVEL_MIN, 1.0,
        ))

    def idle_tick(self, tick: int) -> None:
        self._advance()

    def begin_tick(self, tick: int, rng: np.random.Generator, ledger: Ledger) -> None:
        self._advance()
        ceiling = self.ceiling()
        evicted = np.maximum(self.active - ceiling, 0)
        if evicted.any():
            self.active -= evicted
            ledger.add_preemptions(int(evicted.sum()))
            if self.telemetry is not None:
                self.telemetry.on_reclaim(
                    tick, "harvest_evict", self.name, evicted)
        # in-flight launches above the remaining room never materialize
        # (cancelled, not evicted: they were never running)
        over = np.maximum(self.active + self.pipeline.total - ceiling, 0)
        if over.any():
            self.pipeline.cancel_newest(tick, over)
            if self.telemetry is not None:
                self.telemetry.on_reclaim(
                    tick, "harvest_cancel", self.name, over)

    def set_target(self, tick: int, target: np.ndarray) -> None:
        # the provider only grants capacity under the harvested ceiling
        super().set_target(tick, np.minimum(target, self.ceiling()))


# ---------------------------------------------------------------------------
# Multi-region reserved tier: a second reserved pool, cheaper but farther.
# ---------------------------------------------------------------------------
class MultiRegionReservedTier(ResourceTier):
    """Reserved slices in a second region: same reliability, a discount,
    a much longer slice-acquisition latency, and a per-request network
    egress adder on everything it serves — which is why the engine serves
    strict-class traffic from local (zero-egress) capacity first."""

    name = "remote"

    def provision_latency_s(self) -> float:
        return self.pricing.remote_provision_s

    def price_per_chip_s(self) -> float:
        return self.pricing.reserved_chip_s * self.pricing.remote_discount

    def egress_latency_s(self) -> float:
        return self.pricing.remote_egress_s


# ---------------------------------------------------------------------------
# Burst tier: per-invocation serverless pool (no instances held).
# ---------------------------------------------------------------------------
class BurstTier:
    """The serverless analog: requests offloaded here never queue — they
    pay a premium per invocation and a spin-up (plus cold-start when the
    pool has not seen the model within the idle timeout)."""

    name = "burst"

    #: optional telemetry hook, attached by the engine (see ResourceTier)
    telemetry = None

    def __init__(
        self,
        pricing: FleetPricing,
        lat_b1: np.ndarray,            # batch-1 model latency per arch
        cold_start_s: np.ndarray,      # weight-fetch cold start per arch
        cost_per_request: np.ndarray,  # provider-batched billing per arch
        prewarm: bool,
    ):
        n = len(lat_b1)
        self.pricing = pricing
        self.lat_b1 = np.asarray(lat_b1, dtype=np.float64)
        self.cold_start_s = np.asarray(cold_start_s, dtype=np.float64)
        self.cost_per_request = np.asarray(cost_per_request, dtype=np.float64)
        self.last_used = np.zeros(n) if prewarm else np.full(n, -math.inf)

    def latency(self, tick: int) -> np.ndarray:
        """Latency the *first* invocation of the tick observes (the
        pool-warming one; followers in the same tick hit a warm pool)."""
        cold = (tick - self.last_used) > self.pricing.burst_idle_timeout_s
        return self.pricing.burst_spinup_s + self.lat_b1 + cold * self.cold_start_s

    def offload(
        self, tick: int, counts: np.ndarray, slo_s: float, strict: bool,
        ledger: Ledger,
    ) -> np.ndarray:
        """Send ``counts[a]`` requests to the burst pool right now;
        returns the per-arch violation counts (requests whose burst
        latency exceeded the class SLO).

        Only the pool-warming FIRST invocation of a cold batch pays
        ``cold_start_s`` — every request after it in the same tick hits
        the pool it just warmed (the idle timeout is minutes, not
        sub-second), so a cold batch of N violates at most 1 + the warm
        late mass, not N."""
        lat_first = self.latency(tick)
        lat_warm = self.pricing.burst_spinup_s + self.lat_b1
        first = np.minimum(counts, 1.0)
        viol = first * (lat_first > slo_s) + (counts - first) * (lat_warm > slo_s)
        cost_vec = self.cost_per_request * counts
        ledger.add_burst(
            cost=float(cost_vec.sum()),
            served=float(counts.sum()),
            violations=float(viol.sum()),
            strict=strict,
        )
        if self.telemetry is not None:
            cold = (tick - self.last_used) > self.pricing.burst_idle_timeout_s
            self.telemetry.on_cold_start(tick, cold & (counts > 0))
            self.telemetry.on_burst(tick, strict, counts, viol, cost_vec)
        self.last_used = np.where(counts > 0, float(tick), self.last_used)
        return viol
