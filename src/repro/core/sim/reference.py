"""The seed per-arch-loop simulator, kept as the behavioral reference.

This is the original ``ServingSim`` implementation: a Python loop over
architectures with scalar :class:`BucketQueue` state.  It is O(A) Python
work per tick and therefore slow on large pools, but it is the readable
specification the vectorized engine must match — the golden equivalence
test (``tests/test_sim_engine.py``) asserts both produce the same
``SimResult.summary()`` on the seed workload, and the throughput
benchmark measures the engine's speedup against it.

The only intentional divergence: on workloads that *use the spot tier*,
the engine draws all archs' preemption reclaims in one vectorized
binomial per tick while this loop draws per arch, so the two RNG streams
(and exact preemption counts) differ; everything deterministic matches.
"""
from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

from repro.core.hardware import PRICING, FleetPricing
from repro.core.load_monitor import LoadMonitor
from repro.core.profiles import ModelProfile, get_profile
from repro.core.sim.accounting import SimResult
from repro.core.sim.queues import BucketQueue
from repro.core.sim.types import RELAXED, STRICT, Action, ArchLoad, ArchObs, Policy


class _ArchState:
    def __init__(self, load: ArchLoad, pricing: FleetPricing, prewarm: bool):
        self.load = load
        self.prof: ModelProfile = get_profile(load.arch, req=STRICT)
        self.throughput = self.prof.throughput(STRICT)
        assert self.throughput > 0, f"{load.arch} cannot meet the strict SLO"
        self.lat_b1 = self.prof.request_latency(STRICT, 1)
        self.slack = {
            "strict": max(0, int(STRICT.slo_s - self.lat_b1)),
            "relaxed": max(0, int(RELAXED.slo_s - self.lat_b1)),
        }
        self.queues = {"strict": BucketQueue(), "relaxed": BucketQueue()}
        self.n_active = 0
        self.pending: List[int] = []           # ready ticks
        self.n_spot = 0
        self.spot_pending: List[int] = []
        self.monitor = LoadMonitor()
        self.last_util = 0.0
        # burst pool warmth: last tick the pool saw this model
        self.burst_last_used = 0.0 if prewarm else -math.inf
        self.pricing = pricing
        # provider-batched burst billing (see ModelProfile.burst_cost_per_request)
        self.burst_per_req = (
            self.prof.chips / self.throughput
        ) * pricing.burst_chip_s + pricing.burst_invocation_fee

    # -- burst ----------------------------------------------------------------
    def burst_latency(self, tick: int) -> float:
        cold = (tick - self.burst_last_used) > self.pricing.burst_idle_timeout_s
        lat = self.pricing.burst_spinup_s + self.lat_b1
        if cold:
            lat += self.prof.cold_start_s()
        return lat


class ReferenceSim:
    """Stepwise seed simulator: ``observe() -> actions -> apply()``."""

    def __init__(
        self,
        trace: np.ndarray,
        workload: List[ArchLoad],
        *,
        pricing: FleetPricing = PRICING,
        prewarm: bool = True,
        warm_start: bool = True,
        seed: int = 0,
    ):
        self.trace = trace
        self.pricing = pricing
        self.rng = np.random.default_rng(seed)   # spot preemption draws
        self.states = {w.key: _ArchState(w, pricing, prewarm) for w in workload}
        self.res = SimResult()
        self.tick = 0
        if warm_start:
            for st in self.states.values():
                st.n_active = max(
                    1, math.ceil(trace[0] * st.load.share / st.throughput)
                )

    @property
    def done(self) -> bool:
        return self.tick >= len(self.trace)

    def observe(self) -> Dict[str, ArchObs]:
        """Admit this tick's arrivals and return per-arch observations."""
        tick = self.tick
        rate = float(self.trace[tick])
        obs: Dict[str, ArchObs] = {}
        for arch, st in self.states.items():
            a_rate = rate * st.load.share
            st.monitor.observe(a_rate)
            n_strict = a_rate * st.load.strict_frac
            st.queues["strict"].push(tick, n_strict)
            st.queues["relaxed"].push(tick, a_rate - n_strict)
            self.res.total_requests += a_rate
            obs[arch] = ArchObs(
                arch=arch,
                rate=a_rate,
                ewma_rate=st.monitor.rate,
                window_peak=st.monitor.peak,
                peak_to_median=st.monitor.peak_to_median,
                queue_len=st.queues["strict"].total + st.queues["relaxed"].total,
                n_active=st.n_active,
                n_pending=len(st.pending),
                n_spot=st.n_spot,
                throughput=st.throughput,
                utilization=st.last_util,
            )
        self._last_obs = obs
        return obs

    def apply(self, actions: Dict[str, Action]) -> dict:
        """Apply procurement actions, serve the tick, advance time.

        Returns this tick's marginal metrics (for RL rewards)."""
        tick = self.tick
        res = self.res
        pricing = self.pricing
        obs = self._last_obs
        cost0, viol0 = res.cost_total, res.violations
        for arch, st in self.states.items():
            act = actions.get(arch, Action(target=st.n_active))

            # provisioning pipeline
            ready = [r for r in st.pending if r <= tick]
            st.n_active += len(ready)
            st.pending = [r for r in st.pending if r > tick]
            in_flight = st.n_active + len(st.pending)
            if act.target > in_flight:
                st.pending.extend(
                    [tick + int(pricing.reserved_provision_s)]
                    * (act.target - in_flight)
                )
            elif act.target < in_flight:
                # cancel not-yet-ready slices first, then release active ones
                cancel = min(len(st.pending), in_flight - act.target)
                if cancel:
                    st.pending = st.pending[: len(st.pending) - cancel]
                st.n_active = min(st.n_active, max(act.target, 0))

            # --- spot tier (§VI extension): Poisson reclaim, then scale ---
            if st.n_spot > 0:
                p_reclaim = 1.0 - math.exp(-pricing.spot_preempt_rate)
                reclaimed = int(self.rng.binomial(st.n_spot, p_reclaim))
                if reclaimed:
                    st.n_spot -= reclaimed
                    res.preemptions += reclaimed
            ready_s = [r for r in st.spot_pending if r <= tick]
            st.n_spot += len(ready_s)
            st.spot_pending = [r for r in st.spot_pending if r > tick]
            spot_in_flight = st.n_spot + len(st.spot_pending)
            if act.spot_target > spot_in_flight:
                st.spot_pending.extend(
                    [tick + int(pricing.spot_provision_s)]
                    * (act.spot_target - spot_in_flight)
                )
            elif act.spot_target < spot_in_flight:
                cancel = min(len(st.spot_pending), spot_in_flight - act.spot_target)
                if cancel:
                    st.spot_pending = st.spot_pending[: len(st.spot_pending) - cancel]
                st.n_spot = min(st.n_spot, max(act.spot_target, 0))

            # serve from queues, strict first
            capacity = (st.n_active + st.n_spot) * st.throughput
            served = 0.0
            for cls in ("strict", "relaxed"):
                take = st.queues[cls].pop(capacity - served)
                for t0, cnt in take:
                    if tick - t0 > st.slack[cls]:
                        res.violations += cnt
                        if cls == "strict":
                            res.violations_strict += cnt
                    served += cnt
                    res.served_vm += cnt
            st.last_util = served / capacity if capacity > 0 else 1.0

            # offload decision (see engine._step for the mode semantics).
            # Only the pool-warming FIRST invocation of a cold batch pays
            # the cold start; the rest of the batch hits the warm pool.
            if act.offload in ("blind", "slack_aware"):
                classes = ("strict", "relaxed") if act.offload == "blind" else ("strict",)
                for cls in classes:
                    slo = STRICT.slo_s if cls == "strict" else RELAXED.slo_s
                    offl = st.queues[cls].pop_older_than(tick, -1)
                    if offl <= 0:
                        continue
                    blat_first = st.burst_latency(tick)
                    blat_warm = pricing.burst_spinup_s + st.lat_b1
                    st.burst_last_used = tick
                    res.cost_burst += st.burst_per_req * offl
                    res.served_burst += offl
                    first = min(offl, 1.0)
                    viol = first * (blat_first > slo) + (offl - first) * (
                        blat_warm > slo
                    )
                    if viol > 0:
                        res.violations += viol
                        if cls == "strict":
                            res.violations_strict += viol

            # abandon hopeless VM-only waiters (count violation once)
            for cls in ("strict", "relaxed"):
                slo = STRICT.slo_s if cls == "strict" else RELAXED.slo_s
                dropped = st.queues[cls].pop_older_than(tick, int(3 * slo))
                if dropped > 0:
                    res.violations += dropped
                    if cls == "strict":
                        res.violations_strict += dropped
                    res.served_vm += dropped   # still answered, just very late

            # accounting
            chips = st.n_active * st.prof.chips
            spot_chips = st.n_spot * st.prof.chips
            res.cost_reserved += chips * pricing.reserved_chip_s
            res.cost_spot += (
                spot_chips * pricing.reserved_chip_s * pricing.spot_discount
            )
            res.chip_seconds += chips + spot_chips
            need = math.ceil(obs[arch].rate / st.throughput) * st.prof.chips
            res.chip_seconds_needed += need
            res.chip_seconds_over += max(0, chips + spot_chips - need)

        self.tick += 1
        if self.done:
            self._finalize()
        return {
            "cost": res.cost_total - cost0,
            "violations": res.violations - viol0,
        }

    def _finalize(self) -> None:
        # end-of-trace: whatever is still queued past its slack violates
        for st in self.states.values():
            for cls in ("strict", "relaxed"):
                late = st.queues[cls].pop_older_than(len(self.trace), st.slack[cls])
                self.res.violations += late
                if cls == "strict":
                    self.res.violations_strict += late


def simulate_reference(
    trace: np.ndarray,
    workload: List[ArchLoad],
    policy: Policy,
    *,
    pricing: FleetPricing = PRICING,
    prewarm: bool = True,
    warm_start: bool = True,
) -> SimResult:
    """Closed-loop run of the reference per-arch loop."""
    sim = ReferenceSim(
        trace, workload, pricing=pricing, prewarm=prewarm, warm_start=warm_start
    )
    while not sim.done:
        obs = sim.observe()
        sim.apply(policy(sim.tick, obs))
    return sim.res
