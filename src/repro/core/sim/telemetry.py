"""Observability for the serving simulator.

The paper's §V controller is a feedback loop — it can only manage what
it can observe — yet the engine historically reported a single
end-of-run :meth:`SimResult.summary` dict.  This module adds the three
observability surfaces every later PR (MPC, fleet-scale RL, packing)
reports through:

1. **Per-tick time-series recorder** (:class:`TimeSeriesRecorder`):
   preallocated ``[R, A]`` structure-of-arrays buffers (``R = ceil(T /
   stride)``) of fleet / queue / flow / cost state.  Gauges are
   last-write-wins within a stride bucket, flows accumulate.
2. **Structured event log**: typed :class:`TelemetryEvent` records
   emitted from ``engine._step`` and the fleet tiers.  The stream is
   *reconcilable* against the :class:`~repro.core.sim.accounting.Ledger`
   — :func:`reconcile_events` re-derives every ledger total bit-exactly
   by replaying event magnitudes in the engine's posting order.
3. **SLO burn-rate / anomaly monitors** (:func:`detect_incidents`):
   multi-window burn rate per latency class, queue-age p99, and
   cost-per-served-request drift, summarized as an incidents table.

Everything hangs off one :class:`Telemetry` object attached to
:class:`~repro.core.sim.engine.ServingSim` behind a
zero-cost-when-disabled flag: with ``telemetry=None`` (the default) the
engine takes a handful of ``is not None`` branches and is bit-identical
to the pre-telemetry engine.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sim.types import RELAXED, STRICT, TelemetryEvent

__all__ = [
    "EVENT_TYPES",
    "Incident",
    "JsonlWriter",
    "MonitorConfig",
    "Telemetry",
    "TelemetryEvent",
    "TimeSeriesRecorder",
    "detect_incidents",
    "events_from_jsonl",
    "global_counters",
    "incidents_table",
    "reconcile_events",
    "set_global_counter",
]


# ---------------------------------------------------------------------------
# Event vocabulary.  One entry per ``TelemetryEvent.etype`` the engine or
# a tier can emit — the single doc source for docs/TELEMETRY.md, and the
# coverage test pins that every emitted etype appears here.
# ---------------------------------------------------------------------------
EV_ARRIVAL = "arrival"
EV_SERVE = "serve"
EV_SLO_VIOLATION = "slo_violation"
EV_DROP = "drop"
EV_EXPIRED = "expired"
EV_BURST_OFFLOAD = "burst_offload"
EV_BURST_COLD = "burst_cold_start"
EV_ACCURACY = "accuracy"
EV_ACC_VIOLATION = "acc_violation"
EV_TIER_COST = "tier_cost"
EV_CHIP = "chip_seconds"
EV_CHIP_NEED = "chip_seconds_needed"
EV_CHIP_OVER = "chip_seconds_over"
EV_PROVISION_REQUEST = "provision_request"
EV_PROVISION_LANDED = "provision_landed"
EV_PROVISION_CANCELLED = "provision_cancelled"
EV_RELEASE = "release"
EV_SPOT_RECLAIM = "spot_reclaim"
EV_SPOT_RECLAIM_PENDING = "spot_reclaim_pending"
EV_HARVEST_EVICT = "harvest_evict"
EV_HARVEST_CANCEL = "harvest_cancel"
EV_SWAP_REQUEST = "swap_request"
EV_SWAP_LANDED = "swap_landed"

#: etype -> one-line description (magnitude semantics in parentheses).
EVENT_TYPES: Dict[str, str] = {
    EV_ARRIVAL: "requests admitted for an arch this tick (requests)",
    EV_SERVE: "requests served from VM capacity this tick (requests)",
    EV_SLO_VIOLATION: "late-served mass; tier=vm|burst, cls=strict|relaxed "
                      "(requests)",
    EV_DROP: "hopeless queued mass abandoned past 3x SLO; booked as "
             "served-but-violated (requests)",
    EV_EXPIRED: "still-queued mass swept late at end of trace; emitted at "
                "tick == len(trace) (requests)",
    EV_BURST_OFFLOAD: "requests offloaded to the serverless burst pool "
                      "(requests; cost = dollars billed for this arch)",
    EV_BURST_COLD: "a burst invocation hit a cold pool — the model was idle "
                   "past the warm timeout (cold batches this tick)",
    EV_ACCURACY: "accuracy-weighted answered mass at the active variant "
                 "(requests x accuracy)",
    EV_ACC_VIOLATION: "answered mass whose active variant sits below the "
                      "stream's accuracy floor (requests)",
    EV_TIER_COST: "one tier's bill for this tick; pool-level, "
                  "magnitude == cost (dollars)",
    EV_CHIP: "chip-seconds held across all tiers this tick; pool-level "
             "(chip-seconds)",
    EV_CHIP_NEED: "minimally-needed chip-seconds for this tick's arrivals; "
                  "pool-level (chip-seconds)",
    EV_CHIP_OVER: "held-above-needed chip-seconds this tick; pool-level "
                  "(chip-seconds)",
    EV_PROVISION_REQUEST: "instances a tier starts provisioning toward the "
                          "policy target (instances)",
    EV_PROVISION_LANDED: "in-flight launches that came online this tick "
                         "(instances)",
    EV_PROVISION_CANCELLED: "in-flight launches cancelled by a shrinking "
                            "target, newest first (instances)",
    EV_RELEASE: "active instances released by a shrinking target "
                "(instances)",
    EV_SPOT_RECLAIM: "active spot instances reclaimed by the provider; "
                     "counted as preemptions (instances)",
    EV_SPOT_RECLAIM_PENDING: "in-flight spot launches reclaimed before "
                             "landing; counted as preemptions (instances)",
    EV_HARVEST_EVICT: "active harvest instances evicted by a falling "
                      "availability signal; counted as preemptions "
                      "(instances)",
    EV_HARVEST_CANCEL: "in-flight harvest launches over the new ceiling; "
                       "cancelled, NOT preemptions (instances)",
    EV_SWAP_REQUEST: "a runtime variant swap entered the swap pipeline "
                     "(magnitude 1; cost field carries the target variant "
                     "index)",
    EV_SWAP_LANDED: "an in-flight variant swap completed and took effect "
                    "(magnitude 1)",
}

#: recorder cost-column order (every tier that can post dollars)
TIER_ORDER: Tuple[str, ...] = ("reserved", "spot", "harvest", "remote", "burst")

_CLS = ("strict", "relaxed")


# ---------------------------------------------------------------------------
# Module-level counters (e.g. JAX runner trace counts) — keyed by a
# Prometheus-style ``name{label="v",...}`` string, exported by
# :meth:`Telemetry.prometheus_text`.
# ---------------------------------------------------------------------------
GLOBAL_COUNTERS: Dict[str, float] = {}


def set_global_counter(key: str, value: float) -> None:
    GLOBAL_COUNTERS[key] = float(value)


def global_counters() -> Dict[str, float]:
    return dict(GLOBAL_COUNTERS)


# ---------------------------------------------------------------------------
# Per-tick time-series recorder.
# ---------------------------------------------------------------------------
class TimeSeriesRecorder:
    """Preallocated SoA buffers over ``R = ceil(ticks / stride)`` rows.

    *Flows* (``arrived``, ``served_vm``, ...) accumulate within a stride
    bucket; *gauges* (fleet, queues, variants) are last-write-wins, i.e.
    the bucket reports its final tick's state.

    Buffers are sized ``R x A`` from the stride at allocation, and the
    gauge series are narrow (float32 / int32): they are observability
    state, not ledger inputs, and at fleet scale (A=256+) the ``[R, A]``
    gauge buffers dominate the recorder's footprint.  Flows and
    ``tier_cost`` stay float64 — the event-log reconciliation asserts
    exact agreement between their sums and the billing ledger."""

    FLOW_NAMES = (
        "arrived", "served_vm", "served_burst", "dropped",
        "viol_strict", "viol_relaxed", "acc_weight", "acc_viol",
    )

    def __init__(self, n_archs: int, ticks: int, stride: int = 1,
                 tier_names: Sequence[str] = ("reserved", "spot", "harvest",
                                              "remote")):
        self.n_archs = int(n_archs)
        self.ticks = int(ticks)
        self.stride = max(int(stride), 1)
        self.rows = max(-(-self.ticks // self.stride), 1)
        self.tier_names = tuple(tier_names)
        R, A = self.rows, self.n_archs
        self.tick = np.full(R, -1, dtype=np.int64)
        self.tier_active = {t: np.zeros((R, A), np.int32) for t in self.tier_names}
        self.tier_pending = {t: np.zeros((R, A), np.int32) for t in self.tier_names}
        self.queue_depth = {c: np.zeros((R, A), np.float32) for c in _CLS}
        self.queue_age_p99 = {c: np.zeros((R, A), np.int32) for c in _CLS}
        self.flows = {name: np.zeros((R, A)) for name in self.FLOW_NAMES}
        self.tier_cost = np.zeros((R, len(TIER_ORDER)))
        self.active_variant = np.zeros((R, A), np.int32)
        self.swap_in_flight = np.zeros((R, A), bool)
        self.acc_rate = np.zeros((R, A), np.float32)
        self.utilization = np.zeros((R, A), np.float32)
        self.harvest_level = np.zeros(R, np.float32)
        self._touched = 0                    # rows actually written

    def row(self, tick: int) -> int:
        r = min(tick // self.stride, self.rows - 1)
        self._touched = max(self._touched, r + 1)
        return r

    # -- flows ---------------------------------------------------------------
    def add_flow(self, tick: int, name: str, vec: np.ndarray) -> None:
        self.flows[name][self.row(tick)] += vec

    def add_cost(self, tick: int, tier: str, dollars: float) -> None:
        self.tier_cost[self.row(tick), TIER_ORDER.index(tier)] += dollars

    # -- views ---------------------------------------------------------------
    @property
    def n_rows(self) -> int:
        return self._touched

    def pool_flow(self, name: str) -> np.ndarray:
        """``[n_rows]`` pool-total of a flow."""
        return self.flows[name][: self._touched].sum(axis=1)

    def as_dict(self) -> Dict[str, np.ndarray]:
        """Trimmed copy of every buffer (rows actually written)."""
        n = self._touched
        out: Dict[str, np.ndarray] = {"tick": self.tick[:n].copy()}
        for t in self.tier_names:
            out[f"active_{t}"] = self.tier_active[t][:n].copy()
            out[f"pending_{t}"] = self.tier_pending[t][:n].copy()
        for c in _CLS:
            out[f"queue_{c}"] = self.queue_depth[c][:n].copy()
            out[f"queue_age_p99_{c}"] = self.queue_age_p99[c][:n].copy()
        for name in self.FLOW_NAMES:
            out[name] = self.flows[name][:n].copy()
        out["tier_cost"] = self.tier_cost[:n].copy()
        out["active_variant"] = self.active_variant[:n].copy()
        out["swap_in_flight"] = self.swap_in_flight[:n].copy()
        out["acc_rate"] = self.acc_rate[:n].copy()
        out["utilization"] = self.utilization[:n].copy()
        out["harvest_level"] = self.harvest_level[:n].copy()
        return out


# ---------------------------------------------------------------------------
# The telemetry hook the engine and tiers call into.
# ---------------------------------------------------------------------------
class Telemetry:
    """Event log + recorder + counters for one engine run.

    Attach via ``ServingSim(..., telemetry=Telemetry())`` (or the
    ``simulate(..., telemetry=)`` passthrough).  ``bind`` is called by
    the engine and starts a fresh event list / recorder, so re-using one
    ``Telemetry`` across episodes (the RL env does) observes the latest
    episode; ``counters`` accumulate over the object's lifetime."""

    def __init__(self, *, events: bool = True, record: bool = True,
                 stride: int = 1):
        self.events_on = bool(events)
        self.record_on = bool(record)
        self.stride = max(int(stride), 1)
        self.events: List[TelemetryEvent] = []
        self.recorder: Optional[TimeSeriesRecorder] = None
        self.counters: Dict[str, float] = {}
        self.n_archs = 0
        self.ticks = 0

    # -- lifecycle -----------------------------------------------------------
    def bind(self, sim) -> None:
        """Called by ``ServingSim.__init__``: size buffers to the run."""
        self.n_archs = len(sim.keys)
        self.ticks = len(sim.trace)
        self.events = []
        self.recorder = (
            TimeSeriesRecorder(self.n_archs, self.ticks, self.stride)
            if self.record_on else None
        )

    # -- primitive emitters --------------------------------------------------
    def emit(self, tick: int, etype: str, *, arch: int = -1, tier: str = "",
             cls: str = "", magnitude: float = 1.0, cost: float = 0.0) -> None:
        if self.events_on:
            self.events.append(TelemetryEvent(
                tick, etype, arch, tier, cls, float(magnitude), float(cost)))
            self.counters[etype] = self.counters.get(etype, 0.0) + 1.0

    def emit_flow(self, tick: int, etype: str, vec: np.ndarray, *,
                  tier: str = "", cls: str = "",
                  cost_vec: Optional[np.ndarray] = None) -> None:
        """Emit one event per nonzero entry of ``vec`` (exact values —
        the reconciliation rebuilds the full vector from them)."""
        if not self.events_on:
            return
        for a in np.nonzero(vec)[0]:
            self.emit(tick, etype, arch=int(a), tier=tier, cls=cls,
                      magnitude=float(vec[a]),
                      cost=float(cost_vec[a]) if cost_vec is not None else 0.0)

    def counter(self, name: str, inc: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + inc

    # -- engine hooks (one per posting site, in tick order) ------------------
    def on_arrivals(self, tick: int, rates: np.ndarray) -> None:
        self.emit_flow(tick, EV_ARRIVAL, rates)
        if self.recorder is not None:
            self.recorder.add_flow(tick, "arrived", rates)

    def on_swap_landed(self, tick: int, done_mask: np.ndarray) -> None:
        for a in np.nonzero(done_mask)[0]:
            self.emit(tick, EV_SWAP_LANDED, arch=int(a))

    def on_swap_request(self, tick: int, start_mask: np.ndarray,
                        targets: np.ndarray) -> None:
        for a in np.nonzero(start_mask)[0]:
            self.emit(tick, EV_SWAP_REQUEST, arch=int(a),
                      cost=float(targets[a]))

    def on_serve(self, tick: int, served: np.ndarray, late_s: np.ndarray,
                 late_r: np.ndarray) -> None:
        self.emit_flow(tick, EV_SERVE, served)
        self.emit_flow(tick, EV_SLO_VIOLATION, late_s, tier="vm", cls="strict")
        self.emit_flow(tick, EV_SLO_VIOLATION, late_r, tier="vm", cls="relaxed")
        rec = self.recorder
        if rec is not None:
            rec.add_flow(tick, "served_vm", served)
            rec.add_flow(tick, "viol_strict", late_s)
            rec.add_flow(tick, "viol_relaxed", late_r)

    def on_burst(self, tick: int, strict: bool, counts: np.ndarray,
                 viol: np.ndarray, cost_vec: np.ndarray) -> None:
        cls = "strict" if strict else "relaxed"
        self.emit_flow(tick, EV_BURST_OFFLOAD, counts, tier="burst", cls=cls,
                       cost_vec=cost_vec)
        self.emit_flow(tick, EV_SLO_VIOLATION, viol, tier="burst", cls=cls)
        rec = self.recorder
        if rec is not None:
            rec.add_flow(tick, "served_burst", counts)
            rec.add_flow(tick, f"viol_{cls}", viol)
            rec.add_cost(tick, "burst", float(cost_vec.sum()))

    def on_cold_start(self, tick: int, cold_mask: np.ndarray) -> None:
        for a in np.nonzero(cold_mask)[0]:
            self.emit(tick, EV_BURST_COLD, arch=int(a), tier="burst")

    def on_drop(self, tick: int, strict: bool, dropped: np.ndarray) -> None:
        cls = "strict" if strict else "relaxed"
        self.emit_flow(tick, EV_DROP, dropped, cls=cls)
        rec = self.recorder
        if rec is not None:
            rec.add_flow(tick, "dropped", dropped)
            rec.add_flow(tick, f"viol_{cls}", dropped)

    def on_accuracy(self, tick: int, acc_w: np.ndarray,
                    acc_viol: np.ndarray) -> None:
        self.emit_flow(tick, EV_ACCURACY, acc_w)
        self.emit_flow(tick, EV_ACC_VIOLATION, acc_viol)
        rec = self.recorder
        if rec is not None:
            rec.add_flow(tick, "acc_weight", acc_w)
            rec.add_flow(tick, "acc_viol", acc_viol)

    def on_tier_cost(self, tick: int, tier: str, dollars: float) -> None:
        self.emit(tick, EV_TIER_COST, tier=tier, magnitude=dollars,
                  cost=dollars)
        if self.recorder is not None:
            self.recorder.add_cost(tick, tier, dollars)

    def on_capacity(self, tick: int, chip: float, need: float,
                    over: float) -> None:
        self.emit(tick, EV_CHIP, magnitude=chip)
        self.emit(tick, EV_CHIP_NEED, magnitude=need)
        self.emit(tick, EV_CHIP_OVER, magnitude=over)

    def on_expired(self, tick: int, strict: bool, late: np.ndarray) -> None:
        self.emit_flow(tick, EV_EXPIRED, late,
                       cls="strict" if strict else "relaxed")

    # -- tier hooks ----------------------------------------------------------
    def on_provision(self, tick: int, tier: str, ready: np.ndarray,
                     grow: np.ndarray, cancel: Optional[np.ndarray],
                     released: Optional[np.ndarray]) -> None:
        self.emit_flow(tick, EV_PROVISION_LANDED, ready, tier=tier)
        self.emit_flow(tick, EV_PROVISION_REQUEST, grow, tier=tier)
        if cancel is not None:
            self.emit_flow(tick, EV_PROVISION_CANCELLED, cancel, tier=tier)
        if released is not None:
            self.emit_flow(tick, EV_RELEASE, released, tier=tier)

    def on_reclaim(self, tick: int, etype: str, tier: str,
                   counts: np.ndarray) -> None:
        self.emit_flow(tick, etype, counts, tier=tier)

    # -- end-of-tick gauges --------------------------------------------------
    def end_tick(self, sim, tick: int) -> None:
        rec = self.recorder
        if rec is None:
            return
        r = rec.row(tick)
        rec.tick[r] = tick
        rec.tier_active["reserved"][r] = sim.reserved.active
        rec.tier_pending["reserved"][r] = sim.reserved.pipeline.total
        for name, tier in sim.aux_tiers.items():
            rec.tier_active[name][r] = tier.active
            rec.tier_pending[name][r] = tier.pipeline.total
        for cls, q in (("strict", sim.q_strict), ("relaxed", sim.q_relaxed)):
            rec.queue_depth[cls][r] = q.totals()
            rec.queue_age_p99[cls][r] = q.age_quantile(tick, 0.99)
        rec.active_variant[r] = sim.swap.current
        rec.swap_in_flight[r] = sim.swap.in_flight
        # delivered-accuracy rate at the serving (post-pop) variant —
        # name-aligned with the JAX trajectory gauge "acc_rate"
        rec.acc_rate[r] = sim.cur_acc
        rec.utilization[r] = sim.last_util
        rec.harvest_level[r] = sim.harvest.level

    # -- exporters -----------------------------------------------------------
    def events_as_dicts(self) -> List[dict]:
        return [e._asdict() for e in self.events]

    def to_jsonl(self, path: str) -> int:
        """Write the event log as JSONL; returns the record count."""
        w = JsonlWriter(path)
        for e in self.events:
            w.write(e._asdict())
        w.close()
        return len(self.events)

    def prometheus_text(self, result=None) -> str:
        """Prometheus text-exposition dump of counters (event totals,
        magnitude sums, global counters) and, when ``result`` is given,
        the run's ledger gauges."""
        lines = ["# TYPE repro_sim_events_total counter"]
        for etype in sorted(self.counters):
            lines.append(
                f'repro_sim_events_total{{etype="{etype}"}} '
                f"{self.counters[etype]:g}")
        mags: Dict[str, float] = {}
        for e in self.events:
            mags[e.etype] = mags.get(e.etype, 0.0) + e.magnitude
        if mags:
            lines.append("# TYPE repro_sim_event_magnitude_total counter")
            for etype in sorted(mags):
                lines.append(
                    f'repro_sim_event_magnitude_total{{etype="{etype}"}} '
                    f"{mags[etype]:.10g}")
        if GLOBAL_COUNTERS:
            lines.append("# TYPE repro_counter gauge")
            for key in sorted(GLOBAL_COUNTERS):
                lines.append(f"repro_{key} {GLOBAL_COUNTERS[key]:g}")
        if result is not None:
            lines.append("# TYPE repro_sim_result gauge")
            for k, v in result.summary().items():
                lines.append(f'repro_sim_result{{metric="{k}"}} {v:g}')
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# JSONL plumbing (event export, RL training log).
# ---------------------------------------------------------------------------
class JsonlWriter:
    """Line-per-record JSON writer; creates parent directories."""

    def __init__(self, path: str, mode: str = "w"):
        self.path = str(path)
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._f = open(self.path, mode)

    def write(self, record: dict) -> None:
        self._f.write(json.dumps(record) + "\n")

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "JsonlWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def events_from_jsonl(path: str) -> List[TelemetryEvent]:
    out = []
    with open(path) as f:
        for line in f:
            if line.strip():
                out.append(TelemetryEvent(**json.loads(line)))
    return out


# ---------------------------------------------------------------------------
# Event-log <-> Ledger reconciliation.
# ---------------------------------------------------------------------------
def _scatter(events: Sequence[TelemetryEvent], ticks: int, n_archs: int):
    """Scatter the event stream into per-tick ``[ticks+1, A]`` vectors
    (row ``ticks`` holds the end-of-trace sweep) plus per-tick scalars."""
    A = n_archs
    T1 = ticks + 1
    grids = {
        "arrival": np.zeros((T1, A)), "serve": np.zeros((T1, A)),
        "vm_viol_strict": np.zeros((T1, A)), "vm_viol_relaxed": np.zeros((T1, A)),
        "burst_strict": np.zeros((T1, A)), "burst_relaxed": np.zeros((T1, A)),
        "burst_cost_strict": np.zeros((T1, A)),
        "burst_cost_relaxed": np.zeros((T1, A)),
        "burst_viol_strict": np.zeros((T1, A)),
        "burst_viol_relaxed": np.zeros((T1, A)),
        "drop_strict": np.zeros((T1, A)), "drop_relaxed": np.zeros((T1, A)),
        "acc_w": np.zeros((T1, A)), "acc_viol": np.zeros((T1, A)),
        "expired_strict": np.zeros((T1, A)), "expired_relaxed": np.zeros((T1, A)),
    }
    chip = {k: np.zeros(T1) for k in ("chip", "need", "over")}
    tier_cost: Dict[str, np.ndarray] = {}
    preemptions = 0
    swaps = 0
    for e in events:
        t, a = e.tick, e.arch
        if e.etype == EV_ARRIVAL:
            grids["arrival"][t, a] = e.magnitude
        elif e.etype == EV_SERVE:
            grids["serve"][t, a] = e.magnitude
        elif e.etype == EV_SLO_VIOLATION:
            key = ("vm_viol_" if e.tier == "vm" else "burst_viol_") + e.cls
            grids[key][t, a] = e.magnitude
        elif e.etype == EV_BURST_OFFLOAD:
            grids[f"burst_{e.cls}"][t, a] = e.magnitude
            grids[f"burst_cost_{e.cls}"][t, a] = e.cost
        elif e.etype == EV_DROP:
            grids[f"drop_{e.cls}"][t, a] = e.magnitude
        elif e.etype == EV_EXPIRED:
            grids[f"expired_{e.cls}"][t, a] = e.magnitude
        elif e.etype == EV_ACCURACY:
            grids["acc_w"][t, a] = e.magnitude
        elif e.etype == EV_ACC_VIOLATION:
            grids["acc_viol"][t, a] = e.magnitude
        elif e.etype == EV_TIER_COST:
            if e.tier not in tier_cost:       # first-post order, like the
                tier_cost[e.tier] = np.zeros(T1)   # ledger's cost_other dict
            tier_cost[e.tier][t] = e.cost
        elif e.etype == EV_CHIP:
            chip["chip"][t] = e.magnitude
        elif e.etype == EV_CHIP_NEED:
            chip["need"][t] = e.magnitude
        elif e.etype == EV_CHIP_OVER:
            chip["over"][t] = e.magnitude
        elif e.etype in (EV_SPOT_RECLAIM, EV_SPOT_RECLAIM_PENDING,
                         EV_HARVEST_EVICT):
            preemptions += int(e.magnitude)
        elif e.etype == EV_SWAP_LANDED:
            swaps += 1
    return grids, chip, tier_cost, preemptions, swaps


def reconcile_events(events: Sequence[TelemetryEvent], n_archs: int,
                     ticks: int) -> Dict[str, object]:
    """Re-derive the run's ledger totals and per-arch flows from the
    event log alone, **bit-exactly**.

    The engine posts float *sums of per-arch vectors* into the ledger in
    a fixed order each tick; float addition is order-sensitive, so this
    replays the identical computation: rebuild each full ``[A]`` vector
    from the (nonzero-only) events, reduce it with the same ``.sum()``
    the engine used, and accumulate the per-tick scalars in the same
    posting order.  The returned totals compare ``==`` (not merely
    close) against the :class:`SimResult` of the run that emitted the
    events — the reconciliation test relies on that."""
    g, chip, tier_cost, preemptions, swaps = _scatter(events, ticks, n_archs)
    A = n_archs
    total_requests = served_vm = served_burst = 0.0
    violations = violations_strict = 0.0
    cost_burst = acc_weighted = acc_served = acc_violations = 0.0
    per = {k: np.zeros(A) for k in (
        "arrived", "served_vm", "served_burst", "dropped", "expired_end",
        "violations", "acc_weight", "acc_violations")}
    for t in range(ticks):
        total_requests += g["arrival"][t].sum()
        per["arrived"] += g["arrival"][t]
        # serve (engine: add_served_vm, then add_violations(vm_s + vm_r))
        serve = g["serve"][t]
        served_vm += serve.sum()
        per["served_vm"] += serve
        vm_s, vm_r = g["vm_viol_strict"][t], g["vm_viol_relaxed"][t]
        violations += vm_s.sum() + vm_r.sum()
        violations_strict += vm_s.sum()
        per["violations"] += vm_s + vm_r
        # burst offload, strict then relaxed
        for cls in _CLS:
            counts = g[f"burst_{cls}"][t]
            cost_burst += g[f"burst_cost_{cls}"][t].sum()
            served_burst += counts.sum()
            bviol = g[f"burst_viol_{cls}"][t]
            violations += bviol.sum()
            if cls == "strict":
                violations_strict += bviol.sum()
            per["served_burst"] += counts
            per["violations"] += bviol
        # expiry drops, strict then relaxed (booked served-but-violated)
        for cls in _CLS:
            drop = g[f"drop_{cls}"][t]
            d = drop.sum()
            violations += d
            if cls == "strict":
                violations_strict += d
            served_vm += d
            per["dropped"] += drop
            per["violations"] += drop
        # accuracy: answered = serve + burst_s + burst_r + drop_s + drop_r
        answered = serve.copy()
        answered += g["burst_strict"][t]
        answered += g["burst_relaxed"][t]
        answered += g["drop_strict"][t]
        answered += g["drop_relaxed"][t]
        acc_w = g["acc_w"][t]
        acc_weighted += acc_w.sum()
        acc_served += answered.sum()
        per["acc_weight"] += acc_w
        acc_v = g["acc_viol"][t]
        acc_violations += acc_v.sum()
        per["acc_violations"] += acc_v
    # end-of-trace sweep (row `ticks`), strict then relaxed
    for cls in _CLS:
        exp = g[f"expired_{cls}"][ticks]
        e = exp.sum()
        violations += e
        if cls == "strict":
            violations_strict += e
        per["violations"] += exp
        per["expired_end"] += exp
    # supply side: per-tier dollars in tick order; chip-second totals
    cost_by_tier = {t: _seq_sum(v) for t, v in tier_cost.items()}
    out: Dict[str, object] = {
        "total_requests": total_requests,
        "served_vm": served_vm,
        "served_burst": served_burst,
        "violations": violations,
        "violations_strict": violations_strict,
        "cost_burst": cost_burst,
        "cost_reserved": cost_by_tier.pop("reserved", 0.0),
        "cost_spot": cost_by_tier.pop("spot", 0.0),
        "cost_other": cost_by_tier,
        "preemptions": preemptions,
        "variant_swaps": swaps,
        "accuracy_weighted": acc_weighted,
        "accuracy_served": acc_served,
        "acc_violations": acc_violations,
        "chip_seconds": _seq_sum(chip["chip"]),
        "chip_seconds_needed": _seq_sum(chip["need"]),
        "chip_seconds_over": _seq_sum(chip["over"]),
        "per_arch": per,
    }
    out["cost_total"] = (out["cost_reserved"] + out["cost_spot"]
                         + out["cost_burst"]
                         + sum(out["cost_other"].values()))
    return out


def _seq_sum(values: np.ndarray) -> float:
    """Strict left-to-right float accumulation (``+=`` per tick), matching
    the ledger's one-scalar-add-per-tick order — ``np.sum`` is pairwise
    and would differ in the last bits."""
    acc = 0.0
    for v in values:
        acc += v
    return acc


# ---------------------------------------------------------------------------
# Streaming SLO burn-rate / anomaly monitors.
# ---------------------------------------------------------------------------
@dataclass
class MonitorConfig:
    """Thresholds for :func:`detect_incidents` (tick units; windows are
    converted to recorder rows via the stride)."""

    slo_budget: float = 0.01          # tolerated violation fraction
    burn_threshold: float = 5.0       # burn multiple that pages
    short_window: int = 60            # fast window (ticks)
    long_window: int = 300            # confirmation window (ticks)
    queue_age_factor: float = 2.0     # p99 age limit = factor x class SLO
    cost_window: int = 300            # cost-drift trailing window (ticks)
    cost_drift_factor: float = 2.0    # x baseline $/request that pages
    min_window_requests: float = 1.0  # ignore windows with ~no traffic


@dataclass
class Incident:
    kind: str          # "slo_burn" | "queue_age" | "cost_drift"
    label: str         # latency class or metric the monitor watched
    start_tick: int
    end_tick: int
    peak: float        # worst monitor reading inside the incident
    detail: str = ""


def _rolling_sum(x: np.ndarray, w: int) -> np.ndarray:
    """Trailing-window sums: ``out[i] = sum(x[max(0, i-w+1) : i+1])``."""
    c = np.concatenate([[0.0], np.cumsum(x)])
    idx = np.arange(len(x)) + 1
    lo = np.maximum(idx - w, 0)
    return c[idx] - c[lo]


def _mask_to_incidents(mask: np.ndarray, ticks: np.ndarray, peak: np.ndarray,
                       kind: str, label: str, detail: str) -> List[Incident]:
    out: List[Incident] = []
    if not mask.any():
        return out
    edges = np.flatnonzero(np.diff(np.concatenate([[0], mask.view(np.int8), [0]])))
    for s, e in zip(edges[::2], edges[1::2]):   # [s, e) row runs
        out.append(Incident(
            kind=kind, label=label,
            start_tick=int(ticks[s]), end_tick=int(ticks[e - 1]),
            peak=float(peak[s:e].max()), detail=detail,
        ))
    return out


def detect_incidents(recorder: TimeSeriesRecorder,
                     cfg: MonitorConfig = MonitorConfig()) -> List[Incident]:
    """Run every monitor over the recorded series; returns incidents
    sorted by start tick.

    * **slo_burn** — SRE-style multi-window burn rate per latency class:
      ``burn = (violations / arrivals in window) / slo_budget``; pages
      when BOTH the short and the long window exceed ``burn_threshold``.
    * **queue_age** — per-class pool-max p99 queue age above
      ``queue_age_factor x`` the class SLO.
    * **cost_drift** — trailing cost-per-served-request above
      ``cost_drift_factor x`` the run's median.
    """
    n = recorder.n_rows
    if n == 0:
        return []
    stride = recorder.stride
    ticks = recorder.tick[:n]
    rows = lambda w: max(1, int(round(w / stride)))
    out: List[Incident] = []

    arrived = recorder.pool_flow("arrived")
    for cls, slo_s in (("strict", STRICT.slo_s), ("relaxed", RELAXED.slo_s)):
        viol = recorder.flows[f"viol_{cls}"][:n].sum(axis=1)
        # strict-class arrivals are not split out in the flows; burn is
        # measured against total pool arrivals, which only *understates*
        # the per-class burn — good enough to page on
        burns = []
        for w in (cfg.short_window, cfg.long_window):
            r = rows(w)
            va, aa = _rolling_sum(viol, r), _rolling_sum(arrived, r)
            ok = aa >= cfg.min_window_requests
            burns.append(np.where(
                ok, va / np.maximum(aa, 1e-9) / cfg.slo_budget, 0.0))
        mask = (burns[0] > cfg.burn_threshold) & (burns[1] > cfg.burn_threshold)
        out += _mask_to_incidents(
            mask, ticks, burns[0], "slo_burn", cls,
            f"burn > {cfg.burn_threshold:g}x budget "
            f"({cfg.slo_budget:.2%}) in both {cfg.short_window}s and "
            f"{cfg.long_window}s windows")

        age_limit = cfg.queue_age_factor * slo_s
        age = recorder.queue_age_p99[cls][:n].max(axis=1)
        out += _mask_to_incidents(
            age > age_limit, ticks, age.astype(float), "queue_age", cls,
            f"pool-max p99 queue age > {age_limit:g}s")

    cost = recorder.tier_cost[:n].sum(axis=1)
    served = (recorder.pool_flow("served_vm")
              + recorder.pool_flow("served_burst"))
    r = rows(cfg.cost_window)
    cs, ss = _rolling_sum(cost, r), _rolling_sum(served, r)
    valid = ss >= cfg.min_window_requests
    cpr = np.where(valid, cs / np.maximum(ss, 1e-9), np.nan)
    if valid.any():
        baseline = float(np.nanmedian(cpr))
        if baseline > 0:
            mask = valid & (cpr > cfg.cost_drift_factor * baseline)
            out += _mask_to_incidents(
                mask, ticks, np.nan_to_num(cpr / baseline), "cost_drift",
                "cost_per_request",
                f"trailing $/request > {cfg.cost_drift_factor:g}x the run "
                f"median (${baseline:.3g}/req)")
    out.sort(key=lambda i: (i.start_tick, i.kind, i.label))
    return out


def incidents_table(incidents: Sequence[Incident]) -> str:
    """Fixed-width text table of detected incidents."""
    if not incidents:
        return "no incidents detected\n"
    head = ("kind", "class", "start", "end", "peak", "detail")
    rows = [head] + [
        (i.kind, i.label, str(i.start_tick), str(i.end_tick),
         f"{i.peak:.2f}", i.detail)
        for i in incidents
    ]
    widths = [max(len(r[c]) for r in rows) for c in range(len(head) - 1)]
    lines = []
    for r in rows:
        cells = [r[c].ljust(widths[c]) for c in range(len(head) - 1)]
        lines.append("  ".join(cells) + "  " + r[-1])
    lines.insert(1, "-" * len(lines[0]))
    return "\n".join(lines) + "\n"
