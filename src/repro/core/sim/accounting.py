"""Cost / violation / over-provision ledger.

:class:`SimResult` is the reported record (the paper's three metrics:
cost, SLO violations, over-provisioning); :class:`Ledger` is the
write-side the engine and tiers post into each tick.  Keeping the
accumulation behind one interface means a new tier only needs a name —
``add_tier_cost("harvest", ...)`` — and the demand-side bookkeeping
stays in one place.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

#: What every :meth:`SimResult.summary` key means (docs/TELEMETRY.md is
#: generated against this; the coverage test keeps the two in sync).
#: ``cost_<tier>`` covers the dynamic keys tiers beyond the canonical
#: three post under their own names (``cost_harvest``, ``cost_remote``).
SUMMARY_KEY_DOCS: Dict[str, str] = {
    "cost_total": "total $ across every tier (reserved + spot + burst "
                  "+ any cost_<tier> entries)",
    "cost_reserved": "$ accrued by the reserved / multi-region reserved tier",
    "cost_spot": "$ accrued by the spot tier",
    "cost_burst": "$ paid to the serverless burst backend (per-request "
                  "premium pricing)",
    "cost_<tier>": "$ accrued by a non-canonical tier, keyed by its posted "
                   "name — present iff that tier was ever live in the run",
    "preemptions": "spot instances reclaimed by the provider mid-run",
    "violation_rate": "SLO-violating requests / total arrivals "
                      "(late-served + dropped + expired-at-end)",
    "violations_strict": "violating requests from the strict latency class",
    "served_vm": "requests answered by pool VMs (includes late ones; "
                 "dropped requests are counted served-late here)",
    "served_burst": "requests offloaded to and answered by the burst tier",
    "overprovision_ratio": "idle chip-seconds / needed chip-seconds "
                           "(the paper's over-provisioning metric)",
    "chip_seconds": "total provisioned chip-seconds across the run",
    "mean_accuracy": "answered-request-weighted mean accuracy of the "
                     "serving variants (variant-aware runs only)",
    "acc_violation_rate": "answered requests below their stream's accuracy "
                          "floor / all answered (variant-aware runs only)",
    "variant_swaps": "completed runtime model-variant swaps "
                     "(variant-aware runs only)",
}


@dataclass
class SimResult:
    cost_reserved: float = 0.0
    cost_spot: float = 0.0
    cost_burst: float = 0.0
    # tiers beyond the three canonical ones post here, keyed by tier name
    cost_other: Dict[str, float] = field(default_factory=dict)
    served_vm: float = 0.0
    served_burst: float = 0.0
    violations: float = 0.0
    violations_strict: float = 0.0
    total_requests: float = 0.0
    chip_seconds: float = 0.0
    chip_seconds_needed: float = 0.0
    chip_seconds_over: float = 0.0
    timeline: List[dict] = field(default_factory=list)

    preemptions: int = 0

    # --- delivered accuracy (the model-variant axis) ---------------------
    # ``accuracy_weighted`` accumulates answered-requests x the accuracy
    # of the variant that answered them; ``accuracy_served`` is the
    # matching answered mass, so weighted / served is the delivered mean.
    # Runs through a variant-blind path (the reference loop) never post
    # these, and ``summary()`` omits the derived keys in that case.
    accuracy_weighted: float = 0.0
    accuracy_served: float = 0.0
    acc_violations: float = 0.0          # answered below the accuracy floor
    variant_swaps: int = 0               # completed runtime variant swaps

    @property
    def cost_total(self) -> float:
        return (self.cost_reserved + self.cost_spot + self.cost_burst
                + sum(self.cost_other.values()))

    @property
    def violation_rate(self) -> float:
        return self.violations / max(self.total_requests, 1e-9)

    @property
    def mean_accuracy(self) -> float:
        """Delivered accuracy over every answered request."""
        return self.accuracy_weighted / max(self.accuracy_served, 1e-9)

    @property
    def acc_violation_rate(self) -> float:
        return self.acc_violations / max(self.accuracy_served, 1e-9)

    @property
    def overprovision_ratio(self) -> float:
        """Idle-capacity chip-seconds as a fraction of needed chip-seconds."""
        return self.chip_seconds_over / max(self.chip_seconds_needed, 1e-9)

    def summary(self) -> dict:
        s = {
            "cost_total": round(self.cost_total, 4),
            "cost_reserved": round(self.cost_reserved, 4),
            "cost_spot": round(self.cost_spot, 4),
            "cost_burst": round(self.cost_burst, 4),
            # tiers beyond the canonical three (harvest, remote, ...)
            # appear under their posted names — runs that never used them
            # report the same keys as before
            **{
                f"cost_{t}": round(v, 4)
                for t, v in sorted(self.cost_other.items())
            },
            "preemptions": self.preemptions,
            "violation_rate": round(self.violation_rate, 5),
            "violations_strict": round(self.violations_strict, 1),
            "served_vm": round(self.served_vm, 1),
            "served_burst": round(self.served_burst, 1),
            "overprovision_ratio": round(self.overprovision_ratio, 4),
            "chip_seconds": round(self.chip_seconds, 1),
        }
        if self.accuracy_served > 0:   # variant-aware run: report accuracy
            s["mean_accuracy"] = round(self.mean_accuracy, 5)
            s["acc_violation_rate"] = round(self.acc_violation_rate, 5)
            s["variant_swaps"] = self.variant_swaps
        return s


class Ledger:
    """Write-side of :class:`SimResult` used by the engine and the tiers."""

    def __init__(self) -> None:
        self.res = SimResult()

    # -- demand side ---------------------------------------------------------
    def add_arrivals(self, n: float) -> None:
        self.res.total_requests += n

    def add_served_vm(self, n: float) -> None:
        self.res.served_vm += n

    def add_violations(self, n: float, strict: float = 0.0) -> None:
        self.res.violations += n
        self.res.violations_strict += strict

    # -- supply side ---------------------------------------------------------
    def add_tier_cost(self, tier: str, dollars: float) -> None:
        attr = f"cost_{tier}"
        if hasattr(self.res, attr):
            setattr(self.res, attr, getattr(self.res, attr) + dollars)
        else:                       # a tier type added after this ledger
            other = self.res.cost_other
            other[tier] = other.get(tier, 0.0) + dollars

    def add_burst(self, cost: float, served: float, violations: float,
                  strict: bool) -> None:
        self.res.cost_burst += cost
        self.res.served_burst += served
        self.add_violations(violations, violations if strict else 0.0)

    def add_preemptions(self, n: int) -> None:
        self.res.preemptions += n

    # -- the model-variant axis ----------------------------------------------
    def add_accuracy(self, weighted: float, served: float) -> None:
        """Post one tick's answered mass and its accuracy-weighted sum."""
        self.res.accuracy_weighted += weighted
        self.res.accuracy_served += served

    def add_acc_violations(self, n: float) -> None:
        self.res.acc_violations += n

    def add_variant_swaps(self, n: int) -> None:
        self.res.variant_swaps += n

    def add_capacity(
        self,
        chip_seconds: np.ndarray,       # held chip-seconds per arch, all tiers
        rates: np.ndarray,              # this tick's arrivals per arch
        throughput: np.ndarray,         # per-instance req/s per arch
        chips_per_instance: np.ndarray,
    ) -> None:
        """Over-provisioning bookkeeping: held vs minimally-needed chips."""
        need = np.ceil(rates / throughput) * chips_per_instance
        self.res.chip_seconds += float(chip_seconds.sum())
        self.res.chip_seconds_needed += float(need.sum())
        self.res.chip_seconds_over += float(
            np.maximum(chip_seconds - need, 0.0).sum()
        )
