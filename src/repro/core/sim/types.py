"""Shared vocabulary of the simulation package.

Everything a *policy* needs to speak to the engine lives here: the
workload description (:class:`ArchLoad`), the two latency classes, the
per-arch observation/action records of the legacy dict interface, their
structure-of-arrays counterparts (:class:`PoolObs` / :class:`PoolAction`)
used by vectorized policies on large pools, and the **model-variant
axis**: :class:`VariantCatalog`, the per-arch ordered variant sets
(accuracy / service-rate / cost multipliers derived from the Fig-2
profile pool) a variant-aware engine run swaps between at runtime.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Mapping, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import RequestClass

STRICT = RequestClass("strict", 512, 64, slo_s=2.0, strict=True)
RELAXED = RequestClass("relaxed", 512, 64, slo_s=20.0, strict=False)

#: latency classes in serving priority order (strict is served first)
CLASSES = (STRICT, RELAXED)

#: ``Action.offload`` modes, index == integer code in ``PoolAction.offload``
OFFLOAD_MODES = ("none", "blind", "slack_aware")
OFFLOAD_NONE, OFFLOAD_BLIND, OFFLOAD_SLACK_AWARE = range(3)


# ---------------------------------------------------------------------------
# Telemetry event record.
# ---------------------------------------------------------------------------
class TelemetryEvent(NamedTuple):
    """One structured observability record emitted by the engine or a tier.

    ``arch`` is the pool index the event concerns (``-1`` = pool-level),
    ``tier`` the resource tier name (``""`` when not tier-scoped), ``cls``
    the latency class (``"strict"``/``"relaxed"``, ``""`` when classless).
    ``magnitude`` carries the event's primary quantity (requests, instances,
    chip-seconds — see :data:`repro.core.sim.telemetry.EVENT_TYPES`) and
    ``cost`` its dollar amount when one applies.  The event stream is the
    ground truth the :class:`~repro.core.sim.accounting.Ledger` is
    reconciled against: summing event magnitudes in tick order reproduces
    every ledger total bit-exactly."""

    tick: int
    etype: str
    arch: int = -1
    tier: str = ""
    cls: str = ""
    magnitude: float = 1.0
    cost: float = 0.0


# ---------------------------------------------------------------------------
# Workload description.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ArchLoad:
    """One pool member.  ``share`` only splits a 1-D pool trace; when the
    engine is driven by a per-arch ``[A, T]`` arrival matrix
    (:mod:`repro.core.workloads`) each row IS the arch's stream and
    ``share`` is ignored for admission (``strict_frac`` still applies).

    ``min_accuracy`` is the stream's accuracy SLO: requests answered by a
    variant below this floor count as accuracy violations (0.0 = no
    constraint, the default)."""

    arch: str
    share: float                   # fraction of total arrivals
    strict_frac: float = 0.5       # strict vs relaxed query mix (workload-1)
    name: Optional[str] = None     # pool key; lets one arch appear many
                                   # times in a large pool (defaults to arch)
    min_accuracy: float = 0.0      # per-stream accuracy floor (accuracy SLO)

    @property
    def key(self) -> str:
        return self.name or self.arch


def shares(workload: List["ArchLoad"]) -> np.ndarray:
    """The workload's share vector ``[A]`` — what fans a 1-D pool trace
    out per arch, and what :func:`repro.core.workloads.from_pool_trace`
    needs to rebuild those arrivals as a matrix."""
    return np.array([w.share for w in workload], dtype=np.float64)


def uniform_pool_workload(archs: List[str], strict_frac: float = 0.5) -> List[ArchLoad]:
    return [ArchLoad(a, 1.0 / len(archs), strict_frac) for a in archs]


def replicate_pool(
    archs: List[str], n: int, strict_frac: float = 0.5
) -> List[ArchLoad]:
    """An ``n``-entry pool cycling through ``archs`` with unique keys —
    the pool-scale workloads (50-100 model variants) of INFaaS-style
    model-less serving, built from the profiled architectures we have."""
    return [
        ArchLoad(archs[i % len(archs)], 1.0 / n, strict_frac,
                 name=f"{archs[i % len(archs)]}@{i}")
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# The model-variant axis (INFaaS / Cocktail: model-less serving).
# ---------------------------------------------------------------------------
def filter_pool_candidates(
    pool: Mapping[str, dict],
    *,
    min_accuracy: float = 0.0,
    max_latency_s: float = float("inf"),
) -> Dict[str, dict]:
    """The accuracy/latency candidate filter over a Fig-2 style pool dict
    (:func:`repro.core.profiles.model_pool` entries).

    This is the single implementation both accuracy axes consume: the
    offline selector (:mod:`repro.core.model_selection`) filters a
    query's feasible set with it, and :class:`VariantCatalog` filters an
    arch's runtime variant set with it — so the two can never drift.
    """
    return {
        a: e
        for a, e in pool.items()
        if e["accuracy"] >= min_accuracy and e["latency_s"] <= max_latency_s
    }


@dataclass(frozen=True)
class Variant:
    """One runtime substitute for an arch's base model.

    Multipliers are *relative to the arch's base variant* (the arch
    itself, whose multipliers are exactly 1.0): switching to this variant
    scales the arch's per-instance service rate by ``service_mult``, its
    per-instance chip footprint (and therefore held-capacity cost) by
    ``cost_mult``, and its batch-1 request latency — what a burst
    invocation of the swapped pool observes — by ``lat_mult``; answered
    requests deliver ``accuracy``.  ``cost_per_1k`` is the Fig-2 cost
    basis "cheapest" decisions rank by.
    """

    arch: str
    accuracy: float
    service_mult: float
    cost_mult: float
    cost_per_1k: float
    lat_mult: float = 1.0


class VariantCatalog:
    """Per-arch ordered variant sets, derived from the Fig-2 profile pool.

    For every arch the catalog holds a tuple of :class:`Variant` ordered
    by accuracy ascending (ties broken by cost, then name) — index 0 is
    the least accurate substitute, the last index the most accurate —
    plus the index of the arch's *base* variant (itself; multipliers
    exactly 1.0, so a run that never swaps is bit-identical to a
    variant-blind run).  The engine gathers per-tick effective
    throughput / chips / accuracy from these sets via the per-arch
    ``active_variant`` index.
    """

    def __init__(self, per_arch: Dict[str, Tuple[Variant, ...]],
                 base_idx: Dict[str, int]):
        assert set(per_arch) == set(base_idx)
        for arch, vs in per_arch.items():
            assert len(vs) >= 1, arch
            accs = [v.accuracy for v in vs]
            assert accs == sorted(accs), f"{arch}: variants not accuracy-ordered"
            b = base_idx[arch]
            assert 0 <= b < len(vs), arch
            assert vs[b].arch == arch, f"{arch}: base variant must be itself"
            assert vs[b].service_mult == 1.0 and vs[b].cost_mult == 1.0, arch
        self.per_arch = dict(per_arch)
        self.base_idx = dict(base_idx)

    # -- construction -------------------------------------------------------
    @classmethod
    def from_pool(
        cls,
        pool: Mapping[str, dict],
        archs: Optional[Sequence[str]] = None,
        *,
        candidates: Optional[Sequence[str]] = None,
        min_accuracy: float = 0.0,
        max_latency_s: float = float("inf"),
    ) -> "VariantCatalog":
        """Build the catalog from a Fig-2 style pool dict.

        ``archs`` names the pool members that get a variant set (default:
        every pool entry); ``candidates`` names the entries allowed to
        serve as variants (default: ``archs`` — the deployable pool;
        widen it explicitly to let swaps reach models outside the
        operated fleet).  Candidates are filtered through
        :func:`filter_pool_candidates` plus a positive service rate; an
        arch's own entry always joins its set (it is the base), so every
        arch has at least one variant.
        """
        archs = list(archs if archs is not None else pool)
        cand_pool = {
            a: pool[a] for a in (archs if candidates is None else candidates)
        }
        cands = {
            a: e
            for a, e in filter_pool_candidates(
                cand_pool, min_accuracy=min_accuracy,
                max_latency_s=max_latency_s,
            ).items()
            if e["throughput_rps"] > 0 and math.isfinite(e["cost_per_1k"])
        }
        per_arch: Dict[str, Tuple[Variant, ...]] = {}
        base_idx: Dict[str, int] = {}
        for arch in archs:
            base = pool[arch]
            members = dict(cands)
            members[arch] = base           # the base always belongs
            ordered = sorted(
                members,
                key=lambda a: (members[a]["accuracy"],
                               members[a]["cost_per_1k"], a),
            )
            vs = tuple(
                Variant(
                    arch=a,
                    accuracy=float(members[a]["accuracy"]),
                    service_mult=(
                        1.0 if a == arch else
                        float(members[a]["throughput_rps"])
                        / float(base["throughput_rps"])
                    ),
                    cost_mult=(
                        1.0 if a == arch else
                        float(members[a]["chips"]) / float(base["chips"])
                    ),
                    cost_per_1k=float(members[a]["cost_per_1k"]),
                    lat_mult=(
                        1.0 if a == arch else
                        float(members[a]["latency_s"])
                        / float(base["latency_s"])
                    ),
                )
                for a in ordered
            )
            per_arch[arch] = vs
            base_idx[arch] = ordered.index(arch)
        return cls(per_arch, base_idx)

    @classmethod
    def for_workload(
        cls,
        workload: List["ArchLoad"],
        req: Optional[RequestClass] = None,
        *,
        candidates: Optional[Sequence[str]] = None,
        min_accuracy: float = 0.0,
        max_latency_s: Optional[float] = None,
    ) -> "VariantCatalog":
        """Catalog over a workload's archs from the live Fig-2 pool
        (:func:`repro.core.profiles.model_pool` — the single source of
        truth for the accuracy / service-rate / cost numbers).  Variants
        default to the workload's own archs (the deployable pool); the
        latency bound defaults to the strict class SLO, so every variant
        can serve strict queries."""
        from repro.core.profiles import model_pool  # late: keep import light

        req = STRICT if req is None else req
        bound = req.slo_s if max_latency_s is None else max_latency_s
        return cls.from_pool(
            model_pool(req),
            sorted({w.arch for w in workload}),
            candidates=candidates,
            min_accuracy=min_accuracy,
            max_latency_s=bound,
        )

    # -- queries ------------------------------------------------------------
    def variants(self, arch: str) -> Tuple[Variant, ...]:
        return self.per_arch[arch]

    def n_variants(self, arch: str) -> int:
        return len(self.per_arch[arch])

    def floor_indices(self, arch: str, floor: float) -> Tuple[int, int]:
        """``(lo, cheapest)`` for an accuracy floor: the lowest variant
        index meeting it and the cheapest (Fig-2 cost basis) index
        meeting it.  When no variant meets the floor both fall back to
        the most accurate variant (the closest the arch can get)."""
        vs = self.per_arch[arch]
        ok = [i for i, v in enumerate(vs) if v.accuracy >= floor - 1e-12]
        if not ok:
            top = len(vs) - 1
            return top, top
        return ok[0], min(ok, key=lambda i: (vs[i].cost_per_1k, i))

    def as_arrays(self, workload: List["ArchLoad"]) -> Dict[str, np.ndarray]:
        """Padded SoA view for the engine: ``accuracy`` / ``service_mult``
        / ``cost_mult`` / ``lat_mult`` are ``[A, Vmax]`` (rows padded with
        their last variant — indices are clipped to ``n_variants - 1`` so
        padding is never addressed), plus ``n_variants`` / ``base_idx`` /
        ``floor_lo`` / ``floor_cheapest`` ``[A]`` integer vectors (the
        floor indices evaluated at each stream's ``min_accuracy``)."""
        sets = [self.per_arch[w.arch] for w in workload]
        vmax = max(len(vs) for vs in sets)
        n = len(workload)
        acc = np.empty((n, vmax)); smult = np.empty((n, vmax))
        cmult = np.empty((n, vmax)); lmult = np.empty((n, vmax))
        nvar = np.empty(n, dtype=np.int64)
        base = np.empty(n, dtype=np.int64)
        lo = np.empty(n, dtype=np.int64)
        cheap = np.empty(n, dtype=np.int64)
        for i, (w, vs) in enumerate(zip(workload, sets)):
            row_acc = [v.accuracy for v in vs]
            row_s = [v.service_mult for v in vs]
            row_c = [v.cost_mult for v in vs]
            row_l = [v.lat_mult for v in vs]
            pad = vmax - len(vs)
            acc[i] = row_acc + [row_acc[-1]] * pad
            smult[i] = row_s + [row_s[-1]] * pad
            cmult[i] = row_c + [row_c[-1]] * pad
            lmult[i] = row_l + [row_l[-1]] * pad
            nvar[i] = len(vs)
            base[i] = self.base_idx[w.arch]
            lo[i], cheap[i] = self.floor_indices(w.arch, w.min_accuracy)
        return {
            "accuracy": acc, "service_mult": smult, "cost_mult": cmult,
            "lat_mult": lmult,
            "n_variants": nvar, "base_idx": base,
            "floor_lo": lo, "floor_cheapest": cheap,
        }


# ---------------------------------------------------------------------------
# Policy interface (legacy dict form — one record per arch per tick).
# ---------------------------------------------------------------------------
@dataclass
class ArchObs:
    arch: str
    rate: float                    # this tick's arrivals (req/s)
    ewma_rate: float
    window_peak: float
    peak_to_median: float
    queue_len: float
    n_active: int
    n_pending: int
    n_spot: int
    throughput: float              # per-instance req/s (active variant)
    utilization: float             # served / capacity, last tick
    # --- tier-portfolio state (defaults = the reserved-only world) --------
    n_spot_pending: int = 0        # spot launches in flight
    n_harvest: int = 0             # active harvest-VM instances
    n_harvest_pending: int = 0
    n_remote: int = 0              # active remote-region reserved instances
    n_remote_pending: int = 0
    spot_reclaim_risk: float = 0.0   # per-instance per-tick reclaim prob.
    harvest_level: float = 1.0       # current harvest availability signal
    harvest_ceiling: int = 0         # instances the provider grants at it
    # --- model-variant state (defaults = the single-variant world) -------
    active_variant: int = 0        # index into the arch's ordered variant set
    n_variants: int = 1
    accuracy: float = 0.0          # accuracy delivered by the active variant
    accuracy_floor: float = 0.0    # this stream's accuracy SLO
    variant_lo: int = 0            # lowest index meeting the floor
    variant_cheapest: int = 0      # cheapest index meeting the floor
    variant_in_flight: bool = False  # a swap is mid-pipeline
    variant_up_ratio: float = 1.0    # service-rate ratio of the next
                                     # variant up (1.0 at the top)
    variant_down_ratio: float = 1.0  # ... of the next variant down
    variant_pending_ratio: float = 1.0  # ... of the in-flight target


@dataclass
class Action:
    """Per-arch procurement decision for this tick.

    ``offload`` semantics (who may go to burst, and when):
      ``none``        — VM-only procurement (reactive / util_aware / exascale)
      ``blind``       — ANY request not served this tick is offloaded
                        immediately (MArk/Spock: one global SLO assumption)
      ``slack_aware`` — a request offloads only when its own latency class
                        is about to violate (paper's Paragon: relaxed
                        queries ride out the spike in queue first)
    """

    target: int                    # desired reserved (on-demand) instances
    offload: str = "none"          # none | blind | slack_aware
    spot_target: int = 0           # desired SPOT instances (preemptible,
                                   # spot_discount x price — §VI extension)
    variant: int = -1              # desired variant index (-1 = hold; a
                                   # swap serves at the OLD rate for
                                   # pricing.variant_swap_s first)
    harvest_target: int = 0        # desired harvest-VM instances (capped
                                   # by the provider's harvest ceiling)
    remote_target: int = 0         # desired remote-region reserved
                                   # instances (egress adder per request)


Policy = Callable[[int, Dict[str, ArchObs]], Dict[str, Action]]


# ---------------------------------------------------------------------------
# Vectorized policy interface (structure-of-arrays over the whole pool).
# ---------------------------------------------------------------------------
@dataclass
class PoolObs:
    """One tick's observation for the whole pool, each field an ``[A]``
    array aligned with ``keys``.  Field meanings match :class:`ArchObs`;
    the tail fields below the line have no dict counterpart — they are
    the per-class queue split and last-tick violation feedback the
    pool-wide RL controller's feature vector needs.

    .. warning:: **Aliasing contract.**  ``ServingSim.observe_pool``
       returns engine-OWNED buffers, refilled in place every tick to
       keep the hot loop allocation-free.  A ``PoolObs`` is therefore
       valid only until the next ``observe_pool`` call: a policy that
       retains one across ticks will see its arrays silently mutate
       under it.  Schedulers that need history must call :meth:`copy`
       (or copy individual fields out) before the next tick.
    """

    keys: List[str]
    rate: np.ndarray
    ewma_rate: np.ndarray
    window_peak: np.ndarray
    peak_to_median: np.ndarray
    queue_len: np.ndarray
    n_active: np.ndarray
    n_pending: np.ndarray
    n_spot: np.ndarray
    throughput: np.ndarray
    utilization: np.ndarray
    queue_strict: Optional[np.ndarray] = None
    queue_relaxed: Optional[np.ndarray] = None
    last_violations: Optional[np.ndarray] = None   # violations booked last tick
    # --- tier-portfolio state, each [A] (engine always fills these) -------
    n_spot_pending: Optional[np.ndarray] = None
    n_harvest: Optional[np.ndarray] = None
    n_harvest_pending: Optional[np.ndarray] = None
    n_remote: Optional[np.ndarray] = None
    n_remote_pending: Optional[np.ndarray] = None
    spot_reclaim_risk: Optional[np.ndarray] = None  # per-tick reclaim prob.
    harvest_level: Optional[np.ndarray] = None      # availability signal
    harvest_ceiling: Optional[np.ndarray] = None    # granted instance cap
    # --- model-variant state, each [A] (engine always fills these) -------
    active_variant: Optional[np.ndarray] = None    # int index per arch
    n_variants: Optional[np.ndarray] = None
    accuracy: Optional[np.ndarray] = None          # active variant's accuracy
    accuracy_floor: Optional[np.ndarray] = None    # per-stream accuracy SLO
    variant_lo: Optional[np.ndarray] = None        # lowest index meeting floor
    variant_cheapest: Optional[np.ndarray] = None  # cheapest index meeting floor
    variant_in_flight: Optional[np.ndarray] = None  # bool: swap mid-pipeline
    variant_up_ratio: Optional[np.ndarray] = None   # smult(next up) / smult(cur)
    variant_down_ratio: Optional[np.ndarray] = None  # smult(next down) / smult(cur)
    variant_pending_ratio: Optional[np.ndarray] = None  # smult(pending) / smult(cur)

    def copy(self) -> "PoolObs":
        """A deep, caller-owned snapshot safe to retain across ticks
        (see the aliasing contract in the class docstring)."""
        import dataclasses as _dc

        return PoolObs(**{
            f.name: (
                v.copy() if isinstance(v, np.ndarray) else
                list(v) if f.name == "keys" else v
            )
            for f in _dc.fields(self)
            for v in (getattr(self, f.name),)
        })


@dataclass
class PoolAction:
    """Whole-pool procurement decision: ``target`` is required; ``offload``
    holds integer codes indexing :data:`OFFLOAD_MODES`;
    ``variant_target`` holds desired variant indices (-1 = hold, the
    default — a pool that never sets it is bit-identical to the
    variant-blind engine)."""

    target: np.ndarray
    offload: Optional[np.ndarray] = None   # defaults to all-"none"
    spot_target: Optional[np.ndarray] = None
    variant_target: Optional[np.ndarray] = None   # defaults to all-hold (-1)
    harvest_target: Optional[np.ndarray] = None
    remote_target: Optional[np.ndarray] = None

    def offload_codes(self, n: int) -> np.ndarray:
        return (np.zeros(n, dtype=np.int64)
                if self.offload is None else self.offload)

    def spot_targets(self, n: int) -> np.ndarray:
        return (np.zeros(n, dtype=np.int64)
                if self.spot_target is None else self.spot_target)

    def variant_targets(self, n: int) -> np.ndarray:
        return (np.full(n, -1, dtype=np.int64)
                if self.variant_target is None else self.variant_target)

    def harvest_targets(self, n: int) -> np.ndarray:
        return (np.zeros(n, dtype=np.int64)
                if self.harvest_target is None else self.harvest_target)

    def remote_targets(self, n: int) -> np.ndarray:
        return (np.zeros(n, dtype=np.int64)
                if self.remote_target is None else self.remote_target)


VectorPolicy = Callable[[int, PoolObs], PoolAction]
