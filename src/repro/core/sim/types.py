"""Shared vocabulary of the simulation package.

Everything a *policy* needs to speak to the engine lives here: the
workload description (:class:`ArchLoad`), the two latency classes, the
per-arch observation/action records of the legacy dict interface, and
their structure-of-arrays counterparts (:class:`PoolObs` /
:class:`PoolAction`) used by vectorized policies on large pools.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.profiles import RequestClass

STRICT = RequestClass("strict", 512, 64, slo_s=2.0, strict=True)
RELAXED = RequestClass("relaxed", 512, 64, slo_s=20.0, strict=False)

#: latency classes in serving priority order (strict is served first)
CLASSES = (STRICT, RELAXED)

#: ``Action.offload`` modes, index == integer code in ``PoolAction.offload``
OFFLOAD_MODES = ("none", "blind", "slack_aware")
OFFLOAD_NONE, OFFLOAD_BLIND, OFFLOAD_SLACK_AWARE = range(3)


# ---------------------------------------------------------------------------
# Workload description.
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ArchLoad:
    """One pool member.  ``share`` only splits a 1-D pool trace; when the
    engine is driven by a per-arch ``[A, T]`` arrival matrix
    (:mod:`repro.core.workloads`) each row IS the arch's stream and
    ``share`` is ignored for admission (``strict_frac`` still applies)."""

    arch: str
    share: float                   # fraction of total arrivals
    strict_frac: float = 0.5       # strict vs relaxed query mix (workload-1)
    name: Optional[str] = None     # pool key; lets one arch appear many
                                   # times in a large pool (defaults to arch)

    @property
    def key(self) -> str:
        return self.name or self.arch


def shares(workload: List["ArchLoad"]) -> np.ndarray:
    """The workload's share vector ``[A]`` — what fans a 1-D pool trace
    out per arch, and what :func:`repro.core.workloads.from_pool_trace`
    needs to rebuild those arrivals as a matrix."""
    return np.array([w.share for w in workload], dtype=np.float64)


def uniform_pool_workload(archs: List[str], strict_frac: float = 0.5) -> List[ArchLoad]:
    return [ArchLoad(a, 1.0 / len(archs), strict_frac) for a in archs]


def replicate_pool(
    archs: List[str], n: int, strict_frac: float = 0.5
) -> List[ArchLoad]:
    """An ``n``-entry pool cycling through ``archs`` with unique keys —
    the pool-scale workloads (50-100 model variants) of INFaaS-style
    model-less serving, built from the profiled architectures we have."""
    return [
        ArchLoad(archs[i % len(archs)], 1.0 / n, strict_frac,
                 name=f"{archs[i % len(archs)]}@{i}")
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Policy interface (legacy dict form — one record per arch per tick).
# ---------------------------------------------------------------------------
@dataclass
class ArchObs:
    arch: str
    rate: float                    # this tick's arrivals (req/s)
    ewma_rate: float
    window_peak: float
    peak_to_median: float
    queue_len: float
    n_active: int
    n_pending: int
    n_spot: int
    throughput: float              # per-instance req/s
    utilization: float             # served / capacity, last tick


@dataclass
class Action:
    """Per-arch procurement decision for this tick.

    ``offload`` semantics (who may go to burst, and when):
      ``none``        — VM-only procurement (reactive / util_aware / exascale)
      ``blind``       — ANY request not served this tick is offloaded
                        immediately (MArk/Spock: one global SLO assumption)
      ``slack_aware`` — a request offloads only when its own latency class
                        is about to violate (paper's Paragon: relaxed
                        queries ride out the spike in queue first)
    """

    target: int                    # desired reserved (on-demand) instances
    offload: str = "none"          # none | blind | slack_aware
    spot_target: int = 0           # desired SPOT instances (preemptible,
                                   # spot_discount x price — §VI extension)


Policy = Callable[[int, Dict[str, ArchObs]], Dict[str, Action]]


# ---------------------------------------------------------------------------
# Vectorized policy interface (structure-of-arrays over the whole pool).
# ---------------------------------------------------------------------------
@dataclass
class PoolObs:
    """One tick's observation for the whole pool, each field an ``[A]``
    array aligned with ``keys``.  Field meanings match :class:`ArchObs`;
    the tail fields below the line have no dict counterpart — they are
    the per-class queue split and last-tick violation feedback the
    pool-wide RL controller's feature vector needs."""

    keys: List[str]
    rate: np.ndarray
    ewma_rate: np.ndarray
    window_peak: np.ndarray
    peak_to_median: np.ndarray
    queue_len: np.ndarray
    n_active: np.ndarray
    n_pending: np.ndarray
    n_spot: np.ndarray
    throughput: np.ndarray
    utilization: np.ndarray
    queue_strict: Optional[np.ndarray] = None
    queue_relaxed: Optional[np.ndarray] = None
    last_violations: Optional[np.ndarray] = None   # violations booked last tick


@dataclass
class PoolAction:
    """Whole-pool procurement decision: ``target`` is required; ``offload``
    holds integer codes indexing :data:`OFFLOAD_MODES`."""

    target: np.ndarray
    offload: Optional[np.ndarray] = None   # defaults to all-"none"
    spot_target: Optional[np.ndarray] = None

    def offload_codes(self, n: int) -> np.ndarray:
        return (np.zeros(n, dtype=np.int64)
                if self.offload is None else self.offload)

    def spot_targets(self, n: int) -> np.ndarray:
        return (np.zeros(n, dtype=np.int64)
                if self.spot_target is None else self.spot_target)


VectorPolicy = Callable[[int, PoolObs], PoolAction]
