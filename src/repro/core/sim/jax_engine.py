"""Batched pure-functional JAX twin of the tick engine.

The NumPy engine (:mod:`repro.core.sim.engine`) advances one tick per
Python call: admit -> provision -> serve -> offload -> drop -> account.
This module re-expresses that pipeline as a pure function over one flat
pytree of arrays (:class:`SimState`) so a whole trajectory compiles to a
single ``jax.lax.scan`` — and, with ``jax.vmap`` over a leading batch
axis, a whole (scenario x seed x policy-params) evaluation grid runs as
ONE dispatch instead of B Python tick loops.

Semantics are pinned to the NumPy engine, which stays the oracle
(``tests/test_jax_engine.py`` differential-fuzzes the two over the
scenario zoo).  Three representation changes make the port pure AND
fast without changing results:

* **Prefix-sum age buffers.**  The NumPy queues/pipelines are
  tick-indexed ring buffers of per-age counts; here every age buffer
  is stored as its *running prefix sum* along the age axis.  Queues
  are oldest-first (``S[:, j]`` = total mass in the ``j+1`` oldest
  buckets, so the last column is the queue total), pipelines
  newest-first (``P[:, j]`` = launches in the ``j+1`` newest cohorts).
  The payoff is that every order-dependent operation collapses to a
  rank-1 broadcast:

  - serving ``c`` oldest-first: the cumulative take through bucket
    ``j`` is ``min(S_j, c)``, so ``S' = max(S - c, 0)`` and ``served =
    min(S_last, c)``;
  - SLO lateness: the late buckets are exactly the oldest ``m`` (an
    age-contiguous prefix), so the late mass served is ``min(S[m-1],
    c)`` — a single gather;
  - aging is a column shift, grow / drop / drain are broadcast add /
    subtract / row-zero, and totals are the last column.

  No cumulative sum survives into the compiled tick — XLA's CPU scan
  kernel costs several times a copy over the same elements, and the
  naive count-space port spent most of its wall-clock there; in prefix
  form a queue tick is a handful of fused elementwise passes.

* **Cumulative-counter pipeline rings.**  Tier provisioning pipelines
  (up to 300 ticks deep for the remote tier) would pay an O(A*L) shift
  per tick even in prefix form, and shifting them was the ported
  tick's dominant cost.  Instead each pipeline stores a ring of
  *cumulative granted* counters: slot ``t mod L`` holds ``G_t``, the
  clipped running total of instances granted through tick ``t``.  A
  cohort launched at ``t`` matures at ``t + L``, exactly when its slot
  comes around again, so ready = ``G_{t-L} - G_{t-L-1}`` (the slot
  read minus last tick's), pending = ``G - G_{t-L}``, and the push is
  a single-slot write — all O(A).  Cancelling ``c`` newest-first
  clips the cumulative curve from the top: ``ring = min(ring, G - c)``
  — and because every stored value is ``<= G``, the same clip is a
  numeric no-op on cancel-free ticks, so it runs unconditionally as
  the only O(A*L) pass a pipeline pays per tick.

* **Everything-runs-every-tick.**  The NumPy engine lazily skips idle
  tiers and empty offloads; here every tier provisions, serves and
  accounts unconditionally — a 0-active tier contributes exact zeros,
  so the branchless form is identical (down to summary key presence,
  which per-tick liveness flags reconstruct).

* **Host-precomputed inputs.**  Every stochastic or stream-derived
  input is a pure function of ``(seed, tick)`` or of the arrival matrix
  alone, so the monitor statistics
  (:func:`~repro.core.load_monitor.pool_stats_trajectory`), the harvest
  signal (:func:`~repro.core.sim.fleet.harvest_level_trajectory`) and
  the spot reclaim uniforms
  (:func:`~repro.core.sim.fleet.spot_reclaim_uniforms`) are
  materialized host-side, bit-identical to the streams the NumPy tiers
  consume, and fed to the scan as per-tick inputs.

Everything runs under ``jax.experimental.enable_x64`` (float64, like
the NumPy engine) without flipping the global flag — the float32 PPO
training stack is untouched.  Policies are in-scan twins of the
vectorized schedulers (:data:`JAX_POLICIES`); their parameters ride in
the traced statics pytree, so a parameter sweep vmaps without
recompiling and one trace serves every workload of the same shape.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import enable_x64

from repro.core.hardware import PRICING, FleetPricing
from repro.core.load_monitor import (
    LoadMonitor,
    pool_stats_trajectory,
)
from repro.core.rl.obs import (
    pool_features_arrays,
    procurement_targets_arrays,
    variant_targets_arrays,
)
from repro.core.schedulers import (
    accuracy_floor_move_arrays,
    infaas_variant_move_arrays,
    swap_aware_target_arrays,
)
from repro.core.rl.policy import (
    load_policy_checkpoint,
    _fallback_params,
    policy_logits,
)
from repro.core.sim import telemetry
from repro.core.sim.engine import ServingSim
from repro.core.sim.fleet import (
    BINOMIAL_KMAX,
    harvest_level_trajectory,
    spot_reclaim_uniforms,
)
from repro.core.sim.types import ArchLoad

__all__ = [
    "SimState",
    "JAX_POLICIES",
    "binomial_from_uniform_jnp",
    "build_sim_inputs",
    "make_runner",
    "note_runner_use",
    "run_scenario",
    "run_grid",
    "runner_trace_count",
]


# ---------------------------------------------------------------------------
# State pytree.
# ---------------------------------------------------------------------------
class SimState(NamedTuple):
    """All engine / tier / queue / monitor state for one tick, flat.

    ``*_buf`` are ``[A, W]`` oldest-first queue *prefix sums* (column
    ``j`` totals the ``j+1`` oldest age buckets; the last column is the
    queue total).  Each tier pipeline is a cumulative-counter ring
    (see the module docstring): ``*_ring [A, L]`` holds the clipped
    cumulative granted count by launch slot, ``*_cum [A]`` the current
    cumulative total and ``*_mat [A]`` the cumulative matured total.
    """

    qs_buf: Any          # [A, Ws] strict queue prefix mass (f64)
    qr_buf: Any          # [A, Wr] relaxed queue prefix mass (f64)
    res_active: Any      # [A]     reserved instances (i64)
    res_ring: Any        # [A, Lr] cumulative grants by launch slot (i32)
    res_cum: Any         # [A]     cumulative granted (i32)
    res_mat: Any         # [A]     cumulative matured (i32)
    spot_active: Any
    spot_ring: Any
    spot_cum: Any
    spot_mat: Any
    harv_active: Any
    harv_ring: Any
    harv_cum: Any
    harv_mat: Any
    rem_active: Any
    rem_ring: Any
    rem_cum: Any
    rem_mat: Any
    burst_last_used: Any  # [A] last tick the burst pool saw each arch
    last_util: Any        # [A] previous tick's utilization (policy obs)
    last_viol: Any        # [A] previous tick's violation delta
    prev_rate: Any        # [A] previous tick's arrivals (RL trend feature)
    ewma: Any = None      # [A] in-carry EWMA (None when fed via xs)
    # lazy-ring sliding-window-min state, per tier (None on the eager
    # path): [A, L] per-tick event minima, [A, L] previous-block suffix
    # minima, [A] current-block running min — see _tier_set_target_lazy
    res_ehist: Any = None
    res_sufmin: Any = None
    res_bmin: Any = None
    spot_ehist: Any = None
    spot_sufmin: Any = None
    spot_bmin: Any = None
    harv_ehist: Any = None
    harv_sufmin: Any = None
    harv_bmin: Any = None
    rem_ehist: Any = None
    rem_sufmin: Any = None
    rem_bmin: Any = None
    # model-variant swap pipeline (None on catalog-free runs): the NumPy
    # SwapPipeline's (current, pending, ready_at) triple — at most one
    # swap per arch is ever in flight, so the ISSUE's "ring" collapses
    # to a depth-1 slot and every op is O(A)
    var_cur: Any = None       # [A] active variant index (i64)
    var_pending: Any = None   # [A] in-flight swap target, -1 = none (i64)
    var_ready: Any = None     # [A] tick the in-flight swap matures (i64)
    var_last_move: Any = None  # [A] variant-policy cooldown state (i64)


# ---------------------------------------------------------------------------
# Primitive ops (exact twins of the NumPy engine's steps).
# ---------------------------------------------------------------------------
def binomial_from_uniform_jnp(n, p, u):
    """Traceable twin of :func:`repro.core.sim.fleet.binomial_from_uniform`.

    Identical inverse-CDF walk, identical :data:`BINOMIAL_KMAX` cap —
    the early exit of the NumPy loop never changes the count (the
    ``u >= cdf`` indicator is monotone in the walk), so a bounded
    ``lax.while_loop`` reproduces it exactly.
    """
    n = jnp.asarray(n)
    u = jnp.asarray(u, dtype=jnp.float64)
    p = jnp.asarray(p, dtype=jnp.float64)
    pc = jnp.clip(p, 1e-12, 1.0 - 1e-12)   # walk-safe; edges handled below
    q = 1.0 - pc
    nf = n.astype(jnp.float64)
    pmf0 = q ** nf
    k0 = (u >= pmf0).astype(n.dtype)

    def cond(c):
        j, _, cdf, _ = c
        return (j <= BINOMIAL_KMAX) & (u >= cdf).any()

    def body(c):
        j, pmf, cdf, k = c
        jf = j.astype(jnp.float64)
        pmf = jnp.maximum(pmf * ((nf - (jf - 1.0)) / jf) * (pc / q), 0.0)
        cdf = cdf + pmf
        k = k + (u >= cdf).astype(n.dtype)
        return j + 1, pmf, cdf, k

    j0 = jnp.asarray(1, dtype=jnp.int64)
    _, _, _, k = lax.while_loop(cond, body, (j0, pmf0, pmf0, k0))
    k = jnp.minimum(k, n)
    zero = jnp.zeros_like(n)
    return jnp.where(p <= 0.0, zero, jnp.where(p >= 1.0, n, k))


def _age_queue(S):
    """One tick of queue aging: every bucket gets one tick older.  In
    prefix form that is a left shift — the falling-off oldest bucket is
    empty by construction (the drop step subtracted its prefix last
    tick, zeroing column 0 exactly), so every prefix already excludes
    it and the total (last column, duplicated) is preserved."""
    return jnp.concatenate([S[:, 1:], S[:, -1:]], axis=1)


def _serve(S, capacity, n_late):
    """Oldest-first serve from a prefix queue.  ``n_late[a]`` counts how
    many of the oldest buckets are past arch ``a``'s slack (lateness is
    always an age-contiguous prefix, so one gather scores it).
    Returns ``(S, served, late)``."""
    served = jnp.minimum(S[:, -1], capacity)
    late = jnp.minimum(_late_mass(S, n_late), capacity)
    S = jnp.maximum(S - capacity[:, None], 0.0)
    return S, served, late


def _late_mass(S, n_late):
    """Mass in the ``n_late[a]`` oldest buckets of a prefix queue (also
    the end-of-trace expired sweep)."""
    idx = jnp.clip(n_late - 1, 0, S.shape[1] - 1)
    picked = jnp.take_along_axis(S, idx[:, None], axis=1)[:, 0]
    return jnp.where(n_late > 0, picked, 0.0)


class _Pipe(NamedTuple):
    """A tier's cumulative-counter pipeline ring (module docstring)."""

    ring: Any   # [A, L] clipped cumulative grants by launch slot (i32)
    cum: Any    # [A]    cumulative granted, post-cancel (i32)
    mat: Any    # [A]    cumulative matured (i32)


class _LazyPipe(NamedTuple):
    """A pipeline ring with *lazy* cancel clips.

    The eager :class:`_Pipe` keeps every slot ``<= cum`` by running a
    full ``min(ring, cum)`` pass on every cancel-capable tick — an
    O(A*L) read+write that dominates the whole scan at fleet scale
    (the remote ring alone is 300 columns).  This variant stores the
    raw slot writes and reconstructs the clip at read time: the value
    a read needs is ``min(G_s, min of every cum the tier passed
    through between write and read)`` — a sliding-window minimum over
    the cum event stream with window L, maintained with the standard
    two-block decomposition:

    * ``ehist [A, L]``: each tick's event minimum (entry cum ∧ exit
      cum), written at its slot — O(A) per tick;
    * ``bmin [A]``: running minimum of the current block's events,
      reset when the slot wraps to 0;
    * ``sufmin [A, L]``: suffix minima of the *previous* block's
      events, recomputed once per L ticks (a ``lax.cond`` whose branch
      runs O(A*L·logL) — amortized O(A·logL) per tick).

    At a read of slot ``p`` the window ``(t-L, t)`` splits exactly into
    the previous block's suffix from ``p+1`` plus the current block —
    ``min(sufmin[p+1], bmin)`` — so the read is O(A) and the per-tick
    ring cost collapses to two single-slot writes.  Counters are
    integers, so the lazy and eager forms are bit-identical; the lazy
    form is only wired into non-batched runners (under ``vmap`` the
    block-boundary ``cond`` would decay to ``select`` and pay the
    suffix recompute every tick)."""

    ring: Any     # [A, L] RAW cumulative grants by launch slot (i32)
    cum: Any      # [A]    cumulative granted, post-cancel (i32)
    mat: Any      # [A]    cumulative matured (i32)
    ehist: Any    # [A, L] per-tick event minima by slot (i32)
    sufmin: Any   # [A, L] previous block's suffix minima (i32)
    bmin: Any     # [A]    current block's running minimum (i32)


_I32_MAX = np.int32(np.iinfo(np.int32).max)


def _pipe_cancel(p, counts):
    """Cancel up to ``counts[a]`` in-flight launches, newest first.

    Eagerly: clipping the cumulative curve from the top eats the most
    recent cohorts first; every stored slot is ``<= cum``, so on
    cancel-free rows the clip is a numeric no-op — the op runs
    unconditionally.  Lazily: the cum drop alone records the cancel;
    reads recover the clip from the window minimum."""
    cancel = jnp.minimum(counts, p.cum - p.mat).astype(p.cum.dtype)
    cum = p.cum - cancel
    if isinstance(p, _LazyPipe):
        return p._replace(cum=cum)
    return _Pipe(jnp.minimum(p.ring, cum[:, None]), cum, p.mat)


def _tier_set_target(active, p, target, slot):
    """One tier tick on a pipeline ring: admit the cohort maturing at
    this tick's slot, then grow or shrink toward ``target`` (cancel
    in-flight newest-first before releasing active) —
    ``ResourceTier.set_target`` branchless and O(A) except the cancel
    clip.  ``slot`` is ``t mod L`` (a traced scalar): the slot written
    L ticks ago (or the initial 0) is exactly the cohort maturing now,
    and the write at the end stores this tick's cumulative total for
    tick ``t + L``."""
    if isinstance(p, _LazyPipe):
        return _tier_set_target_lazy(active, p, target, slot)
    v = lax.dynamic_slice_in_dim(p.ring, slot, 1, axis=1)[:, 0]
    ready = (v - p.mat).astype(active.dtype)
    active = active + ready
    pending = (p.cum - v).astype(active.dtype)
    in_flight = active + pending
    grow = jnp.maximum(target - in_flight, 0)
    shrink = in_flight - target
    cancel = jnp.where(shrink > 0, jnp.minimum(pending, shrink), 0)
    cum = p.cum + grow.astype(p.cum.dtype) - cancel.astype(p.cum.dtype)
    ring = jnp.minimum(p.ring, cum[:, None])
    ring = lax.dynamic_update_slice_in_dim(ring, cum[:, None], slot, axis=1)
    active = jnp.where(
        shrink > 0, jnp.minimum(active, jnp.maximum(target, 0)), active
    )
    return active, _Pipe(ring, cum, v)


def _tier_set_target_lazy(active, p: _LazyPipe, target, slot):
    """:func:`_tier_set_target` against a :class:`_LazyPipe` — same
    integer results, O(A) per tick (see the class docstring)."""
    L = p.ring.shape[1]
    # block boundary: the just-completed block becomes "previous" —
    # recompute its suffix minima, reset the running block min
    sufmin, bmin = lax.cond(
        slot == 0,
        lambda: (
            lax.associative_scan(jnp.minimum, p.ehist, reverse=True, axis=1),
            jnp.full_like(p.bmin, _I32_MAX),
        ),
        lambda: (p.sufmin, p.bmin),
    )
    nxt = jnp.minimum(slot + 1, L - 1)
    suf = lax.dynamic_slice_in_dim(sufmin, nxt, 1, axis=1)[:, 0]
    window = jnp.minimum(jnp.where(slot + 1 < L, suf, _I32_MAX), bmin)
    raw = lax.dynamic_slice_in_dim(p.ring, slot, 1, axis=1)[:, 0]
    v = jnp.minimum(jnp.minimum(raw, window), p.cum)
    ready = (v - p.mat).astype(active.dtype)
    active = active + ready
    pending = (p.cum - v).astype(active.dtype)
    in_flight = active + pending
    grow = jnp.maximum(target - in_flight, 0)
    shrink = in_flight - target
    cancel = jnp.where(shrink > 0, jnp.minimum(pending, shrink), 0)
    entry_cum = p.cum
    cum = entry_cum + grow.astype(entry_cum.dtype) - cancel.astype(
        entry_cum.dtype
    )
    ring = lax.dynamic_update_slice_in_dim(p.ring, cum[:, None], slot, axis=1)
    # this tick's event minimum: the lowest cum any later read's window
    # must see from this tick (entry covers the begin-tick cancel)
    e = jnp.minimum(entry_cum, cum)
    ehist = lax.dynamic_update_slice_in_dim(p.ehist, e[:, None], slot, axis=1)
    bmin = jnp.minimum(bmin, e)
    active = jnp.where(
        shrink > 0, jnp.minimum(active, jnp.maximum(target, 0)), active
    )
    return active, _LazyPipe(ring, cum, v, ehist, sufmin, bmin)


def _spot_begin(active, p: _Pipe, u, p_reclaim):
    """``SpotTier.begin_tick``: i.i.d. reclaims on active instances and
    in-flight launches (cancelled newest-first), from this tick's
    precomputed uniform pair."""
    reclaimed = binomial_from_uniform_jnp(active, p_reclaim, u[0])
    active = active - reclaimed
    lost = binomial_from_uniform_jnp(p.cum - p.mat, p_reclaim, u[1])
    p = _pipe_cancel(p, lost)
    return active, p, reclaimed.sum() + lost.sum()


def _harvest_begin(active, p: _Pipe, ceiling):
    """``HarvestVMTier.begin_tick``: evict active above the granted
    ceiling (correlated across the pool), cancel in-flight overflow
    newest-first."""
    evicted = jnp.maximum(active - ceiling, 0)
    active = active - evicted
    over = jnp.maximum(active + (p.cum - p.mat) - ceiling, 0)
    p = _pipe_cancel(p, over)
    return active, p, evicted.sum()


def _offload(S, mask, last_used, t, slo_s, st):
    """``BurstTier.offload`` of one class's drained queues: drain the
    masked archs, zero sub-epsilon cumsum residue in the offload counts
    (the queue rows are emptied regardless), score first-invocation
    cold starts, bill per request."""
    counts = S[:, -1] * mask
    counts = jnp.where(counts <= 1e-9, 0.0, counts)
    S = S * (~mask)[:, None]
    cold = (t - last_used) > st["idle_timeout"]
    lat_first = st["spinup"] + st["lat_b1"] + cold * st["cold_start"]
    lat_warm = st["spinup"] + st["lat_b1"]
    first = jnp.minimum(counts, 1.0)
    viol = first * (lat_first > slo_s) + (counts - first) * (lat_warm > slo_s)
    cost_arch = st["burst_cpr"] * counts
    last_used = jnp.where(counts > 0, t.astype(last_used.dtype), last_used)
    return S, counts, viol, cost_arch, last_used


# ---------------------------------------------------------------------------
# In-scan policies: twins of the vectorized schedulers.  Each maps
# ``(params, obs, key) -> (action dict, extras dict)`` where obs is a
# dict of [A] arrays (the traced PoolObs) and the action dict carries
# ``target / offload / spot / harvest / remote`` integer arrays.
# ---------------------------------------------------------------------------
_OFFLOAD_SLACK_AWARE = 2


def _scale_target(throughput, demand, headroom=1.0):
    return jnp.maximum(1, jnp.ceil(demand * headroom / throughput)).astype(
        jnp.int64
    )


def _no_action(like):
    return jnp.zeros_like(like)


def _pol_reactive(params, obs, key):
    tgt = _scale_target(obs["throughput"], obs["ewma_rate"])
    z = _no_action(tgt)
    return dict(target=tgt, offload=z, spot=z, harvest=z, remote=z), {}


def _pol_paragon(params, obs, key):
    bursty = obs["peak_to_median"] >= params["bursty_threshold"]
    headroom = jnp.where(bursty, 1.0, params["flat_cushion"])
    demand = obs["ewma_rate"] + obs["queue_len"] / params["drain_horizon_s"]
    tgt = _scale_target(obs["throughput"], demand, headroom)
    z = _no_action(tgt)
    off = jnp.full_like(tgt, _OFFLOAD_SLACK_AWARE)
    return dict(target=tgt, offload=off, spot=z, harvest=z, remote=z), {}


def _pol_portfolio(params, obs, key):
    thr = obs["throughput"]
    demand = obs["ewma_rate"] + obs["queue_len"] / params["drain_horizon_s"]
    floor = _scale_target(thr, demand, params["strict_share"])
    remote = (
        params["remote_frac"] * (1 - params["strict_share"])
        * obs["ewma_rate"] / thr
    ).astype(jnp.int64)
    residual = jnp.maximum(0.0, demand - (floor + remote) * thr)
    h_frac = jnp.minimum(
        jnp.maximum(obs["harvest_level"] - params["harvest_margin"], 0.0),
        params["harvest_max_frac"],
    )
    h_want = jnp.ceil(residual * h_frac * params["harvest_buffer"] / thr)
    harvest = jnp.minimum(h_want, obs["harvest_ceiling"]).astype(jnp.int64)
    spot_resid = jnp.maximum(0.0, residual - harvest * thr)
    spot = jnp.ceil(spot_resid * params["spot_buffer"] / thr).astype(jnp.int64)
    off = jnp.full_like(floor, _OFFLOAD_SLACK_AWARE)
    return dict(
        target=floor, offload=off, spot=spot, harvest=harvest, remote=remote
    ), {}


def _net_forward(net, feats):
    """The PPO net's forward pass (policy head via the shared
    :func:`policy_logits` expression, value head alongside)."""
    h = jnp.tanh(feats @ net["torso1"]["w"] + net["torso1"]["b"])
    h = jnp.tanh(h @ net["torso2"]["w"] + net["torso2"]["b"])
    logits = h @ net["pi"]["w"] + net["pi"]["b"]
    value = (h @ net["v"]["w"] + net["v"]["b"])[..., 0]
    return logits, value


def _rl_action(params, obs, actions):
    target, offload, spot, vmove = procurement_targets_arrays(
        actions,
        ewma_rate=obs["ewma_rate"],
        queue_strict=obs["queue_strict"],
        queue_relaxed=obs["queue_relaxed"],
        throughput=obs["throughput"],
        n_spot=obs["n_spot"],
        n_spot_pending=obs["n_spot_pending"],
        xp=jnp,
    )
    z = _no_action(target)
    # the 3-way variant head, decoded exactly like the host env path
    # (procurement_action): on catalog-free runs _tick never reads the
    # "variant" entry and every step clips to the hold code anyway
    variant = variant_targets_arrays(
        obs["active_variant"], obs["n_variants"], vmove, xp=jnp
    )
    return dict(target=target, offload=offload, spot=spot, harvest=z,
                remote=z, variant=variant)


def _pol_rl_greedy(params, obs, key):
    """``RLPoolPolicy(greedy=True)`` inside the scan: deterministic
    argmax over the checkpoint net's logits (the parity-testable form —
    the stochastic form needs a key stream and lives in the rollout
    collector's ``rl_sample``)."""
    feats = pool_features_arrays(
        obs, obs["prev_rate"],
        rate_scale=params["rate_scale"], fleet_scale=params["fleet_scale"],
        xp=jnp,
    )
    logits = policy_logits(params["net"], feats, xp=jnp)
    actions = jnp.argmax(logits, axis=-1)
    return _rl_action(params, obs, actions), {}


def _pol_rl_sample(params, obs, key):
    """Stochastic PPO policy with rollout extras — what
    ``collect_rollouts_jax`` scans: sampled actions, logp, value and the
    feature matrix come back per tick, exactly the buffers the host
    rollout loop fills."""
    feats = pool_features_arrays(
        obs, obs["prev_rate"],
        rate_scale=params["rate_scale"], fleet_scale=params["fleet_scale"],
        xp=jnp,
    )
    logits, value = _net_forward(params["net"], feats)
    actions = jax.random.categorical(key, logits)
    logp = jnp.take_along_axis(
        jax.nn.log_softmax(logits), actions[:, None], axis=1
    )[:, 0]
    extras = {"obs": feats, "action": actions, "logp": logp, "value": value}
    return _rl_action(params, obs, actions), extras


def _pol_infaas_variant(params, obs, key):
    """In-scan twin of ``VectorInfaasVariantPolicy``: Paragon offload +
    swap-aware sizing + the INFaaS up/down move, all through the shared
    ``*_arrays`` expressions (``core/schedulers.py``) so the dict,
    vector and scan forms cannot drift.  The per-arch cooldown state
    (``_last_move``) rides in the scan carry (``SimState.var_last_move``)
    and comes back through the action dict."""
    tgt = swap_aware_target_arrays(
        obs, bursty_threshold=params["bursty_threshold"],
        flat_cushion=params["flat_cushion"],
        drain_horizon_s=params["drain_horizon_s"], xp=jnp,
    )
    variant, last_move = infaas_variant_move_arrays(
        obs, obs["tick"], obs["variant_last_move"],
        up_util=params["up_util"], down_util=params["down_util"],
        post_swap_util=params["post_swap_util"],
        queue_pressure_s=params["queue_pressure_s"],
        cooldown_s=params["cooldown_s"], xp=jnp,
    )
    z = _no_action(tgt)
    off = jnp.full_like(tgt, _OFFLOAD_SLACK_AWARE)
    return dict(target=tgt, offload=off, spot=z, harvest=z, remote=z,
                variant=variant, variant_last_move=last_move), {}


def _pol_accuracy_floor(params, obs, key):
    """In-scan twin of ``VectorAccuracyFloorPolicy``: swap-aware sizing
    + move to the cheapest floor-satisfying variant."""
    tgt = swap_aware_target_arrays(
        obs, bursty_threshold=params["bursty_threshold"],
        flat_cushion=params["flat_cushion"],
        drain_horizon_s=params["drain_horizon_s"], xp=jnp,
    )
    z = _no_action(tgt)
    off = jnp.full_like(tgt, _OFFLOAD_SLACK_AWARE)
    return dict(target=tgt, offload=off, spot=z, harvest=z, remote=z,
                variant=accuracy_floor_move_arrays(obs, xp=jnp)), {}


class JaxPolicy(NamedTuple):
    apply: Callable            # (params, obs, key) -> (actions, extras)
    needs_stats: bool          # True: policy reads peak_to_median
    needs_key: bool            # True: per-tick PRNG keys enter the scan
    default_params: Callable   # () -> params pytree


def _rl_default_params() -> dict:
    params, meta = load_policy_checkpoint()
    if params is None:
        params = _fallback_params(0)
    return {
        "net": params,
        "rate_scale": float(meta.get("rate_scale", 100.0)),
        "fleet_scale": float(meta.get("fleet_scale", 10.0)),
    }


#: in-scan twins of the vectorized schedulers, by registry name
JAX_POLICIES: Dict[str, JaxPolicy] = {
    "reactive": JaxPolicy(_pol_reactive, False, False, lambda: {}),
    "paragon": JaxPolicy(
        _pol_paragon, True, False,
        lambda: dict(bursty_threshold=1.5, flat_cushion=1.1,
                     drain_horizon_s=5.0),
    ),
    "portfolio": JaxPolicy(
        _pol_portfolio, False, False,
        lambda: dict(drain_horizon_s=5.0, strict_share=0.25, remote_frac=0.3,
                     harvest_margin=0.15, harvest_max_frac=0.8,
                     harvest_buffer=1.1, spot_buffer=1.25),
    ),
    "rl_pool": JaxPolicy(_pol_rl_greedy, True, False, _rl_default_params),
    "rl_sample": JaxPolicy(_pol_rl_sample, True, True, _rl_default_params),
    "infaas_variant": JaxPolicy(
        _pol_infaas_variant, True, False,
        lambda: dict(bursty_threshold=1.5, flat_cushion=1.1,
                     drain_horizon_s=5.0, up_util=0.55, down_util=0.9,
                     post_swap_util=0.75, queue_pressure_s=2.0,
                     cooldown_s=120),
    ),
    "accuracy_floor": JaxPolicy(
        _pol_accuracy_floor, True, False,
        lambda: dict(bursty_threshold=1.5, flat_cushion=1.1,
                     drain_horizon_s=5.0),
    ),
}


# ---------------------------------------------------------------------------
# The tick function.
# ---------------------------------------------------------------------------
#: the monitor's smoothing constant, hoisted once (a Python float is a
#: trace-time constant — no statics traffic)
_EWMA_ALPHA = float(LoadMonitor.ewma_alpha)


def _pipe_of(state: SimState, pre: str, lazy: bool):
    """A tier's pipeline view over the flat state, eager or lazy."""
    ring = getattr(state, pre + "_ring")
    cum = getattr(state, pre + "_cum")
    mat = getattr(state, pre + "_mat")
    if lazy:
        return _LazyPipe(
            ring, cum, mat,
            getattr(state, pre + "_ehist"),
            getattr(state, pre + "_sufmin"),
            getattr(state, pre + "_bmin"),
        )
    return _Pipe(ring, cum, mat)


def _gather_v(table, idx):
    """Row-wise gather from a padded ``[A, V]`` catalog table at ``[A]``
    indices (the scan form of ``np.take_along_axis(table, idx[:, None],
    1)[:, 0]``)."""
    return jnp.take_along_axis(table, idx[:, None], axis=1)[:, 0]


def _tick(state: SimState, xs: dict, st: dict, policy_apply,
          ewma_in_carry: bool = False, lazy_rings: bool = False,
          variants: bool = False):
    """One engine tick, pure: ``(state, inputs) -> (state, metrics)``.

    Mirrors ``ServingSim.observe_pool`` + ``_step`` operation for
    operation; see the module docstring for why the branchless form is
    exact.  With ``ewma_in_carry`` the monitor's EWMA recurrence runs
    inside the scan (same float64 expression, same operation order as
    :func:`_ewma_trajectory` — bit-identical) instead of arriving as a
    host-precomputed ``[T, A]`` input.

    ``variants`` is a trace-time switch for the model-variant axis: when
    False (catalog-free) none of the swap machinery is traced, so the
    compiled graph is IDENTICAL to the variant-blind engine's — base
    runs stay bit-for-bit what they were.  When True the tick follows
    the NumPy ordering exactly: the observation gathers at the PRE-pop
    active variant, due swaps land before serving (the arch serves this
    tick at the NEW rate), new requests enter the depth-1 pipeline after
    the pop, and serving / burst billing / accuracy / chip accounting
    all gather at the POST-pop variant."""
    t = xs["t"]
    rate = xs["rate"]
    A = rate.shape[0]
    if ewma_in_carry:
        # first observe seeds the EWMA with the raw rates (seen == 0)
        ewma = jnp.where(
            t == 0, rate,
            _EWMA_ALPHA * rate + (1.0 - _EWMA_ALPHA) * state.ewma,
        )
    else:
        ewma = xs["ewma"]

    # ---- admit (observe_pool): age the queues, push this tick (new
    # arrivals land in the newest bucket: only the total prefix) -------
    qs_buf = _age_queue(state.qs_buf)
    qr_buf = _age_queue(state.qr_buf)
    n_strict = rate * st["strict_frac"]
    n_relaxed = rate - n_strict
    qs_buf = qs_buf.at[:, -1].add(n_strict)
    qr_buf = qr_buf.at[:, -1].add(n_relaxed)
    qs_tot = qs_buf[:, -1]
    qr_tot = qr_buf[:, -1]

    # ---- variant observation (pre-pop, like the NumPy observe_pool:
    # due swaps have NOT landed yet, so ratios and throughput gather at
    # the carried active variant; catalog-free every entry aliases a
    # read-only static and no gather is traced) ------------------------
    if variants:
        v_cur = state.var_cur
        v_pend = state.var_pending
        smult_cur = _gather_v(st["var_smult"], v_cur)
        v_up = jnp.minimum(v_cur + 1, st["var_n"] - 1)
        v_dn = jnp.maximum(v_cur - 1, 0)
        vobs = {
            "throughput": st["thr"] * smult_cur,
            "active_variant": v_cur,
            "n_variants": st["var_n"],
            "accuracy": _gather_v(st["var_acc"], v_cur),
            "accuracy_floor": st["acc_floor"],
            "variant_lo": st["var_lo"],
            "variant_cheapest": st["var_cheapest"],
            "variant_in_flight": v_pend >= 0,
            "variant_up_ratio": _gather_v(st["var_smult"], v_up) / smult_cur,
            "variant_down_ratio": _gather_v(st["var_smult"], v_dn) / smult_cur,
            "variant_pending_ratio": jnp.where(
                v_pend >= 0,
                _gather_v(st["var_smult"], jnp.maximum(v_pend, 0)) / smult_cur,
                1.0,
            ),
            "variant_last_move": state.var_last_move,
        }
    else:
        vobs = {
            "throughput": st["thr"],
            "active_variant": st["zeros_i"],
            "n_variants": st["ones_i"],
            "accuracy": st["cur_acc"],
            "accuracy_floor": st["acc_floor"],
            "variant_lo": st["zeros_i"],
            "variant_cheapest": st["zeros_i"],
            "variant_in_flight": st["false_b"],
            "variant_up_ratio": st["ones_f"],
            "variant_down_ratio": st["ones_f"],
            "variant_pending_ratio": st["ones_f"],
            "variant_last_move": st["neg_i"],
        }

    # ---- observe: the traced PoolObs (pre-provision state, like the
    # NumPy observe_pool; idle-tier fields equal the static zeros the
    # NumPy engine serves because a dead tier's state IS zero) ---------
    obs = {
        "rate": rate,
        "ewma_rate": ewma,
        "peak_to_median": xs["p2m"],
        "queue_len": qs_tot + qr_tot,
        "queue_strict": qs_tot,
        "queue_relaxed": qr_tot,
        "n_active": state.res_active,
        "n_pending": (state.res_cum - state.res_mat).astype(jnp.int64),
        "n_spot": state.spot_active,
        "n_spot_pending": (state.spot_cum - state.spot_mat).astype(jnp.int64),
        "n_harvest": state.harv_active,
        "n_harvest_pending": (state.harv_cum - state.harv_mat).astype(jnp.int64),
        "n_remote": state.rem_active,
        "n_remote_pending": (state.rem_cum - state.rem_mat).astype(jnp.int64),
        "utilization": state.last_util,
        "last_violations": state.last_viol,
        "harvest_level": jnp.broadcast_to(xs["h_lev_obs"], (A,)),
        "harvest_ceiling": jnp.broadcast_to(xs["h_ceil_obs"], (A,)),
        "spot_reclaim_risk": st["risk"],
        "tick": t,
        "prev_rate": state.prev_rate,
        **vobs,
    }
    acts, extras = policy_apply(st["policy"], obs, xs.get("key"))

    # ---- variant swaps (ServingSim._step order): pop matured swaps
    # BEFORE provisioning/serving — the arch serves this tick at the new
    # rate — then enqueue this tick's requests into the depth-1 slot
    # (cancel-newest = one overwrite, exactly SwapPipeline.request) ----
    if variants:
        done = (v_pend >= 0) & (state.var_ready <= t)
        v_cur = jnp.where(done, v_pend, v_cur)
        v_pend = jnp.where(done, -1, v_pend)
        swaps = done.sum()
        # POST-pop effective serving state (what _refresh_variant_state
        # caches on the NumPy engine): serve, bill burst invocations and
        # account chips at the NEW variant from this tick on
        cur_acc = _gather_v(st["var_acc"], v_cur)
        thr = st["thr"] * _gather_v(st["var_smult"], v_cur)
        chips = st["chips"] * _gather_v(st["var_cmult"], v_cur)
        st_off = dict(
            st,
            lat_b1=st["lat_b1"] * _gather_v(st["var_lmult"], v_cur),
            burst_cpr=(chips / thr) * st["burst_chip_s"] + st["inv_fee"],
        )
        # request: re-targeting the current variant cancels the in-flight
        # swap; re-requesting the in-flight target leaves its clock
        # alone; anything else (re)starts the slot
        req = jnp.minimum(acts.get("variant", st["neg_i"]), st["var_n"] - 1)
        cancel = (req >= 0) & (req == v_cur)
        v_pend = jnp.where(cancel, -1, v_pend)
        start = (req >= 0) & (req != v_cur) & (req != v_pend)
        v_pend = jnp.where(start, req, v_pend)
        v_ready = jnp.where(start, t + st["swap_lat"], state.var_ready)
        v_last_move = acts.get("variant_last_move", state.var_last_move)
    else:
        thr = st["thr"]
        chips = st["chips"]
        cur_acc = st["cur_acc"]
        st_off = st

    # ---- provision (reserved, then aux in registration order).  Each
    # tier's ring slot for this tick is t mod L (L static per tier) ----
    res_active, res_pipe = _tier_set_target(
        state.res_active,
        _pipe_of(state, "res", lazy_rings),
        acts["target"], t % state.res_ring.shape[1],
    )
    spot_active, spot_pipe, reclaimed = _spot_begin(
        state.spot_active,
        _pipe_of(state, "spot", lazy_rings),
        xs["spot_u"], st["p_reclaim"],
    )
    spot_active, spot_pipe = _tier_set_target(
        spot_active, spot_pipe, acts["spot"],
        t % state.spot_ring.shape[1],
    )
    harv_active, harv_pipe, evicted = _harvest_begin(
        state.harv_active,
        _pipe_of(state, "harv", lazy_rings),
        xs["h_ceil"],
    )
    harv_active, harv_pipe = _tier_set_target(
        harv_active, harv_pipe, jnp.minimum(acts["harvest"], xs["h_ceil"]),
        t % state.harv_ring.shape[1],
    )
    rem_active, rem_pipe = _tier_set_target(
        state.rem_active,
        _pipe_of(state, "rem", lazy_rings),
        acts["remote"], t % state.rem_ring.shape[1],
    )
    preempt = reclaimed + evicted

    # ---- serve: local capacity first (strict priority), then the
    # remote group against its egress-tightened lateness prefixes ------
    cap_local = (res_active + spot_active + harv_active) * thr
    qs_buf, served_s, late_s = _serve(qs_buf, cap_local, st["late_s"])
    rem_cap = rem_active * thr
    qs_buf, srs, lrs = _serve(qs_buf, rem_cap, st["rlate_s"])
    qr_buf, served_r, late_r = _serve(
        qr_buf, cap_local - served_s, st["late_r"]
    )
    qr_buf, srr, lrr = _serve(qr_buf, rem_cap - srs, st["rlate_r"])
    served_s, late_s = served_s + srs, late_s + lrs
    served_r, late_r = served_r + srr, late_r + lrr
    served = served_s + served_r
    cap_total = cap_local + rem_cap
    util = jnp.where(
        cap_total > 0,
        served / jnp.where(cap_total > 0, cap_total, 1.0),
        1.0,
    )
    viol_arch = late_s + late_r
    viol_strict = late_s.sum()

    # ---- offload to burst (strict: any offload mode; relaxed: blind
    # only), sequential so the relaxed batch sees a warmed pool --------
    offload = acts["offload"]
    qs_buf, counts_s, bviol_s, bcost_s, last_used = _offload(
        qs_buf, offload >= 1, state.burst_last_used, t, st["slo_strict"],
        st_off,
    )
    qr_buf, counts_r, bviol_r, bcost_r, last_used = _offload(
        qr_buf, offload == 1, last_used, t, st["slo_relaxed"], st_off,
    )
    viol_arch = viol_arch + bviol_s + bviol_r
    viol_strict = viol_strict + bviol_s.sum()

    # ---- drop the bucket that aged past the abandon window (the
    # oldest; subtracting its prefix zeroes column 0 exactly) ----------
    dropped_s = qs_buf[:, 0]
    qs_buf = jnp.maximum(qs_buf - dropped_s[:, None], 0.0)
    dropped_r = qr_buf[:, 0]
    qr_buf = jnp.maximum(qr_buf - dropped_r[:, None], 0.0)
    dropped = dropped_s + dropped_r
    viol_arch = viol_arch + dropped
    viol_strict = viol_strict + dropped_s.sum()

    # ---- delivered accuracy ------------------------------------------
    answered = served + counts_s + counts_r + dropped
    acc_w = answered * cur_acc
    acc_viol = answered * (cur_acc < st["acc_floor"] - 1e-12)

    # ---- account ------------------------------------------------------
    ch_res = res_active * chips
    ch_spot = spot_active * chips
    ch_harv = harv_active * chips
    ch_rem = rem_active * chips
    cost_arch = (
        bcost_s + bcost_r
        + ch_res * st["p_res"] + ch_spot * st["p_spot"]
        + ch_harv * st["p_harv"] + ch_rem * st["p_rem"]
    )
    chip_all = ch_res + ch_spot + ch_harv + ch_rem
    need = jnp.ceil(rate / thr) * chips

    # summary key presence: a tier posts (even $0) only on live ticks
    harv_live = (
        harv_active.sum() + (harv_pipe.cum - harv_pipe.mat).sum()
    ) > 0
    rem_live = (
        rem_active.sum() + (rem_pipe.cum - rem_pipe.mat).sum()
    ) > 0

    lazy_kw = {}
    if lazy_rings:
        for pre, pipe in (("res", res_pipe), ("spot", spot_pipe),
                          ("harv", harv_pipe), ("rem", rem_pipe)):
            lazy_kw[pre + "_ehist"] = pipe.ehist
            lazy_kw[pre + "_sufmin"] = pipe.sufmin
            lazy_kw[pre + "_bmin"] = pipe.bmin
    var_ys = {}
    if variants:
        lazy_kw.update(var_cur=v_cur, var_pending=v_pend,
                       var_ready=v_ready, var_last_move=v_last_move)
        var_ys = {
            # "swaps" is a flow (summed into the ledger); the rest are
            # per-tick gauges matching the NumPy recorder's end_tick
            # sampling points: active variant post-pop (swap.current),
            # in-flight post-request (swap.in_flight), delivered
            # accuracy at the serving variant (cur_acc)
            "swaps": swaps,
            "active_variant": v_cur,
            "swap_in_flight": v_pend >= 0,
            "acc_rate": cur_acc,
        }
    new_state = SimState(
        qs_buf=qs_buf, qr_buf=qr_buf,
        res_active=res_active,
        res_ring=res_pipe.ring, res_cum=res_pipe.cum, res_mat=res_pipe.mat,
        spot_active=spot_active,
        spot_ring=spot_pipe.ring, spot_cum=spot_pipe.cum,
        spot_mat=spot_pipe.mat,
        harv_active=harv_active,
        harv_ring=harv_pipe.ring, harv_cum=harv_pipe.cum,
        harv_mat=harv_pipe.mat,
        rem_active=rem_active,
        rem_ring=rem_pipe.ring, rem_cum=rem_pipe.cum, rem_mat=rem_pipe.mat,
        burst_last_used=last_used, last_util=util, last_viol=viol_arch,
        prev_rate=rate,
        ewma=ewma if ewma_in_carry else None,
        **lazy_kw,
    )
    ys = {
        "served": served,
        "burst": counts_s + counts_r,
        "dropped": dropped,
        "viol": viol_arch,
        "viol_strict": viol_strict,
        "acc_w": acc_w,
        "acc_viol": acc_viol,
        "cost_arch": cost_arch,
        "cost_res": ch_res.sum() * st["p_res"],
        "cost_spot": ch_spot.sum() * st["p_spot"],
        "cost_harv": ch_harv.sum() * st["p_harv"],
        "cost_rem": ch_rem.sum() * st["p_rem"],
        "cost_burst": bcost_s.sum() + bcost_r.sum(),
        "preempt": preempt,
        "chip": chip_all.sum(),
        "need": need.sum(),
        "over": jnp.maximum(chip_all - need, 0.0).sum(),
        "harv_live": harv_live,
        "rem_live": rem_live,
        # fleet / queue gauges for the telemetry trajectory (exact zeros
        # contribute nothing in "sum" mode; "stack" mode exposes the
        # per-tick series run_scenario(record_trajectory=True) returns)
        "n_res": res_active,
        "n_spot": spot_active,
        "n_harv": harv_active,
        "n_rem": rem_active,
        "queue_strict": qs_buf[:, -1],
        "queue_relaxed": qr_buf[:, -1],
        **var_ys,
        **extras,
    }
    return new_state, ys


# ---------------------------------------------------------------------------
# Host-side input builder.
# ---------------------------------------------------------------------------
def _ewma_trajectory(arrivals: np.ndarray, alpha: float) -> np.ndarray:
    """The monitor's EWMA alone (for policies that never read the
    order-statistic fields — skips the windowed median machinery)."""
    A, T = arrivals.shape
    out = np.empty((T, A), dtype=np.float64)
    e = arrivals[:, 0].astype(np.float64).copy()
    out[0] = e
    for t in range(1, T):
        e = alpha * arrivals[:, t] + (1 - alpha) * e
        out[t] = e
    return out


#: memoized harvest availability signals — pure functions of
#: ``(seed, T)``, shared across the cells of a grid
_HARV_CACHE: Dict[tuple, np.ndarray] = {}


def _harvest_traj(seed: int, ticks: int) -> np.ndarray:
    k = (seed, ticks)
    if k not in _HARV_CACHE:
        if len(_HARV_CACHE) > 256:
            _HARV_CACHE.clear()
        _HARV_CACHE[k] = harvest_level_trajectory(seed, ticks)
    return _HARV_CACHE[k]


def build_sim_inputs(
    arrivals: np.ndarray,
    workload: List[ArchLoad],
    *,
    pricing: FleetPricing = PRICING,
    catalog=None,
    seed: int = 0,
    prewarm: bool = True,
    warm_start: bool = True,
    needs_stats: bool = True,
    needs_key: bool = False,
    key=None,
    ewma: Optional[np.ndarray] = None,
    ewma_in_scan: Optional[bool] = None,
    stats: Optional[tuple] = None,
    lazy_rings: bool = True,
    _sim: Optional[ServingSim] = None,
):
    """Materialize ``(statics, state0, xs)`` for one scan — NumPy host
    arrays throughout (device transfer happens at the jit boundary).

    ``statics`` is the traced per-run constant pytree (slip the policy
    parameters in under ``statics["policy"]``); ``xs`` holds the
    per-tick inputs with leading time axis.  All derived quantities are
    read off a throwaway :class:`ServingSim` so the two engines share
    one construction path and cannot drift — ``_sim`` lets
    :func:`run_grid` amortize that construction over cells sharing a
    workload (every sim-derived quantity is arrival- and
    seed-independent except the warm-start fleet, recomputed here), and
    ``stats`` likewise injects precomputed ``(ewma, p2m)`` monitor
    trajectories for ``needs_stats`` policies (the grid batches the
    monitor across cells).

    On the non-stats path the EWMA recurrence runs *inside* the scan by
    default (``ewma_in_scan=None`` resolves to ``not needs_stats``):
    ``state0.ewma`` seeds the carry and no ``[T, A]`` smoothing input
    is materialized.  Pass ``ewma_in_scan=False`` for the legacy
    host-precomputed input (``ewma`` optionally injects it); the runner
    flavor must match (:func:`_get_runner` ``flavor``).
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    assert arrivals.ndim == 2, "the JAX engine needs an [A, T] matrix"
    A, T = arrivals.shape
    sim = _sim if _sim is not None else ServingSim(
        arrivals, workload, pricing=pricing, prewarm=prewarm,
        warm_start=warm_start, seed=seed, catalog=catalog,
    )
    variants = sim._variants_live

    if ewma_in_scan is None:
        ewma_in_scan = not needs_stats
    if needs_stats:
        assert not ewma_in_scan, "stats policies read the monitor stream"
        if stats is not None:
            ewma, p2m = stats
        else:
            ewma, _, p2m = pool_stats_trajectory(arrivals)
    else:
        if not ewma_in_scan and ewma is None:
            ewma = _ewma_trajectory(arrivals, LoadMonitor.ewma_alpha)
        # no policy on this path reads peak_to_median: a broadcastable
        # placeholder keeps it out of the grid's host->device traffic
        p2m = np.ones((T, 1), dtype=np.float64)

    cap = pricing.harvest_cap_per_arch
    lev = _harvest_traj(seed, T)
    h_lev_obs = np.concatenate([[1.0], lev[:-1]])   # level BEFORE the advance
    statics = {
        "strict_frac": sim.strict_frac.astype(np.float64),
        "thr": sim.eff_throughput,
        "chips": sim.eff_chips,
        "cur_acc": sim.cur_acc,
        "acc_floor": sim.acc_floor.astype(np.float64),
        # lateness as prefix lengths: how many of the oldest buckets
        # violate each arch's slack (masks are age-contiguous)
        "late_s": _n_late(sim.q_strict._late_mask),
        "late_r": _n_late(sim.q_relaxed._late_mask),
        "rlate_s": _n_late(sim._remote_late_strict),
        "rlate_r": _n_late(sim._remote_late_relaxed),
        # finalize prefixes: buffer age + 1 (the sweep runs at tick T)
        "fin_s": _n_late(_finalize_mask(sim.q_strict)),
        "fin_r": _n_late(_finalize_mask(sim.q_relaxed)),
        "lat_b1": sim.burst.lat_b1,
        "cold_start": sim.burst.cold_start_s,
        "burst_cpr": sim.burst.cost_per_request,
        "spinup": float(pricing.burst_spinup_s),
        "idle_timeout": float(pricing.burst_idle_timeout_s),
        "slo_strict": sim.q_strict.slo_s,
        "slo_relaxed": sim.q_relaxed.slo_s,
        "p_res": sim.reserved.price_per_chip_s(),
        "p_spot": sim.spot.price_per_chip_s(),
        "p_harv": sim.harvest.price_per_chip_s(),
        "p_rem": sim.remote.price_per_chip_s(),
        "p_reclaim": sim.spot.reclaim_probability(),
        "risk": np.full(A, sim.spot.reclaim_probability()),
        "zeros_i": np.zeros(A, dtype=np.int64),
        "ones_i": np.ones(A, dtype=np.int64),
        "false_b": np.zeros(A, dtype=bool),
        "ones_f": np.ones(A, dtype=np.float64),
        # the hold sentinel for variant requests / cooldown clocks (any
        # value far below tick 0 works; matches the vector schedulers)
        "neg_i": np.full(A, -(10 ** 9), dtype=np.int64),
        "policy": {},            # caller / run_scenario fills this in
    }
    if variants:
        # the scan gathers effective quantities per tick, so the serving
        # statics revert to BASE values and the padded catalog rides in
        statics.update(
            thr=sim.throughput,
            chips=sim.chips,
            lat_b1=sim.lat_b1,
            var_acc=sim.var_acc,
            var_smult=sim.var_smult,
            var_cmult=sim.var_cmult,
            var_lmult=sim.var_lmult,
            var_n=sim.var_n,
            var_lo=sim.var_lo,
            var_cheapest=sim.var_cheapest,
            swap_lat=np.int64(sim.swap.lat),
            burst_chip_s=float(pricing.burst_chip_s),
            inv_fee=float(pricing.burst_invocation_fee),
        )
    if warm_start:
        # the sim's own warm-start rule, recomputed so a reused _sim
        # still yields THIS cell's t=0 fleet
        res_active0 = np.maximum(
            1, np.ceil(arrivals[:, 0] / sim.eff_throughput)
        ).astype(np.int64)
    else:
        res_active0 = sim.reserved.active.copy()
    state0 = SimState(
        qs_buf=np.zeros((A, sim.q_strict.window), dtype=np.float64),
        qr_buf=np.zeros((A, sim.q_relaxed.window), dtype=np.float64),
        res_active=res_active0,
        res_ring=np.zeros((A, sim.reserved.pipeline.lat), dtype=np.int32),
        res_cum=np.zeros(A, dtype=np.int32),
        res_mat=np.zeros(A, dtype=np.int32),
        spot_active=np.zeros(A, dtype=np.int64),
        spot_ring=np.zeros((A, sim.spot.pipeline.lat), dtype=np.int32),
        spot_cum=np.zeros(A, dtype=np.int32),
        spot_mat=np.zeros(A, dtype=np.int32),
        harv_active=np.zeros(A, dtype=np.int64),
        harv_ring=np.zeros((A, sim.harvest.pipeline.lat), dtype=np.int32),
        harv_cum=np.zeros(A, dtype=np.int32),
        harv_mat=np.zeros(A, dtype=np.int32),
        rem_active=np.zeros(A, dtype=np.int64),
        rem_ring=np.zeros((A, sim.remote.pipeline.lat), dtype=np.int32),
        rem_cum=np.zeros(A, dtype=np.int32),
        rem_mat=np.zeros(A, dtype=np.int32),
        burst_last_used=sim.burst.last_used.copy(),
        last_util=np.zeros(A, dtype=np.float64),
        last_viol=np.zeros(A, dtype=np.float64),
        prev_rate=arrivals[:, 0].copy(),         # trend feature = 0 at t=0
        # the t=0 value is recomputed in-scan; this seeds dtype/shape
        ewma=arrivals[:, 0].copy() if ewma_in_scan else None,
        # variant axis: start at the base variant with an empty swap
        # slot and a cooldown clock that never blocks the first move
        **(
            dict(
                var_cur=sim.swap.current.astype(np.int64),
                var_pending=np.full(A, -1, dtype=np.int64),
                var_ready=np.zeros(A, dtype=np.int64),
                var_last_move=np.full(A, -(10 ** 9), dtype=np.int64),
            )
            if variants else {}
        ),
        # lazy-ring window-min state: "no events yet" is +inf everywhere
        **(
            {
                pre + suf: (
                    np.full(A, _I32_MAX, dtype=np.int32) if suf == "_bmin"
                    else np.full(
                        (A, getattr(sim, tier).pipeline.lat), _I32_MAX,
                        dtype=np.int32,
                    )
                )
                for pre, tier in (("res", "reserved"), ("spot", "spot"),
                                  ("harv", "harvest"), ("rem", "remote"))
                for suf in ("_ehist", "_sufmin", "_bmin")
            }
            if lazy_rings else {}
        ),
    )
    xs = {
        "t": np.arange(T, dtype=np.int64),
        "rate": np.ascontiguousarray(arrivals.T),
        "p2m": p2m,
        "spot_u": spot_reclaim_uniforms(seed, T, A),
        "h_ceil": (lev * cap).astype(np.int64),
        "h_lev_obs": h_lev_obs,
        "h_ceil_obs": (h_lev_obs * cap).astype(np.int64),
    }
    if not ewma_in_scan:
        xs["ewma"] = ewma
    if needs_key:
        if key is None:
            key = jax.random.PRNGKey(seed)
        xs["key"] = _split_keys(key, T)
    return statics, state0, xs


def _finalize_mask(q) -> np.ndarray:
    """Lateness mask for the end-of-trace sweep: the sweep runs one tick
    after the last shift, so every bucket is one tick older than its
    column says."""
    ages = np.arange(q.window - 1, -1, -1) + 1
    return ages[None, :] > q.slack[:, None]


def _n_late(mask: np.ndarray) -> np.ndarray:
    """An oldest-first lateness mask is always age-contiguous from
    bucket 0, so its per-arch count fully describes it — the gather
    index the prefix queues score lateness with."""
    n = mask.sum(axis=1).astype(np.int64)
    w = mask.shape[1]
    assert (mask == (np.arange(w)[None, :] < n[:, None])).all()
    return n


@jax.jit
def _split_chain(key, length):
    """The host rollout loop's split sequence (``key, k_t = split(key)``
    each tick) as ONE device scan — bit-identical keys, one dispatch
    instead of ``n`` host round-trips."""
    def f(k, _):
        k, kt = jax.random.split(k)
        return k, jax.random.key_data(kt)

    _, keys = lax.scan(f, key, length)
    return keys


def _split_keys(key, n: int) -> np.ndarray:
    return np.asarray(
        _split_chain(key, np.zeros(n, dtype=np.int8)), dtype=np.uint32
    )


# ---------------------------------------------------------------------------
# Runners: jitted scan (optionally vmapped), cached per policy so
# repeated calls of the same (A, T, policy) shape never re-trace.
# ---------------------------------------------------------------------------
_RUNNERS: Dict[tuple, Any] = {}


#: per-tick *level* series (fleet sizes, queue depths) exposed only by
#: the ``mode="stack"`` trajectory path — excluded from the in-graph
#: "sum" reduction, where their totals would be meaningless tick-seconds
GAUGE_KEYS = frozenset(
    ("n_res", "n_spot", "n_harv", "n_rem", "queue_strict", "queue_relaxed",
     "active_variant", "swap_in_flight", "acc_rate")
)

#: metric keys reduced by the in-carry accumulator ("sum" mode); the
#: per-tick liveness flags fold with logical-or instead of ``+``.
#: "swaps" only exists on variant-catalog runs — the accumulator keys
#: are filtered by presence in the tick's output shape
_SUM_KEYS = (
    "served", "burst", "dropped", "viol", "viol_strict", "acc_w",
    "acc_viol", "cost_arch", "cost_res", "cost_spot", "cost_harv",
    "cost_rem", "cost_burst", "preempt", "chip", "need", "over", "swaps",
)
_LIVE_KEYS = ("harv_live", "rem_live")

#: default chunked-scan unroll for the optimized runner flavor.  The
#: option exists (``make_runner(unroll=...)`` chunks the scan body so
#: XLA amortizes loop overhead), but on CPU unrolling forces the
#: single-slot ring writes to materialize full copies per unrolled
#: step — measured strictly slower at A>=256 — so the default stays 1
SCAN_UNROLL = 1


def make_runner(policy_apply, mode: str = "sum", *, unroll: int = 1,
                ewma_in_carry: bool = False, accumulate: bool = False,
                lazy_rings: bool = False, variants: bool = False):
    """Build ``run(statics, state0, xs) -> out`` around one policy.

    ``mode="sum"`` reduces the per-tick metrics (scenario evaluation);
    ``mode="stack"`` returns them per tick (rollout collection).
    ``accumulate`` (sum mode only) folds the totals into the scan carry
    as running sums instead of stacking ``[T, ...]`` outputs and
    reducing post-scan — at fleet scale the stacked form writes and
    re-reads hundreds of MB per run, the in-carry form touches only
    ``[A]`` accumulators.  ``unroll`` is passed through to ``lax.scan``
    (the chunked/unrolled option); ``ewma_in_carry`` moves the monitor
    EWMA into the scan (see :func:`_tick`).  Not jitted or cached — see
    :func:`_get_runner`.
    """
    assert not (accumulate and mode != "sum")

    def run(statics, state0, xs):
        if accumulate:
            x0 = jax.tree.map(lambda a: a[0], xs)
            ys_shape = jax.eval_shape(
                lambda s, x: _tick(s, x, statics, policy_apply,
                                   ewma_in_carry, lazy_rings, variants)[1],
                state0, x0,
            )
            acc0 = {
                k: jnp.zeros(ys_shape[k].shape, ys_shape[k].dtype)
                for k in _SUM_KEYS + _LIVE_KEYS if k in ys_shape
            }

            def f(carry, x):
                state, acc = carry
                state, ys = _tick(state, x, statics, policy_apply,
                                  ewma_in_carry, lazy_rings, variants)
                acc = {
                    k: (acc[k] | ys[k]) if k in _LIVE_KEYS
                    else acc[k] + ys[k]
                    for k in acc
                }
                return (state, acc), None

            (final, tot), _ = lax.scan(f, (state0, acc0), xs, unroll=unroll)
            return {
                "final": final,
                "expired_s": _late_mass(final.qs_buf, statics["fin_s"]),
                "expired_r": _late_mass(final.qr_buf, statics["fin_r"]),
                "totals": tot,
            }

        def f(carry, x):
            return _tick(carry, x, statics, policy_apply, ewma_in_carry,
                         lazy_rings, variants)

        final, ys = lax.scan(f, state0, xs, unroll=unroll)
        out = {
            "final": final,
            "expired_s": _late_mass(final.qs_buf, statics["fin_s"]),
            "expired_r": _late_mass(final.qr_buf, statics["fin_r"]),
        }
        if mode == "sum":
            # summing the telemetry gauges is meaningless (they are
            # levels, not flows) — dropping them here lets XLA dead-code
            # the per-tick stacking, keeping scenario evaluation at its
            # pre-telemetry throughput
            out["totals"] = jax.tree.map(
                lambda a: a.sum(axis=0),
                {k: v for k, v in ys.items() if k not in GAUGE_KEYS},
            )
        else:
            out["ys"] = ys
        return out

    return run


def _flavor_opts(policy: str, mode: str, flavor: str) -> dict:
    """Resolve a runner flavor to concrete :func:`make_runner` options.

    ``"opt"`` (default everywhere) carries the totals and — for
    policies that never read the order statistics — the EWMA in the
    scan carry, and unrolls the scan; ``"legacy"`` reproduces the
    pre-optimization construction (stacked per-tick outputs, host-fed
    EWMA, unroll=1, no donation) and exists so the throughput benchmark
    can A/B the two in one run on one machine."""
    if flavor == "legacy":
        return dict(unroll=1, ewma_in_carry=False, accumulate=False,
                    lazy_rings=False)
    assert flavor == "opt", flavor
    return dict(
        unroll=SCAN_UNROLL,
        ewma_in_carry=not JAX_POLICIES[policy].needs_stats,
        accumulate=(mode == "sum"),
        # under vmap the lazy rings' block-boundary cond decays to
        # select (both branches execute) — batched runners keep the
        # eager clip; _get_runner strips this flag for them
        lazy_rings=True,
    )


def _get_sharded_runner(policy: str, mesh, mode: str = "sum",
                        flavor: str = "opt", variants: bool = False):
    """The batched grid runner wrapped in ``shard_map``: the leading
    cell axis splits across ``mesh``'s devices (pure data parallelism —
    cells never communicate), statics stay replicated.  The logical
    "cells" axis maps onto the mesh axis through the standard
    :mod:`repro.distributed.sharding` rules so the spec derivation is
    the same one model code uses."""
    from jax.experimental.shard_map import shard_map

    from repro.distributed.sharding import AxisRules, logical_to_spec

    ndev = mesh.devices.size
    key = (policy, mode, "sharded", ndev, flavor, variants)
    if key not in _RUNNERS:
        opts = _flavor_opts(policy, mode, flavor)
        opts["lazy_rings"] = False          # vmapped inside shard_map
        base = make_runner(JAX_POLICIES[policy].apply, mode,
                           variants=variants, **opts)

        def grid(statics, policy_params, state0, xs):
            return base({**statics, "policy": policy_params}, state0, xs)

        inner = jax.vmap(grid, in_axes=(None, 0, 0, 0))
        rules = AxisRules(mesh, {"cells": mesh.axis_names[0]})
        cell = logical_to_spec(("cells",), rules)
        rep = logical_to_spec((), rules)
        # check_rep=False: the binomial inverse-CDF lax.while_loop has no
        # shard_map replication rule; every input/output spec is explicit
        # here so the check adds nothing.
        fn = shard_map(
            inner, mesh=mesh,
            in_specs=(rep, cell, cell, cell), out_specs=cell,
            check_rep=False,
        )
        _RUNNERS[key] = jax.jit(fn)
    return _RUNNERS[key]


def _get_runner(policy: str, mode: str = "sum", batched: bool = False,
                flavor: str = "opt", variants: bool = False):
    key = (policy, mode, batched, flavor, variants)
    if key not in _RUNNERS:
        opts = _flavor_opts(policy, mode, flavor)
        if batched:
            opts["lazy_rings"] = False
        base = make_runner(JAX_POLICIES[policy].apply, mode,
                           variants=variants, **opts)
        if batched:
            # one statics pytree serves every cell (grid cells share a
            # workload); only policy params, state and per-tick inputs
            # carry the batch axis
            def grid(statics, policy_params, state0, xs):
                return base({**statics, "policy": policy_params}, state0, xs)

            fn = jax.vmap(grid, in_axes=(None, 0, 0, 0))
            donate = (2,)
        else:
            fn = base
            donate = (1,)
        if flavor == "opt":
            # donate the scan carry's initial state — jit converts the
            # host state0 to a fresh device buffer per call, so XLA may
            # alias it into the carry without copying
            _RUNNERS[key] = jax.jit(fn, donate_argnums=donate)
        else:
            _RUNNERS[key] = jax.jit(fn)
    return _RUNNERS[key]


def runner_trace_count(policy: str, mode: str = "sum",
                       batched: bool = False, flavor: str = "opt",
                       variants: bool = False) -> int:
    """How many distinct shapes the cached runner has traced (the
    recompile guard: repeated same-shape runs must report 1)."""
    fn = _RUNNERS.get((policy, mode, batched, flavor, variants))
    return 0 if fn is None else fn._cache_size()


# trace counts last observed per runner key, and the keys already warned
# about — a runner retracing for a key we've seen is a silent recompile
# (a perf bug), surfaced once per key and counted in the telemetry
# counters (`repro_jax_runner_traces_total{...}` in the Prometheus dump)
_TRACE_SEEN: Dict[tuple, int] = {}
_TRACE_WARNED: set = set()


def note_runner_use(policy: str, mode: str = "sum",
                    batched: bool = False, flavor: str = "opt",
                    variants: bool = False) -> int:
    """Record a runner dispatch: export its trace count as a telemetry
    counter and warn (once per key) if it retraced for an already-seen
    ``(policy, mode, batched)`` key.  Returns the current trace count."""
    key = (policy, mode, batched, flavor, variants)
    n = runner_trace_count(policy, mode, batched, flavor, variants)
    telemetry.set_global_counter(
        f'jax_runner_traces_total{{policy="{policy}",mode="{mode}",'
        f'batched="{int(batched)}"}}', n)
    prev = _TRACE_SEEN.get(key)
    if prev is not None and n > prev and key not in _TRACE_WARNED:
        _TRACE_WARNED.add(key)
        warnings.warn(
            f"jax_engine runner retraced for already-seen key {key}: "
            f"{n} traces cached (was {prev}) — same-shape runs should "
            "hit the jit cache; check for dtype/shape drift in inputs",
            RuntimeWarning, stacklevel=3,
        )
    _TRACE_SEEN[key] = max(n, prev or 0)
    return n


# ---------------------------------------------------------------------------
# Result assembly (mirrors SimResult.summary / per_arch_counts).
# ---------------------------------------------------------------------------
def _assemble(out: dict, arrivals: np.ndarray) -> dict:
    tot = out["totals"]
    exp_s, exp_r = out["expired_s"], out["expired_r"]
    expired = exp_s + exp_r
    total_requests = float(arrivals.sum())
    viol_total = float(tot["viol"].sum() + expired.sum())
    viol_strict = float(tot["viol_strict"] + exp_s.sum())
    served_vm = float(tot["served"].sum() + tot["dropped"].sum())
    served_burst = float(tot["burst"].sum())
    answered = served_vm + served_burst
    cost_res = float(tot["cost_res"])
    cost_spot = float(tot["cost_spot"])
    cost_burst = float(tot["cost_burst"])
    cost_harv = float(tot["cost_harv"])
    cost_rem = float(tot["cost_rem"])
    chip = float(tot["chip"])
    need = float(tot["need"])
    over = float(tot["over"])

    summary = {
        "cost_total": round(
            cost_res + cost_spot + cost_burst + cost_harv + cost_rem, 4
        ),
        "cost_reserved": round(cost_res, 4),
        "cost_spot": round(cost_spot, 4),
        "cost_burst": round(cost_burst, 4),
    }
    # tier keys appear iff the tier was ever live (it posts $0 entries
    # on pipeline-only ticks) — same rule as the lazy NumPy accounting
    if bool(tot["harv_live"]):
        summary["cost_harvest"] = round(cost_harv, 4)
    if bool(tot["rem_live"]):
        summary["cost_remote"] = round(cost_rem, 4)
    summary.update({
        "preemptions": int(tot["preempt"]),
        "violation_rate": round(viol_total / max(total_requests, 1e-9), 5),
        "violations_strict": round(viol_strict, 1),
        "served_vm": round(served_vm, 1),
        "served_burst": round(served_burst, 1),
        "overprovision_ratio": round(over / max(need, 1e-9), 4),
        "chip_seconds": round(chip, 1),
    })
    if answered > 0:
        acc_w = float(tot["acc_w"].sum())
        summary["mean_accuracy"] = round(acc_w / max(answered, 1e-9), 5)
        summary["acc_violation_rate"] = round(
            float(tot["acc_viol"].sum()) / max(answered, 1e-9), 5
        )
        summary["variant_swaps"] = (
            int(tot["swaps"]) if "swaps" in tot else 0
        )

    final: SimState = out["final"]
    per_arch = {
        "arrived": arrivals.sum(axis=1),
        "served_vm": tot["served"],
        "served_burst": tot["burst"],
        "dropped": tot["dropped"],
        "expired_end": expired,
        "violations": tot["viol"] + expired,
        "queued": (final.qs_buf[:, -1] - exp_s) + (final.qr_buf[:, -1] - exp_r),
        "acc_weight": tot["acc_w"],
        "acc_violations": tot["acc_viol"],
    }
    return {"summary": summary, "per_arch": per_arch, "raw": out}


def _tree_to_host(out):
    return jax.tree.map(np.asarray, out)


def _tree_stack(trees):
    return jax.tree.map(lambda *leaves: np.stack(leaves), *trees)


def _tree_index(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


# ---------------------------------------------------------------------------
# Public entry points.
# ---------------------------------------------------------------------------
def run_scenario(
    arrivals: np.ndarray,
    workload: List[ArchLoad],
    policy: str = "portfolio",
    params: Optional[dict] = None,
    *,
    pricing: FleetPricing = PRICING,
    catalog=None,
    seed: int = 0,
    prewarm: bool = True,
    warm_start: bool = True,
    record_trajectory: bool = False,
) -> dict:
    """One scenario through the jitted scan; returns ``{"summary",
    "per_arch", "raw"}`` with the summary shaped exactly like
    ``SimResult.summary()`` from the NumPy engine.

    ``catalog`` switches on the model-variant axis: the scan carries the
    per-arch swap pipeline and gathers effective serving state from the
    padded catalog tables every tick (see :func:`_tick`); without one
    the compiled graph is the variant-blind engine's, unchanged.

    ``record_trajectory=True`` runs the ``mode="stack"`` runner instead
    and adds a ``"trajectory"`` entry: the per-tick ``[T, ...]`` series
    of every scan output (served / burst / violation flows, per-tier
    cost and fleet gauges, queue totals, and — on catalog runs — the
    variant gauges ``active_variant`` / ``swap_in_flight`` /
    ``acc_rate``) — the JAX-side counterpart of the NumPy engine's
    telemetry recorder."""
    pol = JAX_POLICIES[policy]
    statics, state0, xs = build_sim_inputs(
        arrivals, workload, pricing=pricing, catalog=catalog, seed=seed,
        prewarm=prewarm, warm_start=warm_start, needs_stats=pol.needs_stats,
        needs_key=pol.needs_key,
    )
    variants = "var_smult" in statics
    statics["policy"] = pol.default_params() if params is None else params
    mode = "stack" if record_trajectory else "sum"
    with enable_x64():
        out = _tree_to_host(
            _get_runner(policy, mode=mode, variants=variants)(
                statics, state0, xs
            )
        )
    note_runner_use(policy, mode, variants=variants)
    trajectory = None
    if record_trajectory:
        trajectory = out.pop("ys")
        # reduce the stacked series host-side so _assemble sees the same
        # shape the in-graph "sum" reduction produces
        out["totals"] = {k: v.sum(axis=0) for k, v in trajectory.items()}
    result = _assemble(out, np.asarray(arrivals, dtype=np.float64))
    if record_trajectory:
        result["trajectory"] = trajectory
    return result


def run_grid(
    arrivals_batch: np.ndarray,              # [B, A, T]
    workload: List[ArchLoad],
    policy: str = "portfolio",
    params_batch: Optional[List[dict]] = None,
    seeds: Optional[List[int]] = None,
    *,
    pricing: FleetPricing = PRICING,
    catalog=None,
    prewarm: bool = True,
    warm_start: bool = True,
    sharded: Optional[bool] = None,
) -> List[dict]:
    """A whole (scenario x seed x policy-params) grid in ONE vmapped
    dispatch: cell ``i`` runs ``arrivals_batch[i]`` under
    ``params_batch[i]`` with spot/harvest realizations from
    ``seeds[i]``.  Returns one :func:`run_scenario`-shaped dict per
    cell.

    With more than one device the cell axis is sharded across them via
    ``shard_map`` (``sharded=None`` auto-enables when the cell count
    divides evenly; ``True`` requires it, ``False`` forces the single
    dispatch).  Cells never communicate, so the sharded and unsharded
    paths compute identical cells."""
    from repro.distributed.sharding import device_mesh

    arrivals_batch = np.asarray(arrivals_batch, dtype=np.float64)
    B, A, T = arrivals_batch.shape
    pol = JAX_POLICIES[policy]
    seeds = list(seeds) if seeds is not None else [0] * B
    assert len(seeds) == B
    # one template sim serves the whole grid (cells share the
    # workload); per-cell monitor streams run as ONE batched recurrence
    # over the stacked [B*A, T] arrival matrix (rows are independent,
    # so the batched pass is bit-identical to B per-cell passes)
    sim = ServingSim(
        arrivals_batch[0], workload, pricing=pricing, prewarm=prewarm,
        warm_start=warm_start, seed=seeds[0], catalog=catalog,
    )
    variants = sim._variants_live
    if pol.needs_stats:
        ew, _, p2 = pool_stats_trajectory(arrivals_batch.reshape(B * A, T))
        stats = [
            (ew[:, i * A:(i + 1) * A], p2[:, i * A:(i + 1) * A])
            for i in range(B)
        ]
    else:
        stats = [None] * B       # EWMA runs in the scan carry
    cells = [
        build_sim_inputs(
            arrivals_batch[i], workload, pricing=pricing, seed=seeds[i],
            prewarm=prewarm, warm_start=warm_start,
            needs_stats=pol.needs_stats, needs_key=pol.needs_key,
            key=jax.random.PRNGKey(seeds[i]) if pol.needs_key else None,
            stats=stats[i], lazy_rings=False, _sim=sim,
        )
        for i in range(B)
    ]
    statics = cells[0][0]
    state0_b = _tree_stack([c[1] for c in cells])
    xs_b = _tree_stack([c[2] for c in cells])
    if params_batch is None:
        params_batch = [pol.default_params() for _ in range(B)]
    policy_b = _tree_stack(list(params_batch))
    mesh = device_mesh()
    use_shard = (
        mesh is not None and B % mesh.devices.size == 0
        if sharded is None else sharded
    )
    if use_shard:
        assert mesh is not None and B % mesh.devices.size == 0, (
            f"sharded run_grid needs the cell count ({B}) to divide the "
            f"device count ({1 if mesh is None else mesh.devices.size})"
        )
        runner = _get_sharded_runner(policy, mesh, variants=variants)
    else:
        runner = _get_runner(policy, batched=True, variants=variants)
    with enable_x64():
        out = _tree_to_host(runner(statics, policy_b, state0_b, xs_b))
    note_runner_use(policy, batched=True, variants=variants)
    return [
        _assemble(_tree_index(out, i), arrivals_batch[i]) for i in range(B)
    ]
