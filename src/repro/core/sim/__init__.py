"""Composable serving-simulation package (paper §II-C / §IV methodology).

Subsystems, each its own module, composed by the engine's tick pipeline
``admit -> provision -> serve -> offload -> drop -> account``:

  types       — workload description, latency classes, policy interfaces
                (per-arch dicts and pool-wide structure-of-arrays)
  queues      — age-bucketed class queues, vectorized over the pool
  fleet       — resource tiers: reserved / spot / burst behind one
                interface (a new tier type is one subclass)
  accounting  — the cost / violation / over-provision ledger
  engine      — :class:`ServingSim` (the tick loop) and ``simulate``
  telemetry   — per-tick recorder, structured event log (ledger-
                reconcilable), burn-rate monitors, exporters
  reference   — the seed per-arch loop, kept as the golden oracle

``repro.core.simulator`` re-exports this surface, so seed-era imports
keep working unchanged.
"""
from repro.core.sim.accounting import (  # noqa: F401
    SUMMARY_KEY_DOCS,
    Ledger,
    SimResult,
)
from repro.core.sim.engine import ArchView, ServingSim, simulate  # noqa: F401
from repro.core.sim.fleet import (  # noqa: F401
    BurstTier,
    HarvestVMTier,
    MultiRegionReservedTier,
    ProvisionPipeline,
    ResourceTier,
    SpotTier,
    SwapPipeline,
)
from repro.core.sim.queues import BucketQueue, QueueArray  # noqa: F401
from repro.core.sim.reference import ReferenceSim, simulate_reference  # noqa: F401
from repro.core.sim.telemetry import (  # noqa: F401
    EVENT_TYPES,
    Incident,
    MonitorConfig,
    Telemetry,
    TimeSeriesRecorder,
    detect_incidents,
    incidents_table,
    reconcile_events,
)
from repro.core.sim.types import (  # noqa: F401
    CLASSES,
    OFFLOAD_BLIND,
    OFFLOAD_MODES,
    OFFLOAD_NONE,
    OFFLOAD_SLACK_AWARE,
    RELAXED,
    STRICT,
    Action,
    ArchLoad,
    ArchObs,
    Policy,
    PoolAction,
    PoolObs,
    TelemetryEvent,
    Variant,
    VariantCatalog,
    VectorPolicy,
    filter_pool_candidates,
    replicate_pool,
    shares,
    uniform_pool_workload,
)
