"""Age-bucketed request queues.

Two implementations of the same FIFO-with-ages contract:

:class:`BucketQueue`
    The scalar deque-of-buckets queue the seed simulator used — one
    instance per (arch, class).  Kept for the reference simulator and as
    the readable specification of queue semantics.

:class:`QueueArray`
    The vectorized pool queue: one instance per latency class holds the
    age-bucketed queues of *all* architectures as a ``[A, W]`` ring
    buffer (structure-of-arrays), where column ``arrival_tick % W``
    counts the requests that arrived at that tick.  Because every queue
    is drained of entries older than the abandon window every tick, a
    window of ``3 * slo + 2`` columns is provably enough, and serving
    oldest-first becomes a cumulative sum — the hot path is O(A * W)
    NumPy work per tick instead of per-arch Python.  A backlog flag
    short-circuits the common well-provisioned tick (only this tick's
    arrivals queued, all of them served) down to O(A).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, List, Tuple

import numpy as np


def cumsum_serve(counts, capacity, late_mask, *, xp=np):
    """Serve ``capacity[a]`` from ``counts[a, w]`` buckets oldest-first.

    ``counts`` columns must be ordered oldest -> newest; a cumulative sum
    allocates capacity front-to-back and ``late_mask`` scores which
    buckets violate.  Backend-parametric (``xp`` is ``numpy`` or
    ``jax.numpy``): :class:`QueueArray` runs it eagerly and the batched
    JAX engine traces the identical expression inside ``lax.scan``, so
    the two serve paths cannot drift.  Returns ``(left, served, late)``.
    """
    before = xp.cumsum(counts, axis=1) - counts
    take = xp.minimum(counts, xp.clip(capacity[:, None] - before, 0.0, None))
    served = take.sum(axis=1)
    late = (take * late_mask).sum(axis=1)
    return counts - take, served, late


# ---------------------------------------------------------------------------
# Scalar reference queue (seed implementation).
# ---------------------------------------------------------------------------
class BucketQueue:
    __slots__ = ("buckets",)

    def __init__(self) -> None:
        self.buckets: Deque[List[float]] = deque()  # [arrival_tick, count]

    def push(self, tick: int, count: float) -> None:
        if count > 0:
            self.buckets.append([tick, count])

    def __len__(self) -> int:
        return int(sum(c for _, c in self.buckets))

    @property
    def total(self) -> float:
        return sum(c for _, c in self.buckets)

    def pop(self, amount: float) -> List[Tuple[int, float]]:
        """Serve ``amount`` oldest-first; returns [(arrival_tick, count)]."""
        out: List[Tuple[int, float]] = []
        while amount > 1e-9 and self.buckets:
            t0, c = self.buckets[0]
            take = min(c, amount)
            out.append((t0, take))
            amount -= take
            if take >= c - 1e-12:
                self.buckets.popleft()
            else:
                self.buckets[0][1] = c - take
        return out

    def pop_older_than(self, tick: int, max_age: int) -> float:
        """Remove and return the count of entries with age > max_age."""
        n = 0.0
        while self.buckets and tick - self.buckets[0][0] > max_age:
            n += self.buckets.popleft()[1]
        return n


# ---------------------------------------------------------------------------
# Vectorized pool queue: all archs of one latency class, SoA.
# ---------------------------------------------------------------------------
class QueueArray:
    """Pool-wide age-bucketed FIFO queues for one latency class.

    ``slack[a]`` is the per-arch integer age beyond which a served
    request counts as an SLO violation; ``drop_age`` (3 x the class SLO)
    is the abandon window after which unserved requests are dropped.
    """

    def __init__(self, n_archs: int, slo_s: float, slack: np.ndarray):
        self.slo_s = float(slo_s)
        self.slack = np.asarray(slack, dtype=np.int64)
        self.drop_age = int(3 * slo_s)
        # ages 0..drop_age live between ticks; +1 transient before the
        # drop step runs; +1 spare so "this tick's" column is always free
        self.window = self.drop_age + 2
        self.buf = np.zeros((n_archs, self.window), dtype=np.float64)
        # incremental per-arch mass, and whether any mass is older than
        # the current tick's column (the slow-path trigger)
        self.total = np.zeros(n_archs, dtype=np.float64)
        self.backlog = False
        # precomputed geometry: for tick t, the columns oldest -> newest
        # are _cols[t % W]; their ages are always W-1 .. 0
        w = self.window
        self._cols = np.stack([np.arange(r + 1, r + 1 + w) % w for r in range(w)])
        ages = np.arange(w - 1, -1, -1)
        self._late_mask = ages[None, :] > self.slack[:, None]

    # -- admission ----------------------------------------------------------
    def push(self, tick: int, counts: np.ndarray) -> None:
        """Admit this tick's arrivals (``counts[a]`` requests per arch)."""
        self.buf[:, tick % self.window] += counts
        self.total += counts

    def totals(self) -> np.ndarray:
        return self.total

    def age_quantile(self, tick: int, q: float = 0.99) -> np.ndarray:
        """Per-arch ``q``-quantile of queued-request ages at this tick
        (seconds; 0 for empty queues) — the telemetry recorder's
        queue-age gauge.  The quantile age is the smallest age holding
        at least ``q`` of the arch's queued mass at or below it."""
        counts = self.buf[:, self._cols[tick % self.window]]   # oldest->newest
        total = counts.sum(axis=1)
        by_age = counts[:, ::-1]                               # ages 0..W-1
        cum = np.cumsum(by_age, axis=1)
        k = np.argmax(cum >= (q * total)[:, None], axis=1)
        return np.where(total > 0, k, 0)

    def late_mask_for(self, slack: np.ndarray) -> np.ndarray:
        """An alternative ``[A, W]`` lateness mask for ``serve``: a served
        request is late when its age exceeds ``slack[a]`` (which may be
        negative — e.g. a remote tier whose egress adder alone blows the
        SLO makes even age-0 service late)."""
        ages = np.arange(self.window - 1, -1, -1)
        return ages[None, :] > np.asarray(slack, dtype=np.int64)[:, None]

    # -- serving ------------------------------------------------------------
    def serve(
        self, tick: int, capacity: np.ndarray,
        late_mask: np.ndarray = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Serve up to ``capacity[a]`` requests oldest-first.

        Returns ``(served[a], late[a])`` where ``late`` counts served
        requests whose queueing age exceeded the arch's slack —
        evaluated against ``late_mask`` (from :meth:`late_mask_for`)
        instead of the arch's own slack when given, which is how capacity
        with a per-request latency adder (a remote region's egress)
        books its tighter lateness threshold.
        """
        if not self.backlog and late_mask is None:
            # only this tick's arrivals are queued: age 0, never late
            col = tick % self.window
            counts = self.buf[:, col]
            take = np.minimum(counts, capacity)
            left = counts - take
            self.buf[:, col] = left
            self.total = left.copy()
            self.backlog = bool(left.any())
            return take, np.zeros_like(take)

        idx = self._cols[tick % self.window]
        counts = self.buf[:, idx]
        mask = self._late_mask if late_mask is None else late_mask
        left, served, late = cumsum_serve(counts, capacity, mask)
        self.buf[:, idx] = left
        self.total = self.total - served
        self.backlog = bool(self.total.any())
        return served, late

    # -- burst offload ------------------------------------------------------
    def drain(self, mask: np.ndarray) -> np.ndarray:
        """Empty the queues of archs selected by boolean ``mask[a]``;
        returns the drained counts (0 elsewhere)."""
        out = self.total * mask
        self.buf[mask] = 0.0
        self.total = self.total * ~mask
        self.backlog = bool(self.total.any())
        return out

    # -- abandonment --------------------------------------------------------
    def drop_expired(self, tick: int) -> np.ndarray:
        """Drop the bucket that just aged past the abandon window.

        Because this runs every tick, at most one column (age
        ``drop_age + 1``) can hold expired mass.
        """
        arrival = tick - self.drop_age - 1
        if arrival < 0 or not self.backlog:
            return np.zeros(self.buf.shape[0])
        col = arrival % self.window
        out = self.buf[:, col].copy()
        self.buf[:, col] = 0.0
        self.total = self.total - out
        self.backlog = bool(self.total.any())
        return out

    def pop_older_than_slack(self, tick: int) -> np.ndarray:
        """End-of-trace sweep: remove everything older than each arch's
        slack (it would violate if it were ever served)."""
        idx = self._cols[tick % self.window]
        counts = self.buf[:, idx]
        old = self._late_mask
        out = (counts * old).sum(axis=1)
        self.buf[:, idx] = counts * ~old
        self.total = self.total - out
        self.backlog = bool(self.total.any())
        return out
