"""The tick engine: admit -> provision -> serve -> offload -> drop -> account.

Time-stepped fluid simulation at 1 s ticks (paper §II-C / §IV
methodology): trace-driven arrivals fan out over a model pool, each
(arch, latency-class) pair keeps an age-bucketed FIFO queue
(:mod:`repro.core.sim.queues`), resource tiers serve at their profiled
throughput (:mod:`repro.core.sim.fleet` — reserved / spot / harvest /
remote behind one interface, driven by generic provision / serve /
account loops; strict-class traffic is served from zero-egress local
capacity first), and a procurement policy decides — every tick — the
per-tier fleet targets and which queued requests to offload to burst
instances.  Metrics accumulate in the ledger
(:mod:`repro.core.sim.accounting`), tier costs keyed by tier name.

All pool state is structure-of-arrays, so one tick costs O(A) NumPy work
however many architectures the pool holds; a 64-arch 24 h trace runs in
seconds.  Policies can speak either interface:

* the legacy dict form — ``observe() -> {arch: ArchObs}``,
  ``apply({arch: Action})`` — unchanged from the seed simulator;
* the vectorized form — ``observe_pool() -> PoolObs``,
  ``apply_pool(PoolAction)`` — arrays end-to-end, used by the
  ``Vector*`` schedulers on large pools.

Arrivals come in two shapes (``trace`` argument):

* a 1-D ``[T]`` pool trace — every arch sees ``share x trace`` (the
  seed behavior); the load monitor exploits the shared shape and scales
  precomputed pool statistics by share;
* a 2-D ``[A, T]`` arrival matrix (:mod:`repro.core.workloads`) — each
  arch has its own stream, and a vectorized streaming per-arch monitor
  (:class:`~repro.core.load_monitor.PoolLoadMonitor`) computes
  ``PoolObs.ewma_rate / window_peak / peak_to_median`` per arch.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.hardware import PRICING, FleetPricing
from repro.core.load_monitor import LoadMonitor, PoolLoadMonitor
from repro.core.profiles import ModelProfile, get_profile
from repro.core.sim.accounting import Ledger, SimResult
from repro.core.sim.fleet import (
    BurstTier,
    HarvestVMTier,
    MultiRegionReservedTier,
    ResourceTier,
    SpotTier,
    SwapPipeline,
)
from repro.core.sim.queues import QueueArray
from repro.core.sim.types import (
    OFFLOAD_MODES,
    RELAXED,
    STRICT,
    Action,
    ArchLoad,
    ArchObs,
    PoolAction,
    PoolObs,
    VariantCatalog,
    shares,
)

_OFFLOAD_CODE = {m: i for i, m in enumerate(OFFLOAD_MODES)}

# monitor parameters come from LoadMonitor so the engine's precomputed
# window statistics can never drift from the reference simulator's
MONITOR_WINDOW_S = LoadMonitor.window_s
MONITOR_EWMA_ALPHA = LoadMonitor.ewma_alpha


def _trace_window_stats(trace: np.ndarray, window: int):
    """Sliding-window peak and median of the whole trace, precomputed.

    The load monitor's window statistics depend only on the (known)
    trace, so one upfront O(T * W) pass replaces a per-tick ``np.median``
    in the hot loop.  The first ``window - 1`` ticks use growing windows,
    matching the seed :class:`~repro.core.load_monitor.LoadMonitor`.
    """
    n = len(trace)
    peak = np.empty(n)
    med = np.empty(n)
    for t in range(min(window - 1, n)):
        peak[t] = trace[: t + 1].max()
        med[t] = np.median(trace[: t + 1])
    if n >= window:
        sw = np.lib.stride_tricks.sliding_window_view(trace, window)
        for s in range(0, len(sw), 8192):   # chunk: bounds partition scratch
            blk = sw[s: s + 8192]
            peak[window - 1 + s: window - 1 + s + len(blk)] = blk.max(axis=1)
            med[window - 1 + s: window - 1 + s + len(blk)] = np.median(blk, axis=1)
    return peak, med


# ---------------------------------------------------------------------------
# Per-arch compatibility views over the SoA state.
# ---------------------------------------------------------------------------
class _QueueView:
    """Scalar window into one arch's row of a :class:`QueueArray`."""

    __slots__ = ("_q", "_i")

    def __init__(self, q: QueueArray, i: int):
        self._q, self._i = q, i

    @property
    def total(self) -> float:
        return float(self._q.buf[self._i].sum())

    def __len__(self) -> int:
        return int(self.total)


class _MonitorView:
    """Per-arch window into the engine's materialized monitor vectors
    (shared-trace runs: share x pool statistics; matrix runs: the
    streaming per-arch monitor's own statistics)."""

    __slots__ = ("_sim", "_i")

    def __init__(self, sim: "ServingSim", i: int):
        self._sim, self._i = sim, i

    @property
    def rate(self) -> float:
        return float(self._sim._ewma_vec[self._i])

    @property
    def peak(self) -> float:
        return float(self._sim._peak_vec[self._i])

    @property
    def peak_to_median(self) -> float:
        return float(self._sim._p2m_vec[self._i])


class ArchView:
    """Read view of one arch's slice of the engine state — what the seed
    simulator called ``_ArchState``.  Kept so stepwise drivers (the RL
    env) can keep reading per-arch fields."""

    def __init__(self, sim: "ServingSim", i: int, load: ArchLoad,
                 prof: ModelProfile):
        self._sim, self._i = sim, i
        self.load = load
        self.prof = prof
        self.queues = {
            "strict": _QueueView(sim.q_strict, i),
            "relaxed": _QueueView(sim.q_relaxed, i),
        }
        self.monitor = _MonitorView(sim, i)

    @property
    def throughput(self) -> float:
        return float(self._sim.eff_throughput[self._i])

    @property
    def n_active(self) -> int:
        return int(self._sim.reserved.active[self._i])

    @property
    def n_spot(self) -> int:
        return int(self._sim.spot.active[self._i])

    @property
    def n_pending(self) -> int:
        return int(self._sim.reserved.pending_total[self._i])

    @property
    def slack(self) -> Dict[str, int]:
        return {
            "strict": int(self._sim.q_strict.slack[self._i]),
            "relaxed": int(self._sim.q_relaxed.slack[self._i]),
        }


# ---------------------------------------------------------------------------
# The engine.
# ---------------------------------------------------------------------------
class ServingSim:
    """Stepwise serving simulator: ``observe() -> actions -> apply()``."""

    def __init__(
        self,
        trace: np.ndarray,                 # [T] pool trace or [A, T] matrix
        workload: List[ArchLoad],
        *,
        pricing: FleetPricing = PRICING,
        prewarm: bool = True,
        warm_start: bool = True,
        seed: int = 0,
        catalog: Optional[VariantCatalog] = None,
        telemetry: Optional["Telemetry"] = None,
    ):
        arr = np.asarray(trace, dtype=np.float64)
        self.pricing = pricing
        # tier-protocol generator (the stochastic tiers own per-tick
        # seeded streams instead — see sim/fleet.py)
        self.rng = np.random.default_rng(seed)
        self.tick = 0

        keys = [w.key for w in workload]
        assert len(set(keys)) == len(keys), "workload keys must be unique"
        self.keys = keys
        n = len(workload)

        if arr.ndim == 2:
            assert arr.shape[0] == n, (
                f"arrival matrix has {arr.shape[0]} rows for {n} archs"
            )
            self.arrivals: Optional[np.ndarray] = arr   # [A, T]
            self.trace = arr.sum(axis=0)                # pooled view
        else:
            self.arrivals = None
            self.trace = arr

        profs = [get_profile(w.arch, req=STRICT) for w in workload]
        self.share = shares(workload)
        self.strict_frac = np.array([w.strict_frac for w in workload])
        self.throughput = np.array([p.throughput(STRICT) for p in profs])
        for w, thr in zip(workload, self.throughput):
            assert thr > 0, f"{w.arch} cannot meet the strict SLO"
        self.chips = np.array([p.chips for p in profs], dtype=np.float64)
        lat_b1 = np.array([p.request_latency(STRICT, 1) for p in profs])
        self.lat_b1 = lat_b1

        # model-variant axis: each arch serves its *active* variant's
        # service rate / chip footprint / accuracy, and burst invocations
        # observe its batch-1 latency; without a catalog the arch is its
        # own sole variant (multipliers 1.0 — bit-identical to the
        # variant-blind engine).  Queue slack stays pinned to the base
        # variant's batch-1 latency: it encodes the stream's SLO
        # geometry, not the deployed weights.
        self.acc_floor = np.array([w.min_accuracy for w in workload])
        if catalog is None:
            self.var_acc = np.array([[p.cfg.quality] for p in profs])
            self.var_smult = np.ones((n, 1))
            self.var_cmult = np.ones((n, 1))
            self.var_lmult = np.ones((n, 1))
            self.var_n = np.ones(n, dtype=np.int64)
            base_idx = np.zeros(n, dtype=np.int64)
            self.var_lo = np.zeros(n, dtype=np.int64)
            self.var_cheapest = np.zeros(n, dtype=np.int64)
        else:
            va = catalog.as_arrays(workload)
            self.var_acc = va["accuracy"]
            self.var_smult = va["service_mult"]
            self.var_cmult = va["cost_mult"]
            self.var_lmult = va["lat_mult"]
            self.var_n = va["n_variants"]
            base_idx = va["base_idx"]
            self.var_lo = va["floor_lo"]
            self.var_cheapest = va["floor_cheapest"]
        self.catalog = catalog
        self.swap = SwapPipeline(base_idx, pricing.variant_swap_s)

        # class queues: slack = SLO minus the batch-1 model latency
        slack_strict = np.maximum(0, (STRICT.slo_s - lat_b1).astype(np.int64))
        slack_relaxed = np.maximum(0, (RELAXED.slo_s - lat_b1).astype(np.int64))
        self.q_strict = QueueArray(n, STRICT.slo_s, slack_strict)
        self.q_relaxed = QueueArray(n, RELAXED.slo_s, slack_relaxed)

        # resource tiers: reserved / spot / harvest / remote slices serve
        # the queues; the burst pool absorbs offloads per-invocation.
        # The engine only speaks the ResourceTier interface — a new
        # offering registers in ``aux_tiers`` below and the generic
        # provision / serve / account loops drive it.
        self.reserved = ResourceTier(n, pricing)
        self.spot = SpotTier(n, pricing, seed=seed)
        self.harvest = HarvestVMTier(n, pricing, seed=seed)
        self.remote = MultiRegionReservedTier(n, pricing)
        #: policy-targetable tiers beyond reserved, keyed by action field
        self.aux_tiers: Dict[str, ResourceTier] = {
            "spot": self.spot, "harvest": self.harvest, "remote": self.remote,
        }
        # lazily-activated: an untargeted tier costs nothing per tick
        self._tier_live: Dict[str, bool] = {k: False for k in self.aux_tiers}
        # local (zero-egress) capacity serves strict-class traffic first;
        # remote-group capacity pays its egress adder on lateness.  Both
        # groups are derived from the tier interface, so a new tier
        # lands in the right serve group by registration alone.
        self._remote_group = [
            t for t in (self.reserved, *self.aux_tiers.values())
            if t.egress_latency_s() > 0
        ]
        self._local_aux = [
            t for t in self.aux_tiers.values() if t.egress_latency_s() == 0
        ]
        self.burst = BurstTier(
            pricing,
            lat_b1=lat_b1,
            cold_start_s=np.array([p.cold_start_s() for p in profs]),
            cost_per_request=(
                self.chips / self.throughput
            ) * pricing.burst_chip_s + pricing.burst_invocation_fee,
            prewarm=prewarm,
        )

        # effective (active-variant) serving state; with every arch on its
        # base variant this is exactly the base state (multipliers 1.0)
        self._refresh_variant_state()
        # single-variant world: the variant observation never changes, so
        # one read-only record serves every tick (keeps the seed fast
        # path free of per-tick copies/gathers for the new fields)
        self._variants_live = self.var_smult.shape[1] > 1
        if not self._variants_live:
            ones = np.ones(n)
            statics = {
                "active_variant": self.swap.current,
                "n_variants": self.var_n,
                "accuracy": self.cur_acc,
                "accuracy_floor": self.acc_floor,
                "variant_lo": self.var_lo,
                "variant_cheapest": self.var_cheapest,
                "variant_in_flight": np.zeros(n, dtype=bool),
                "variant_up_ratio": ones,
                "variant_down_ratio": ones,
                "variant_pending_ratio": ones,
            }
            for a in statics.values():
                a.setflags(write=False)
            self._static_variant_obs = statics
        # floor-free streams cannot violate the accuracy SLO — skip the
        # per-tick comparison and share one read-only zero marginal
        self._acc_floor_live = bool((self.acc_floor > 0).any())
        self._zero_arch = np.zeros(n)
        self._zero_arch.setflags(write=False)

        self.ledger = Ledger()
        self.last_util = np.zeros(n)
        self._ewma: Optional[float] = None
        if self.arrivals is None:
            # shared trace: every arch is share x pool, so the window
            # statistics are one precomputed pool pass scaled by share
            self._wpeak, self._wmed = _trace_window_stats(
                self.trace, MONITOR_WINDOW_S
            )
            self.pool_monitor: Optional[PoolLoadMonitor] = None
        else:
            # heterogeneous streams: per-arch streaming monitor
            self._wpeak = self._wmed = None
            self.pool_monitor = PoolLoadMonitor(n)
        # materialized per-arch monitor vectors (what policies see)
        self._ewma_vec = np.zeros(n)
        self._peak_vec = np.zeros(n)
        self._p2m_vec = np.ones(n)
        self._rates = np.zeros(n)
        self._pool_obs: Optional[PoolObs] = None

        # tier-portfolio observation state: idle tiers share precomputed
        # read-only records (the common reserved-only tick stays O(A)
        # with no extra copies); live tiers overwrite their entries
        zeros_i = np.zeros(n, dtype=np.int64)
        zeros_i.setflags(write=False)
        risk = np.full(n, self.spot.reclaim_probability())
        risk.setflags(write=False)
        self._static_tier_obs = {
            "n_spot_pending": zeros_i,
            "n_harvest": zeros_i, "n_harvest_pending": zeros_i,
            "n_remote": zeros_i, "n_remote_pending": zeros_i,
            "spot_reclaim_risk": risk,
        }
        # remote-group capacity books lateness against an egress-tightened
        # slack (which may be negative: egress alone can blow the SLO)
        egress = max(
            (t.egress_latency_s() for t in self._remote_group), default=0.0
        )
        self._remote_late_strict = self.q_strict.late_mask_for(
            np.floor(STRICT.slo_s - lat_b1 - egress)
        )
        self._remote_late_relaxed = self.q_relaxed.late_mask_for(
            np.floor(RELAXED.slo_s - lat_b1 - egress)
        )

        # hot-path observation buffers: observe_pool refills these in
        # place every tick instead of allocating fresh [A] vectors, so
        # the telemetry-disabled tick allocates no obs arrays even at
        # fleet scale (A=256+).  The PoolObs contract is unchanged in
        # practice: a returned observation is stable until the *next*
        # observe_pool call; consumers that keep values across ticks
        # copy fields out (env._prev_rate does).
        self._share_pos = self.share > 0
        self._nstrict_buf = np.zeros(n)
        self._nrelaxed_buf = np.zeros(n)
        self._qlen_buf = np.zeros(n)
        self._qs_buf = np.zeros(n)
        self._qr_buf = np.zeros(n)
        self._nact_buf = np.zeros(n, dtype=np.int64)
        self._npend_buf = np.zeros(n, dtype=np.int64)
        self._nspot_buf = np.zeros(n, dtype=np.int64)
        self._thr_buf = np.zeros(n)
        self._util_buf = np.zeros(n)
        self._lviol_buf = np.zeros(n)
        self._harv_level_buf = np.zeros(n)
        self._harv_ceil_buf = np.zeros(n, dtype=np.int64)
        self._tier_obs_buf = {
            k: np.zeros(n, dtype=np.int64)
            for k in ("n_spot_pending", "n_harvest", "n_harvest_pending",
                      "n_remote", "n_remote_pending")
        }
        self._tobs = dict(self._static_tier_obs)
        self._tobs["harvest_level"] = self._harv_level_buf
        self._tobs["harvest_ceiling"] = self._harv_ceil_buf

        # per-arch flow accounting (arrived == served_vm + served_burst +
        # dropped + queued, every tick; `per_arch_counts` exposes copies)
        self.arrived_arch = np.zeros(n)
        self.served_vm_arch = np.zeros(n)
        self.served_burst_arch = np.zeros(n)
        self.dropped_arch = np.zeros(n)
        self.expired_end_arch = np.zeros(n)
        self.violations_arch = np.zeros(n)
        # per-arch reward surface: cumulative $ cost attributed to each
        # arch (reserved/spot by held capacity, burst by invocation) and
        # the violations booked during the previous tick — what a
        # pool-wide controller decomposes its reward from
        self.cost_arch = np.zeros(n)
        self.last_viol_arch = np.zeros(n)
        # delivered-accuracy accounting: answered mass x active-variant
        # accuracy, and the mass answered below each stream's floor
        self.acc_weight_arch = np.zeros(n)
        self.acc_viol_arch = np.zeros(n)

        self.states: Dict[str, ArchView] = {
            k: ArchView(self, i, w, p)
            for i, (k, w, p) in enumerate(zip(keys, workload, profs))
        }

        t0_rates = (
            self.trace[0] * self.share if self.arrivals is None
            else self.arrivals[:, 0]
        )
        if warm_start:
            self.reserved.active = np.maximum(
                1, np.ceil(t0_rates / self.eff_throughput)
            ).astype(np.int64)

        # observability: every emission below is gated on `telemetry is
        # not None`, so the disabled engine is bit-identical to (and as
        # fast as) the pre-telemetry one
        self.telemetry = telemetry
        if telemetry is not None:
            telemetry.bind(self)
            for tier in (self.reserved, *self.aux_tiers.values(), self.burst):
                tier.telemetry = telemetry

    # ------------------------------------------------------------------
    def _refresh_variant_state(self) -> None:
        """Re-gather the active variant's effective serving vectors.

        Called at init and whenever a swap completes (rare), so the hot
        loop reads plain ``[A]`` vectors.  On base variants every gather
        returns multiplier 1.0 and the products are bit-identical to the
        variant-blind quantities (``x * 1.0 == x`` in IEEE-754).
        """
        cur = self.swap.current[:, None]
        self.cur_acc = np.take_along_axis(self.var_acc, cur, 1)[:, 0]
        smult = np.take_along_axis(self.var_smult, cur, 1)[:, 0]
        cmult = np.take_along_axis(self.var_cmult, cur, 1)[:, 0]
        lmult = np.take_along_axis(self.var_lmult, cur, 1)[:, 0]
        self.cur_smult = smult
        self.eff_throughput = self.throughput * smult
        self.eff_chips = self.chips * cmult
        # burst invocations hit the *active* variant's warm pool: both
        # the billing and the batch-1 latency follow the swap
        self.burst.cost_per_request = (
            self.eff_chips / self.eff_throughput
        ) * self.pricing.burst_chip_s + self.pricing.burst_invocation_fee
        self.burst.lat_b1 = self.lat_b1 * lmult

    # ------------------------------------------------------------------
    @property
    def res(self) -> SimResult:
        return self.ledger.res

    @property
    def done(self) -> bool:
        return self.tick >= len(self.trace)

    # ------------------------------------------------------------------
    # Admit + observe.
    # ------------------------------------------------------------------
    def observe_pool(self) -> PoolObs:
        """Admit this tick's arrivals and return the pool observation.

        The returned ``PoolObs`` aliases per-tick buffers owned by the
        engine — valid until the next ``observe_pool`` call (every
        scheduler and the step-wise RL loop consume it within the tick;
        callers that need history copy fields out)."""
        tick = self.tick
        rates = self._rates

        if self.arrivals is None:
            rate = float(self.trace[tick])
            # load monitor, vectorized: every arch's stream is share x the
            # pool stream, so EWMA/peak/median scale by share and the
            # peak-to-median ratio is share-invariant
            self._ewma = (
                rate if self._ewma is None
                else MONITOR_EWMA_ALPHA * rate + (1 - MONITOR_EWMA_ALPHA) * self._ewma
            )
            window_peak = float(self._wpeak[tick])
            med = float(self._wmed[tick])
            p2m = window_peak / med if med > 0 else 1.0

            np.multiply(rate, self.share, out=rates)
            np.multiply(self._ewma, self.share, out=self._ewma_vec)
            np.multiply(window_peak, self.share, out=self._peak_vec)
            # zero-share rows stay at their initial 1.0 forever
            np.copyto(self._p2m_vec, p2m, where=self._share_pos)
        else:
            # heterogeneous streams: one streaming monitor update, every
            # statistic per arch (share scaling cannot express these)
            np.copyto(rates, self.arrivals[:, tick])
            self.pool_monitor.observe(rates)
            self._ewma_vec, self._peak_vec, _, self._p2m_vec = (
                self.pool_monitor.stats()
            )

        n_strict = np.multiply(rates, self.strict_frac, out=self._nstrict_buf)
        self.q_strict.push(tick, n_strict)
        self.q_relaxed.push(
            tick, np.subtract(rates, n_strict, out=self._nrelaxed_buf)
        )
        self.ledger.add_arrivals(float(rates.sum()))
        self.arrived_arch += rates
        if self.telemetry is not None:
            self.telemetry.on_arrivals(tick, rates)

        # variant observation: neighbor / in-flight service-rate ratios
        # are what swap-aware policies need to judge (and pre-provision
        # for) a move; in the single-variant world the whole record is
        # the precomputed read-only constant
        if not self._variants_live:
            vobs = self._static_variant_obs
        else:
            cur = self.swap.current
            up = np.minimum(cur + 1, self.var_n - 1)[:, None]
            dn = np.maximum(cur - 1, 0)[:, None]
            pend = self.swap.pending
            vobs = {
                "active_variant": cur.copy(),
                "n_variants": self.var_n.copy(),
                "accuracy": self.cur_acc.copy(),
                "accuracy_floor": self.acc_floor.copy(),
                "variant_lo": self.var_lo.copy(),
                "variant_cheapest": self.var_cheapest.copy(),
                "variant_in_flight": self.swap.in_flight.copy(),
                "variant_up_ratio": (
                    np.take_along_axis(self.var_smult, up, 1)[:, 0]
                    / self.cur_smult
                ),
                "variant_down_ratio": (
                    np.take_along_axis(self.var_smult, dn, 1)[:, 0]
                    / self.cur_smult
                ),
                "variant_pending_ratio": np.where(
                    pend >= 0,
                    np.take_along_axis(
                        self.var_smult, np.maximum(pend, 0)[:, None], 1
                    )[:, 0] / self.cur_smult,
                    1.0,
                ),
            }

        # tier-portfolio state: idle tiers alias the precomputed read-only
        # statics; live tiers refill their persistent buffers.  The
        # harvest signal is provider-side time-varying state, so its
        # level/ceiling are re-broadcast every tick (the signal advances
        # whether or not any policy holds harvest capacity).
        tobs = self._tobs
        self._harv_level_buf.fill(self.harvest.level)
        self._harv_ceil_buf.fill(self.harvest.ceiling())
        for obs_key, live, src in (
            ("n_spot_pending", self._tier_live["spot"],
             self.spot.pipeline.total),
            ("n_harvest", self._tier_live["harvest"], self.harvest.active),
            ("n_harvest_pending", self._tier_live["harvest"],
             self.harvest.pipeline.total),
            ("n_remote", self._tier_live["remote"], self.remote.active),
            ("n_remote_pending", self._tier_live["remote"],
             self.remote.pipeline.total),
        ):
            if live:
                buf = self._tier_obs_buf[obs_key]
                np.copyto(buf, src)
                tobs[obs_key] = buf
            else:
                # _tier_live is NOT monotonic (a drained tier goes idle
                # again) — restore the static zeros when it does
                tobs[obs_key] = self._static_tier_obs[obs_key]

        np.copyto(self._nact_buf, self.reserved.active)
        np.copyto(self._npend_buf, self.reserved.pending_total)
        np.copyto(self._nspot_buf, self.spot.active)
        np.copyto(self._thr_buf, self.eff_throughput)
        np.copyto(self._util_buf, self.last_util)
        np.copyto(self._qs_buf, self.q_strict.totals())
        np.copyto(self._qr_buf, self.q_relaxed.totals())
        np.copyto(self._lviol_buf, self.last_viol_arch)
        np.add(self._qs_buf, self._qr_buf, out=self._qlen_buf)
        self._pool_obs = PoolObs(
            keys=self.keys,
            rate=rates,
            ewma_rate=self._ewma_vec,
            window_peak=self._peak_vec,
            peak_to_median=self._p2m_vec,
            queue_len=self._qlen_buf,
            n_active=self._nact_buf,
            n_pending=self._npend_buf,
            n_spot=self._nspot_buf,
            throughput=self._thr_buf,
            utilization=self._util_buf,
            queue_strict=self._qs_buf,
            queue_relaxed=self._qr_buf,
            last_violations=self._lviol_buf,
            **tobs,
            **vobs,
        )
        return self._pool_obs

    def observe(self) -> Dict[str, ArchObs]:
        """Dict form of :meth:`observe_pool` (legacy policy interface)."""
        p = self.observe_pool()
        return {
            k: ArchObs(
                arch=k,
                rate=float(p.rate[i]),
                ewma_rate=float(p.ewma_rate[i]),
                window_peak=float(p.window_peak[i]),
                peak_to_median=float(p.peak_to_median[i]),
                queue_len=float(p.queue_len[i]),
                n_active=int(p.n_active[i]),
                n_pending=int(p.n_pending[i]),
                n_spot=int(p.n_spot[i]),
                throughput=float(p.throughput[i]),
                utilization=float(p.utilization[i]),
                n_spot_pending=int(p.n_spot_pending[i]),
                n_harvest=int(p.n_harvest[i]),
                n_harvest_pending=int(p.n_harvest_pending[i]),
                n_remote=int(p.n_remote[i]),
                n_remote_pending=int(p.n_remote_pending[i]),
                spot_reclaim_risk=float(p.spot_reclaim_risk[i]),
                harvest_level=float(p.harvest_level[i]),
                harvest_ceiling=int(p.harvest_ceiling[i]),
                active_variant=int(p.active_variant[i]),
                n_variants=int(p.n_variants[i]),
                accuracy=float(p.accuracy[i]),
                accuracy_floor=float(p.accuracy_floor[i]),
                variant_lo=int(p.variant_lo[i]),
                variant_cheapest=int(p.variant_cheapest[i]),
                variant_in_flight=bool(p.variant_in_flight[i]),
                variant_up_ratio=float(p.variant_up_ratio[i]),
                variant_down_ratio=float(p.variant_down_ratio[i]),
                variant_pending_ratio=float(p.variant_pending_ratio[i]),
            )
            for i, k in enumerate(self.keys)
        }

    # ------------------------------------------------------------------
    # Apply.
    # ------------------------------------------------------------------
    def apply(self, actions: Dict[str, Action]) -> dict:
        """Apply per-arch dict actions, serve the tick, advance time.

        Returns this tick's marginal metrics (for RL rewards)."""
        n = len(self.keys)
        target = np.empty(n, dtype=np.int64)
        offload = np.zeros(n, dtype=np.int64)
        spot_target = np.zeros(n, dtype=np.int64)
        harvest_target = np.zeros(n, dtype=np.int64)
        remote_target = np.zeros(n, dtype=np.int64)
        variant_target = np.full(n, -1, dtype=np.int64)
        for i, k in enumerate(self.keys):
            act = actions.get(k)
            if act is None:
                target[i] = self.reserved.active[i]
            else:
                target[i] = act.target
                # unknown offload values mean "none", as in the seed loop
                offload[i] = _OFFLOAD_CODE.get(act.offload, 0)
                spot_target[i] = act.spot_target
                harvest_target[i] = act.harvest_target
                remote_target[i] = act.remote_target
                variant_target[i] = act.variant
        return self._step(target, offload, spot_target, variant_target,
                          harvest_target, remote_target)

    def apply_pool(self, action: PoolAction) -> dict:
        """Vectorized counterpart of :meth:`apply`."""
        n = len(self.keys)
        return self._step(
            np.asarray(action.target, dtype=np.int64),
            action.offload_codes(n),
            action.spot_targets(n),
            action.variant_targets(n),
            action.harvest_targets(n),
            action.remote_targets(n),
        )

    def _step(
        self,
        target: np.ndarray,
        offload: np.ndarray,
        spot_target: np.ndarray,
        variant_target: Optional[np.ndarray] = None,
        harvest_target: Optional[np.ndarray] = None,
        remote_target: Optional[np.ndarray] = None,
    ) -> dict:
        assert self._pool_obs is not None, "call observe() before apply()"
        tick = self.tick
        led = self.ledger
        res = led.res
        cost0, viol0 = res.cost_total, res.violations
        cost0_arch = self.cost_arch.copy()
        viol0_arch = self.violations_arch.copy()

        # variant swaps: due swaps take effect for THIS tick's serving
        # (like provisioning: ready launches join before the queues are
        # served), then new requests enter the pipeline — the arch keeps
        # serving at the old variant's rate until theirs completes
        # (single-variant world: every request is a held/cancelled no-op)
        tel = self.telemetry
        if self._variants_live:
            done_swaps = self.swap.pop_ready(tick)
            if done_swaps.any():
                led.add_variant_swaps(int(done_swaps.sum()))
                self._refresh_variant_state()
                if tel is not None:
                    tel.on_swap_landed(tick, done_swaps)
            if variant_target is not None and (variant_target >= 0).any():
                req = np.minimum(variant_target, self.var_n - 1)
                started = self.swap.request(tick, req)
                if tel is not None:
                    tel.on_swap_request(tick, started, req)

        # provision: each tier runs its events + pipeline toward its
        # target.  Aux tiers activate lazily — an untargeted tier is
        # skipped entirely, so the reserved-only tick stays unchanged.
        self.reserved.begin_tick(tick, self.rng, led)
        self.reserved.set_target(tick, target)
        aux_targets = {
            "spot": spot_target, "harvest": harvest_target,
            "remote": remote_target,
        }
        for name, tier in self.aux_tiers.items():
            tgt = aux_targets[name]
            if self._tier_live[name] or (tgt is not None and tgt.any()):
                tier.begin_tick(tick, self.rng, led)
                tier.set_target(tick, tgt)
                self._tier_live[name] = bool(
                    tier.active.any() or tier.pipeline.total.any()
                )
            else:
                # provider-side state (the harvest availability signal)
                # evolves with time, not with usage
                tier.idle_tick(tick)

        # serve from the class queues, strict first, oldest first, at the
        # ACTIVE variant's service rate (old variant while a swap is in
        # flight — the weight reload has not landed yet).  Strict traffic
        # prefers LOCAL capacity: zero-egress tiers serve first; the
        # remote group's capacity follows, booking lateness against its
        # egress-tightened slack.
        remote_live = any(
            self._tier_live.get(t.name, False) for t in self._remote_group
        )
        local_active = self.reserved.active
        for t in self._local_aux:
            local_active = local_active + t.active
        capacity = local_active * self.eff_throughput
        served_s, late_s = self.q_strict.serve(tick, capacity)
        if remote_live:
            remote_cap = sum(
                t.active for t in self._remote_group
            ) * self.eff_throughput
            srs, lrs = self.q_strict.serve(
                tick, remote_cap, late_mask=self._remote_late_strict
            )
            served_r, late_r = self.q_relaxed.serve(tick, capacity - served_s)
            srr, lrr = self.q_relaxed.serve(
                tick, remote_cap - srs, late_mask=self._remote_late_relaxed
            )
            served_s, late_s = served_s + srs, late_s + lrs
            served_r, late_r = served_r + srr, late_r + lrr
            capacity = capacity + remote_cap
        else:
            served_r, late_r = self.q_relaxed.serve(tick, capacity - served_s)
        served = served_s + served_r
        answered = served.copy()       # accuracy accounting: who answered
        led.add_served_vm(float(served.sum()))
        led.add_violations(float(late_s.sum() + late_r.sum()), float(late_s.sum()))
        self.served_vm_arch += served
        self.violations_arch += late_s + late_r
        if tel is not None:
            tel.on_serve(tick, served, late_s, late_r)
        self.last_util = np.where(
            capacity > 0, served / np.where(capacity > 0, capacity, 1.0), 1.0
        )

        # offload decision: what leaves the queue for burst instances.
        #   blind       — anything unserved goes now, both classes
        #                 (MArk/Spock assume one global SLO)
        #   slack_aware — Paragon: strict queries offload when a VM slot
        #                 is unavailable; relaxed queries NEVER pay the
        #                 burst premium ("does not offload to lambdas for
        #                 relaxed latency queries", §IV-B)
        for q, mask, strict in (
            (self.q_strict, offload >= 1, True),
            (self.q_relaxed, offload == 1, False),
        ):
            if mask.any():
                counts = q.drain(mask)
                # sub-epsilon residue of the cumsum serve is not real
                # offload mass (the seed's BucketQueue absorbed it at its
                # 1e-12 threshold) and must not warm the burst pool
                counts[counts <= 1e-9] = 0.0
                if counts.any():
                    burst_viol = self.burst.offload(
                        tick, counts, q.slo_s, strict, led
                    )
                    self.served_burst_arch += counts
                    answered += counts
                    self.violations_arch += burst_viol
                    self.cost_arch += self.burst.cost_per_request * counts

        # abandon hopeless VM-only waiters (count violation once):
        # anything older than 3x its SLO is recorded and dropped so
        # queues cannot grow without bound under sustained shortfall.
        for q, strict in ((self.q_strict, True), (self.q_relaxed, False)):
            dropped_a = q.drop_expired(tick)
            dropped = float(dropped_a.sum())
            if dropped > 0:
                led.add_violations(dropped, dropped if strict else 0.0)
                led.add_served_vm(dropped)   # still answered, just very late
                self.dropped_arch += dropped_a
                self.violations_arch += dropped_a
                answered += dropped_a
                if tel is not None:
                    tel.on_drop(tick, strict, dropped_a)

        # delivered accuracy: every answered request carries the active
        # variant's accuracy; mass answered below the stream's floor is
        # an accuracy-SLO violation (conserved: the per-arch weights sum
        # to the ledger totals, tick by tick)
        acc_w = answered * self.cur_acc
        self.acc_weight_arch += acc_w
        led.add_accuracy(float(acc_w.sum()), float(answered.sum()))
        if self._acc_floor_live:
            acc_viol = answered * (self.cur_acc < self.acc_floor - 1e-12)
            if acc_viol.any():
                self.acc_viol_arch += acc_viol
                led.add_acc_violations(float(acc_viol.sum()))
        else:
            acc_viol = self._zero_arch
        if tel is not None:
            tel.on_accuracy(tick, acc_w, acc_viol)

        # accounting (cost attributed per arch as each tier posts — by
        # name, at the active variant's chip footprint; a new tier needs
        # no ledger changes beyond its registration above)
        chip_s = self.reserved.account(led, self.eff_chips)
        self.cost_arch += chip_s * self.reserved.price_per_chip_s()
        if tel is not None:
            tel.on_tier_cost(
                tick, "reserved",
                float(chip_s.sum()) * self.reserved.price_per_chip_s())
        for name, tier in self.aux_tiers.items():
            if self._tier_live[name]:
                t_chip_s = tier.account(led, self.eff_chips)
                self.cost_arch += t_chip_s * tier.price_per_chip_s()
                chip_s = chip_s + t_chip_s
                if tel is not None:
                    tel.on_tier_cost(
                        tick, name,
                        float(t_chip_s.sum()) * tier.price_per_chip_s())
        led.add_capacity(chip_s, self._rates, self.eff_throughput, self.eff_chips)
        if tel is not None:
            # mirror add_capacity's arithmetic exactly (reconciliation
            # compares these event magnitudes `==` against the ledger)
            need = np.ceil(self._rates / self.eff_throughput) * self.eff_chips
            tel.on_capacity(
                tick, float(chip_s.sum()), float(need.sum()),
                float(np.maximum(chip_s - need, 0.0).sum()))
            tel.end_tick(self, tick)

        self.tick += 1
        if self.done:
            self._finalize()
        self.last_viol_arch = self.violations_arch - viol0_arch
        return {
            "cost": res.cost_total - cost0,
            "violations": res.violations - viol0,
            "cost_arch": self.cost_arch - cost0_arch,
            "violations_arch": self.last_viol_arch.copy(),
            "accuracy": float(acc_w.sum()),
            "accuracy_arch": acc_w,
            "acc_violations": float(acc_viol.sum()),
            "acc_violations_arch": acc_viol,
        }

    def _finalize(self) -> None:
        # end-of-trace: whatever is still queued past its slack violates
        end = len(self.trace)
        for q, strict in ((self.q_strict, True), (self.q_relaxed, False)):
            late_a = q.pop_older_than_slack(end)
            late = float(late_a.sum())
            self.ledger.add_violations(late, late if strict else 0.0)
            self.violations_arch += late_a
            self.expired_end_arch += late_a
            if self.telemetry is not None:
                self.telemetry.on_expired(end, strict, late_a)

    def per_arch_counts(self) -> Dict[str, np.ndarray]:
        """Per-arch flow totals so far, each an ``[A]`` copy.

        ``arrived == served_vm + served_burst + dropped + expired_end +
        queued`` holds per arch after every tick (``dropped`` is the
        abandoned mass the ledger books as served-but-violated;
        ``expired_end`` is the still-queued late mass the end-of-trace
        sweep removes without serving)."""
        return {
            "arrived": self.arrived_arch.copy(),
            "served_vm": self.served_vm_arch.copy(),
            "served_burst": self.served_burst_arch.copy(),
            "dropped": self.dropped_arch.copy(),
            "expired_end": self.expired_end_arch.copy(),
            "violations": self.violations_arch.copy(),
            "queued": self.q_strict.totals() + self.q_relaxed.totals(),
            # the accuracy axis (answered == served_vm + served_burst +
            # dropped; acc_weight / answered is delivered accuracy)
            "acc_weight": self.acc_weight_arch.copy(),
            "acc_violations": self.acc_viol_arch.copy(),
        }

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        queued = self.q_strict.totals() + self.q_relaxed.totals()
        return {
            "t": self.tick,
            "rate": float(self.trace[min(self.tick, len(self.trace) - 1)]),
            "active": {
                k: int(self.reserved.active[i]) for i, k in enumerate(self.keys)
            },
            "queued": {k: float(queued[i]) for i, k in enumerate(self.keys)},
        }


def simulate(
    trace: np.ndarray,                       # [T] pool req/s or [A, T] matrix
    workload: List[ArchLoad],
    policy,                                  # Policy or VectorPolicy
    *,
    pricing: FleetPricing = PRICING,
    prewarm: bool = True,
    warm_start: bool = True,                 # fleet starts sized for t=0 load
    record_timeline: bool = False,
    catalog: Optional[VariantCatalog] = None,
    telemetry: Optional["Telemetry"] = None,
) -> SimResult:
    """Closed-loop run: the policy drives :class:`ServingSim` over the trace.

    ``trace`` may be a 1-D pool trace (fanned out by ``share``) or a 2-D
    per-arch arrival matrix from :mod:`repro.core.workloads` (e.g.
    ``Scenario.build(len(workload))``).  Policies with a truthy
    ``vectorized`` attribute get the SoA interface (``PoolObs ->
    PoolAction``); everything else gets the dict interface.  ``catalog``
    opens the model-variant axis (runtime swaps via
    ``PoolAction.variant_target`` / ``Action.variant``).
    """
    sim = ServingSim(
        trace, workload, pricing=pricing, prewarm=prewarm,
        warm_start=warm_start, catalog=catalog, telemetry=telemetry,
    )
    vectorized = bool(getattr(policy, "vectorized", False))
    while not sim.done:
        if vectorized:
            pobs = sim.observe_pool()
            action = policy(sim.tick, pobs)
            if record_timeline:
                sim.res.timeline.append(sim.snapshot())
            sim.apply_pool(action)
        else:
            obs = sim.observe()
            actions = policy(sim.tick, obs)
            if record_timeline:
                sim.res.timeline.append(sim.snapshot())
            sim.apply(actions)
    return sim.res
