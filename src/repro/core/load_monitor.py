"""Load monitor (paper §III-B2).

Watches the arrival stream in sliding sampling windows and exposes the
statistics the procurement policies plug into: smoothed rate (EWMA),
windowed peak, and the peak-to-median ratio that Observation 4 says
predicts whether mixed procurement pays off.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

import numpy as np


@dataclass
class LoadMonitor:
    window_s: int = 300
    ewma_alpha: float = 0.3
    _hist: Deque[float] = field(default_factory=deque)
    _ewma: Optional[float] = None

    def observe(self, rate: float) -> None:
        self._hist.append(float(rate))
        while len(self._hist) > self.window_s:
            self._hist.popleft()
        self._ewma = (
            rate
            if self._ewma is None
            else self.ewma_alpha * rate + (1 - self.ewma_alpha) * self._ewma
        )

    @property
    def rate(self) -> float:
        """Smoothed current arrival rate (req/s)."""
        return self._ewma or 0.0

    @property
    def peak(self) -> float:
        return max(self._hist) if self._hist else 0.0

    @property
    def median(self) -> float:
        return float(np.median(self._hist)) if self._hist else 0.0

    @property
    def peak_to_median(self) -> float:
        """Observation-4 statistic over the sampling window."""
        m = self.median
        return self.peak / m if m > 0 else 1.0

    def bursty(self, threshold: float = 1.5) -> bool:
        """True when the window shows spike structure worth offloading."""
        return len(self._hist) >= self.window_s // 4 and self.peak_to_median >= threshold
