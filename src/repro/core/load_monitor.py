"""Load monitor (paper §III-B2).

Watches the arrival stream in sliding sampling windows and exposes the
statistics the procurement policies plug into: smoothed rate (EWMA),
windowed peak, and the peak-to-median ratio that Observation 4 says
predicts whether mixed procurement pays off.

Two implementations of the same contract:

:class:`LoadMonitor`
    The seed scalar monitor — one arrival stream, a deque window.

:class:`PoolLoadMonitor`
    The vectorized streaming counterpart for heterogeneous per-arch
    arrival matrices: every arch keeps its own EWMA and sliding window
    in one ``[A, W]`` ring buffer.  Window order statistics (peak and
    the two middle ranks the median needs) are maintained
    *incrementally*: each arch carries a small sorted **band** of
    consecutive order statistics around the median, so a steady-state
    tick is O(A) classification work plus tiny ``[n, band]`` edits —
    the full ``[A, W]`` pass survives only in the rare re-centering
    refill.  (The previous implementation recomputed ``np.median`` over
    the whole window every tick: O(A*W) partition work per tick, the
    pool-scale hot spot `sim_throughput.py` now benchmarks at A=256.)
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

import numpy as np


@dataclass
class LoadMonitor:
    window_s: int = 300
    ewma_alpha: float = 0.3
    _hist: Deque[float] = field(default_factory=deque)
    _ewma: Optional[float] = None

    def observe(self, rate: float) -> None:
        self._hist.append(float(rate))
        while len(self._hist) > self.window_s:
            self._hist.popleft()
        self._ewma = (
            rate
            if self._ewma is None
            else self.ewma_alpha * rate + (1 - self.ewma_alpha) * self._ewma
        )

    @property
    def rate(self) -> float:
        """Smoothed current arrival rate (req/s)."""
        return self._ewma or 0.0

    @property
    def peak(self) -> float:
        return max(self._hist) if self._hist else 0.0

    @property
    def median(self) -> float:
        return float(np.median(self._hist)) if self._hist else 0.0

    @property
    def peak_to_median(self) -> float:
        """Observation-4 statistic over the sampling window."""
        m = self.median
        return self.peak / m if m > 0 else 1.0

    def bursty(self, threshold: float = 1.5) -> bool:
        """True when the window shows spike structure worth offloading."""
        return len(self._hist) >= self.window_s // 4 and self.peak_to_median >= threshold


class PoolLoadMonitor:
    """Per-arch load statistics over a pool, vectorized and streaming.

    Semantically one :class:`LoadMonitor` per architecture; all A windows
    live in a single ``[A, W]`` ring buffer.  Built for heterogeneous
    arrival matrices (:mod:`repro.core.workloads`), where each arch's
    stream has its own burst structure and the share-invariant trick the
    engine uses for a single pool trace (every arch = share x pool) no
    longer holds.

    **Incremental order statistics.**  Once a window is full, each row
    maintains

    * a running ``peak`` (grown with each arrival; recomputed for the
      ~1/W of rows whose *leaving* sample was the peak), and
    * a sorted *band* — the ``<= band_width`` consecutive window order
      statistics ``start_rank .. start_rank + n - 1`` bracketing the two
      middle ranks the median averages.  A tick classifies the leaving
      and arriving samples against the band edges in O(A); samples
      landing inside the band trigger an ``[n, band_width]`` insert /
      delete on just those rows; samples below the band only shift
      ``start_rank``.  When drift or shrinkage pushes the middle ranks
      out of the band, the affected rows (rare — drift must cross the
      band margin) are refilled with one sort of their window.

    Results are *bit-identical* to the per-row :class:`LoadMonitor`
    (``tests/test_workloads.py`` asserts it); the first ``window_s - 1``
    ticks use growing windows, matching the filling deque, and fall back
    to direct reductions while ranks still move with the window length.
    """

    def __init__(self, n_archs: int, window_s: int = LoadMonitor.window_s,
                 ewma_alpha: float = LoadMonitor.ewma_alpha, *,
                 band_width: int = 32, incremental: bool = True):
        self.window_s = int(window_s)
        self.ewma_alpha = float(ewma_alpha)
        self.buf = np.zeros((n_archs, self.window_s), dtype=np.float64)
        self.ewma = np.zeros(n_archs, dtype=np.float64)
        self._seen = 0
        # the two middle (0-indexed) ranks np.median averages
        self._k1 = (self.window_s - 1) // 2
        self._k2 = self.window_s // 2
        self.incremental = bool(incremental)
        self._B = max(int(band_width), (self._k2 - self._k1 + 1) + 4)
        self._rows = np.arange(n_archs)
        self._band = np.full((n_archs, self._B), np.inf)
        self._nb = np.zeros(n_archs, dtype=np.int64)     # valid band entries
        self._sr = np.zeros(n_archs, dtype=np.int64)     # rank of band[:, 0]
        self._peak = np.zeros(n_archs, dtype=np.float64)
        self._median = np.zeros(n_archs, dtype=np.float64)

    @property
    def filled(self) -> int:
        """How many window columns hold real observations."""
        return min(self._seen, self.window_s)

    # -- band primitives (sub: [n, B] rows, inf-padded past the count) -----
    @staticmethod
    def _band_delete(sub: np.ndarray, pos: np.ndarray) -> np.ndarray:
        """Drop the element at per-row ``pos``, shift left, pad with inf."""
        n, B = sub.shape
        tmp = np.concatenate([sub, np.full((n, 1), np.inf)], axis=1)
        j = np.arange(B)[None, :]
        return np.take_along_axis(tmp, j + (j >= pos[:, None]), axis=1)

    @staticmethod
    def _band_insert(sub: np.ndarray, pos: np.ndarray, val: np.ndarray) -> np.ndarray:
        """Insert ``val`` at per-row ``pos``, shift right (top falls off)."""
        _, B = sub.shape
        j = np.arange(B)[None, :]
        out = np.take_along_axis(sub, np.maximum(j - (j > pos[:, None]), 0), axis=1)
        np.put_along_axis(out, pos[:, None], val[:, None], axis=1)
        return out

    def _refill(self, idx: np.ndarray) -> None:
        """Rebuild band + peak for ``idx`` rows from their full windows."""
        if idx.size == 0:
            return
        margin = (self._B - (self._k2 - self._k1 + 1)) // 2
        lo = max(self._k1 - margin, 0)
        hi = min(self._k2 + margin, self.window_s - 1)
        sub = np.sort(self.buf[idx], axis=1)
        self._band[idx] = np.inf
        self._band[idx, : hi - lo + 1] = sub[:, lo: hi + 1]
        self._nb[idx] = hi - lo + 1
        self._sr[idx] = lo
        self._peak[idx] = sub[:, -1]

    def _steady_update(self, out: np.ndarray, new: np.ndarray) -> None:
        band, nb, sr = self._band, self._nb, self._sr
        rows = self._rows
        # ---- remove the leaving sample from the order statistics --------
        b0 = band[:, 0]
        btop = band[rows, np.maximum(nb - 1, 0)]
        below = out < b0
        sr -= below
        inside = (~below) & (out <= btop) & (nb > 0)
        idx = np.flatnonzero(inside)
        if idx.size:
            sub = band[idx]
            band[idx] = self._band_delete(sub, (sub < out[idx, None]).sum(axis=1))
            nb[idx] -= 1
        # ---- insert the arriving sample ---------------------------------
        b0 = band[:, 0]
        btop = band[rows, np.maximum(nb - 1, 0)]
        below = (new < b0) & (nb > 0)
        sr += below
        inside = (~below) & (new <= btop) & (nb > 0)
        idx = np.flatnonzero(inside)
        if idx.size:
            # full bands drop one end; dropping left means start_rank += 1,
            # pick the side with more slack around the tracked ranks
            over = nb[idx] == self._B
            drop_left = over & (self._k1 - sr[idx] >= sr[idx] + nb[idx] - 1 - self._k2)
            if drop_left.any():
                di = idx[drop_left]
                band[di] = self._band_delete(
                    band[di], np.zeros(drop_left.sum(), np.int64)
                )
                nb[di] -= 1
                sr[di] += 1
            sub = band[idx]
            band[idx] = self._band_insert(
                sub, (sub < new[idx, None]).sum(axis=1), new[idx]
            )
            nb[idx] = np.minimum(nb[idx] + 1, self._B)
        # ---- peak: grows with arrivals; recompute only the rows whose
        # leaving sample was (possibly) the unique window max
        stale = (out >= self._peak) & (out > new)
        np.maximum(self._peak, new, out=self._peak)
        idx = np.flatnonzero(stale)
        if idx.size:
            self._peak[idx] = self.buf[idx].max(axis=1)
        # ---- re-center rows whose band no longer brackets the medians ---
        bad = (sr > self._k1) | (sr + nb - 1 < self._k2) | (nb <= 0)
        self._refill(np.flatnonzero(bad))
        self._median = 0.5 * (
            band[rows, self._k1 - sr] + band[rows, self._k2 - sr]
        )

    def observe(self, rates: np.ndarray) -> None:
        """Record one tick's per-arch arrival rates (``rates[a]``)."""
        rates = np.asarray(rates, dtype=np.float64)
        col = self._seen % self.window_s
        full = self._seen >= self.window_s
        out = self.buf[:, col].copy() if full else None
        self.buf[:, col] = rates
        self.ewma = (
            rates.copy() if self._seen == 0
            else self.ewma_alpha * rates + (1 - self.ewma_alpha) * self.ewma
        )
        self._seen += 1
        if not self.incremental:
            return
        if full:
            self._steady_update(out, rates)
        elif self._seen == self.window_s:
            self._refill(self._rows)
            band, sr = self._band, self._sr
            self._median = 0.5 * (
                band[self._rows, self._k1 - sr]
                + band[self._rows, self._k2 - sr]
            )

    def _steady(self) -> bool:
        return self.incremental and self._seen >= self.window_s

    @property
    def rate(self) -> np.ndarray:
        """Smoothed per-arch arrival rate (req/s), ``[A]``."""
        return self.ewma

    @property
    def peak(self) -> np.ndarray:
        if self._steady():
            return self._peak
        f = self.filled
        if f == 0:
            return np.zeros(self.buf.shape[0])
        return self.buf[:, :f].max(axis=1)

    @property
    def median(self) -> np.ndarray:
        if self._steady():
            return self._median
        f = self.filled
        if f == 0:
            return np.zeros(self.buf.shape[0])
        return np.median(self.buf[:, :f], axis=1)

    def stats(self) -> tuple:
        """One-pass snapshot ``(ewma, peak, median, peak_to_median)``,
        each ``[A]`` — what a per-tick consumer (the engine) wants.  In
        the steady state these are O(A) reads of the incrementally
        maintained statistics."""
        peak, med = self.peak, self.median
        p2m = np.where(med > 0, peak / np.where(med > 0, med, 1.0), 1.0)
        return self.ewma, peak, med, p2m

    @property
    def peak_to_median(self) -> np.ndarray:
        """Observation-4 statistic per arch, ``[A]``."""
        return self.stats()[3]

    def bursty(self, threshold: float = 1.5) -> np.ndarray:
        """Boolean ``[A]``: archs whose window shows spike structure."""
        if self.filled < self.window_s // 4:
            return np.zeros(self.buf.shape[0], dtype=bool)
        return self.peak_to_median >= threshold


def pool_stats_trajectory(
    arrivals: np.ndarray, *, window_s: int = LoadMonitor.window_s,
    ewma_alpha: float = LoadMonitor.ewma_alpha,
) -> tuple:
    """Functional form of the monitor: the full per-tick statistics
    trajectory for a known ``[A, T]`` arrival matrix.

    The monitor's outputs are a pure function of the arrival stream —
    independent of policy and fleet state — so the batched JAX engine
    (``sim/jax_engine.py``) materializes them up front and feeds them
    into ``lax.scan`` as inputs instead of carrying the order-statistic
    machinery as traced state.  Returns ``(ewma, peak, p2m)``, each
    ``[T, A]``, bit-identical to calling ``observe``/``stats`` tick by
    tick (it *is* that loop, run against the streaming monitor).
    """
    arrivals = np.asarray(arrivals, dtype=np.float64)
    n_archs, ticks = arrivals.shape
    mon = PoolLoadMonitor(n_archs, window_s=window_s, ewma_alpha=ewma_alpha)
    ewma = np.empty((ticks, n_archs), dtype=np.float64)
    peak = np.empty((ticks, n_archs), dtype=np.float64)
    p2m = np.empty((ticks, n_archs), dtype=np.float64)
    for t in range(ticks):
        mon.observe(arrivals[:, t])
        e, pk, _, pm = mon.stats()
        ewma[t] = e
        peak[t] = pk
        p2m[t] = pm
    return ewma, peak, p2m
