"""Load monitor (paper §III-B2).

Watches the arrival stream in sliding sampling windows and exposes the
statistics the procurement policies plug into: smoothed rate (EWMA),
windowed peak, and the peak-to-median ratio that Observation 4 says
predicts whether mixed procurement pays off.

Two implementations of the same contract:

:class:`LoadMonitor`
    The seed scalar monitor — one arrival stream, a deque window.

:class:`PoolLoadMonitor`
    The vectorized streaming counterpart for heterogeneous per-arch
    arrival matrices: every arch keeps its own EWMA and sliding window
    as one ``[A, W]`` ring buffer, so a pool-wide observation is O(A*W)
    NumPy work per tick with no per-arch Python.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

import numpy as np


@dataclass
class LoadMonitor:
    window_s: int = 300
    ewma_alpha: float = 0.3
    _hist: Deque[float] = field(default_factory=deque)
    _ewma: Optional[float] = None

    def observe(self, rate: float) -> None:
        self._hist.append(float(rate))
        while len(self._hist) > self.window_s:
            self._hist.popleft()
        self._ewma = (
            rate
            if self._ewma is None
            else self.ewma_alpha * rate + (1 - self.ewma_alpha) * self._ewma
        )

    @property
    def rate(self) -> float:
        """Smoothed current arrival rate (req/s)."""
        return self._ewma or 0.0

    @property
    def peak(self) -> float:
        return max(self._hist) if self._hist else 0.0

    @property
    def median(self) -> float:
        return float(np.median(self._hist)) if self._hist else 0.0

    @property
    def peak_to_median(self) -> float:
        """Observation-4 statistic over the sampling window."""
        m = self.median
        return self.peak / m if m > 0 else 1.0

    def bursty(self, threshold: float = 1.5) -> bool:
        """True when the window shows spike structure worth offloading."""
        return len(self._hist) >= self.window_s // 4 and self.peak_to_median >= threshold


class PoolLoadMonitor:
    """Per-arch load statistics over a pool, vectorized and streaming.

    Semantically one :class:`LoadMonitor` per architecture, but all A
    windows live in a single ``[A, W]`` ring buffer and every statistic
    is one NumPy reduction over it.  Built for heterogeneous arrival
    matrices (:mod:`repro.core.workloads`), where each arch's stream has
    its own burst structure and the share-invariant trick the engine
    uses for a single pool trace (every arch = share x pool) no longer
    holds.

    The first ``window_s - 1`` ticks use growing windows, matching
    :class:`LoadMonitor`'s filling deque.
    """

    def __init__(self, n_archs: int, window_s: int = LoadMonitor.window_s,
                 ewma_alpha: float = LoadMonitor.ewma_alpha):
        self.window_s = int(window_s)
        self.ewma_alpha = float(ewma_alpha)
        self.buf = np.zeros((n_archs, self.window_s), dtype=np.float64)
        self.ewma = np.zeros(n_archs, dtype=np.float64)
        self._seen = 0

    @property
    def filled(self) -> int:
        """How many window columns hold real observations."""
        return min(self._seen, self.window_s)

    def observe(self, rates: np.ndarray) -> None:
        """Record one tick's per-arch arrival rates (``rates[a]``)."""
        rates = np.asarray(rates, dtype=np.float64)
        self.buf[:, self._seen % self.window_s] = rates
        self.ewma = (
            rates.copy() if self._seen == 0
            else self.ewma_alpha * rates + (1 - self.ewma_alpha) * self.ewma
        )
        self._seen += 1

    @property
    def rate(self) -> np.ndarray:
        """Smoothed per-arch arrival rate (req/s), ``[A]``."""
        return self.ewma

    @property
    def peak(self) -> np.ndarray:
        f = self.filled
        if f == 0:
            return np.zeros(self.buf.shape[0])
        return self.buf[:, :f].max(axis=1)

    @property
    def median(self) -> np.ndarray:
        f = self.filled
        if f == 0:
            return np.zeros(self.buf.shape[0])
        return np.median(self.buf[:, :f], axis=1)

    def stats(self) -> tuple:
        """One-pass snapshot ``(ewma, peak, median, peak_to_median)``,
        each ``[A]`` — what a per-tick consumer (the engine) wants,
        computing the window reductions exactly once."""
        peak, med = self.peak, self.median
        p2m = np.where(med > 0, peak / np.where(med > 0, med, 1.0), 1.0)
        return self.ewma, peak, med, p2m

    @property
    def peak_to_median(self) -> np.ndarray:
        """Observation-4 statistic per arch, ``[A]``."""
        return self.stats()[3]

    def bursty(self, threshold: float = 1.5) -> np.ndarray:
        """Boolean ``[A]``: archs whose window shows spike structure."""
        if self.filled < self.window_s // 4:
            return np.zeros(self.buf.shape[0], dtype=bool)
        return self.peak_to_median >= threshold
