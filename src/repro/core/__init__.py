"""The paper's contribution: a self-managed ML inference serving system.

Layers:
  hardware         — TPU v5e machine model + fleet pricing (VM/serverless analog)
  profiles         — derived offline-profiling table (latency/accuracy/cost)
  traces           — statistical twins of the four request-arrival traces
  load_monitor     — windowed peak-to-median estimation (Observation 4)
  sim              — trace-driven serving simulation package: vectorized
                     queues, resource tiers (reserved/spot/burst), ledger,
                     and the tick engine (simulator.py is a compat shim)
  workloads        — heterogeneous per-arch arrival matrices: scenario
                     generators (diurnal / flash crowds / MMPP / hotswap)
                     and the declarative seeded Scenario spec
  schedulers       — reactive / util_aware / exascale / mixed / paragon
  model_selection  — naive vs paragon (least-cost under constraints)
  rl               — PPO controller (§V, implemented beyond the paper)
"""
from repro.core.hardware import PRICING, V5E, ChipSpec, FleetPricing  # noqa: F401
from repro.core.load_monitor import LoadMonitor, PoolLoadMonitor  # noqa: F401
from repro.core.model_selection import (  # noqa: F401
    Constraint,
    select_naive,
    select_paragon,
    selection_cost,
)
from repro.core.profiles import (  # noqa: F401
    ModelProfile,
    RequestClass,
    get_profile,
    iso_accuracy_set,
    iso_latency_set,
    model_pool,
)
from repro.core.schedulers import SCHEDULERS, get_scheduler  # noqa: F401
from repro.core.sim import (  # noqa: F401
    Action,
    ArchLoad,
    ArchObs,
    PoolAction,
    PoolObs,
    SimResult,
    Variant,
    VariantCatalog,
    replicate_pool,
    simulate,
    uniform_pool_workload,
)
from repro.core.traces import TRACES, get_trace, peak_to_median, trace_stats  # noqa: F401
from repro.core.workloads import (  # noqa: F401
    SCENARIO_ZOO,
    Scenario,
    from_pool_trace,
    get_scenario,
)
