"""Declarative, seeded, serializable workload scenarios.

A :class:`Scenario` names a generator (:data:`~repro.core.workloads.generators.GENERATORS`),
its parameters, a duration, a pool-mean rate, and a seed — everything a
benchmark, test, or RL env needs to rebuild the exact same ``[A, T]``
arrival matrix, as a plain dict/JSON round-trippable record:

    sc = Scenario("flash", kind="flash_crowd", params={"mode": "anti"})
    arrivals = sc.build(n_archs=8)          # [8, 3600], deterministic
    sc2 = Scenario.from_json(sc.to_json())  # == sc

The :data:`SCENARIO_ZOO` holds the named presets the scenario-grid
benchmark and the examples run: one shared-trace baseline plus the
heterogeneous shapes (phase-shifted diurnals, correlated / anti-correlated
flash crowds, MMPP bursts, trending-model hotswap) that share scaling
cannot express.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional

import numpy as np

from repro.core.workloads.generators import GENERATORS

DEFAULT_DURATION_S = 3600
DEFAULT_MEAN_RPS = 100.0


@dataclass(frozen=True)
class Scenario:
    """A named, seeded recipe for a per-arch arrival matrix."""

    name: str
    kind: str                                  # key into GENERATORS
    duration_s: int = DEFAULT_DURATION_S
    mean_rps: float = DEFAULT_MEAN_RPS         # pool mean (req/s)
    seed: int = 0
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        assert self.kind in GENERATORS, (
            f"unknown scenario kind {self.kind!r}; have {sorted(GENERATORS)}"
        )

    # -- building -----------------------------------------------------------
    def build(self, n_archs: int, *, seed: Optional[int] = None,
              duration_s: Optional[int] = None,
              mean_rps: Optional[float] = None) -> np.ndarray:
        """Materialize the ``[n_archs, duration_s]`` arrival matrix.

        ``seed`` (and the other overrides) re-roll one realization
        without mutating the spec — the RL env uses this to sample a
        fresh episode from the same scenario family.
        """
        gen = GENERATORS[self.kind]
        mat = gen(
            n_archs,
            int(self.duration_s if duration_s is None else duration_s),
            float(self.mean_rps if mean_rps is None else mean_rps),
            int(self.seed if seed is None else seed),
            **dict(self.params),
        )
        assert mat.shape[0] == n_archs
        return mat

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "duration_s": self.duration_s,
            "mean_rps": self.mean_rps,
            "seed": self.seed,
            "params": dict(self.params),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Scenario":
        return cls(
            name=d["name"],
            kind=d["kind"],
            duration_s=int(d.get("duration_s", DEFAULT_DURATION_S)),
            mean_rps=float(d.get("mean_rps", DEFAULT_MEAN_RPS)),
            seed=int(d.get("seed", 0)),
            params=dict(d.get("params", {})),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Named presets.
# ---------------------------------------------------------------------------
SCENARIO_ZOO: Dict[str, Scenario] = {
    sc.name: sc
    for sc in (
        # today's behavior: one pool trace, static share
        Scenario("shared_berkeley", kind="pool_trace",
                 params={"trace": "berkeley"}),
        # regions in different time zones: arch peaks spread over the cycle
        Scenario("diurnal_phases", kind="diurnal",
                 params={"phase_jitter": 1.0, "amp_jitter": 0.5}),
        # a launch event hits half the pool at once
        Scenario("flash_correlated", kind="flash_crowd",
                 params={"mode": "correlated", "n_events": 3}),
        # attention shifts: one model trends while the others drain
        Scenario("flash_anti", kind="flash_crowd",
                 params={"mode": "anti", "n_events": 3, "dip": 0.6}),
        # decorrelated heavy-tailed bursts per arch
        Scenario("mmpp_bursts", kind="mmpp",
                 params={"burst_mult": 4.0}),
        # trending-model popularity migration over a smooth pool trace
        Scenario("trending_hotswap", kind="hotswap",
                 params={"n_shifts": 3, "boost": 5.0}),
    )
}


def get_scenario(name: str) -> Scenario:
    return SCENARIO_ZOO[name]
