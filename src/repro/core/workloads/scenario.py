"""Declarative, seeded, serializable workload scenarios.

A :class:`Scenario` names a generator (:data:`~repro.core.workloads.generators.GENERATORS`),
its parameters, a duration, a pool-mean rate, and a seed — everything a
benchmark, test, or RL env needs to rebuild the exact same ``[A, T]``
arrival matrix, as a plain dict/JSON round-trippable record:

    sc = Scenario("flash", kind="flash_crowd", params={"mode": "anti"})
    arrivals = sc.build(n_archs=8)          # [8, 3600], deterministic
    sc2 = Scenario.from_json(sc.to_json())  # == sc

**Composition** (``kind="compose"``): a scenario may combine *child*
scenarios (serialized inline as dicts in ``params["children"]``) by

* ``op="sum"`` — a weighted mix of the children's matrices (weights
  normalized, so ``mean_rps`` stays the pool mean), or
* ``op="splice"`` — a time-splice: child k owns the trace segment
  between consecutive ``splits`` fractions (children are built over the
  full duration and sliced, so their internal time structure — diurnal
  phase, event times — stays aligned with the clock).

Seed overrides propagate to children as a *delta* against the parent's
spec seed, so re-rolling a composed scenario (the RL env samples a fresh
realization per episode) re-rolls every child coherently.

The :data:`SCENARIO_ZOO` holds the named presets the scenario-grid
benchmark and the examples run: one shared-trace baseline plus the
heterogeneous shapes (phase-shifted diurnals, correlated / anti-correlated
flash crowds, MMPP bursts, trending-model hotswap, a diurnal/flash-crowd
splice) that share scaling cannot express.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional

import numpy as np

from repro.core.workloads.generators import GENERATORS

DEFAULT_DURATION_S = 3600
DEFAULT_MEAN_RPS = 100.0

#: the pseudo-kind that combines child scenarios (not a row generator)
COMPOSE_KIND = "compose"


@dataclass(frozen=True)
class Scenario:
    """A named, seeded recipe for a per-arch arrival matrix."""

    name: str
    kind: str                                  # key into GENERATORS
    duration_s: int = DEFAULT_DURATION_S
    mean_rps: float = DEFAULT_MEAN_RPS         # pool mean (req/s)
    seed: int = 0
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.kind == COMPOSE_KIND:
            children = self.params.get("children", ())
            assert len(children) >= 2, "compose needs >= 2 children"
            op = self.params.get("op", "sum")
            assert op in ("sum", "splice"), f"unknown compose op {op!r}"
            kids = [Scenario.from_dict(c) for c in children]   # validates kinds
            if op == "sum":
                w = self.params.get("weights")
                assert w is None or len(w) == len(kids)
            else:
                splits = self.params.get("splits")
                assert splits is None or (
                    len(splits) == len(kids) - 1
                    and all(0.0 < s < 1.0 for s in splits)
                    and list(splits) == sorted(splits)
                ), f"bad splice splits {splits!r}"
            return
        assert self.kind in GENERATORS, (
            f"unknown scenario kind {self.kind!r}; have "
            f"{sorted(GENERATORS) + [COMPOSE_KIND]}"
        )

    # -- building -----------------------------------------------------------
    def build(self, n_archs: int, *, seed: Optional[int] = None,
              duration_s: Optional[int] = None,
              mean_rps: Optional[float] = None) -> np.ndarray:
        """Materialize the ``[n_archs, duration_s]`` arrival matrix.

        ``seed`` (and the other overrides) re-roll one realization
        without mutating the spec — the RL env uses this to sample a
        fresh episode from the same scenario family.
        """
        eff_seed = int(self.seed if seed is None else seed)
        eff_dur = int(self.duration_s if duration_s is None else duration_s)
        eff_rps = float(self.mean_rps if mean_rps is None else mean_rps)
        if self.kind == COMPOSE_KIND:
            return self._build_composed(n_archs, eff_seed, eff_dur, eff_rps)
        gen = GENERATORS[self.kind]
        mat = gen(n_archs, eff_dur, eff_rps, eff_seed, **dict(self.params))
        assert mat.shape[0] == n_archs
        return mat

    def _build_composed(self, n_archs: int, seed: int, duration_s: int,
                        mean_rps: float) -> np.ndarray:
        """Sum or time-splice the children's ``[A, T]`` realizations."""
        delta = seed - self.seed          # override propagates as a delta
        kids = [Scenario.from_dict(c) for c in self.params["children"]]
        mats = [
            k.build(n_archs, seed=k.seed + delta, duration_s=duration_s,
                    mean_rps=mean_rps)
            for k in kids
        ]
        if self.params.get("op", "sum") == "sum":
            w = self.params.get("weights")
            w = (np.full(len(kids), 1.0 / len(kids)) if w is None
                 else np.asarray(w, dtype=np.float64))
            w = w / w.sum()
            return sum(wk * m for wk, m in zip(w, mats))
        # splice: child k owns [bounds[k], bounds[k+1])
        splits = self.params.get("splits")
        if splits is None:
            splits = [(i + 1) / len(kids) for i in range(len(kids) - 1)]
        bounds = [0] + [int(round(s * duration_s)) for s in splits] + [duration_s]
        out = np.empty((n_archs, duration_s))
        for m, lo, hi in zip(mats, bounds[:-1], bounds[1:]):
            out[:, lo:hi] = m[:, lo:hi]
        return out

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "duration_s": self.duration_s,
            "mean_rps": self.mean_rps,
            "seed": self.seed,
            "params": dict(self.params),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "Scenario":
        return cls(
            name=d["name"],
            kind=d["kind"],
            duration_s=int(d.get("duration_s", DEFAULT_DURATION_S)),
            mean_rps=float(d.get("mean_rps", DEFAULT_MEAN_RPS)),
            seed=int(d.get("seed", 0)),
            params=dict(d.get("params", {})),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "Scenario":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Named presets.
# ---------------------------------------------------------------------------
SCENARIO_ZOO: Dict[str, Scenario] = {
    sc.name: sc
    for sc in (
        # today's behavior: one pool trace, static share
        Scenario("shared_berkeley", kind="pool_trace",
                 params={"trace": "berkeley"}),
        # regions in different time zones: arch peaks spread over the cycle
        Scenario("diurnal_phases", kind="diurnal",
                 params={"phase_jitter": 1.0, "amp_jitter": 0.5}),
        # a launch event hits half the pool at once
        Scenario("flash_correlated", kind="flash_crowd",
                 params={"mode": "correlated", "n_events": 3}),
        # attention shifts: one model trends while the others drain
        Scenario("flash_anti", kind="flash_crowd",
                 params={"mode": "anti", "n_events": 3, "dip": 0.6}),
        # decorrelated heavy-tailed bursts per arch
        Scenario("mmpp_bursts", kind="mmpp",
                 params={"burst_mult": 4.0}),
        # trending-model popularity migration over a smooth pool trace
        Scenario("trending_hotswap", kind="hotswap",
                 params={"n_shifts": 3, "boost": 5.0}),
        # composed: a diurnal first half splicing into an afternoon of
        # anti-correlated flash crowds (attention shifts mid-day)
        Scenario("diurnal_flash_splice", kind=COMPOSE_KIND,
                 params={
                     "op": "splice",
                     "splits": [0.5],
                     "children": [
                         Scenario("base", kind="diurnal",
                                  params={"phase_jitter": 0.6,
                                          "amp_jitter": 0.4}).to_dict(),
                         Scenario("crowd", kind="flash_crowd",
                                  params={"mode": "anti", "n_events": 3,
                                          "dip": 0.6}, seed=1).to_dict(),
                     ],
                 }),
    )
}


def get_scenario(name: str) -> Scenario:
    return SCENARIO_ZOO[name]
