"""Per-arch arrival-matrix generators.

Every generator returns an ``[A, T]`` float64 matrix: row ``a`` is the
per-second request rate arch ``a`` sees over ``duration_s`` ticks.  The
pool-trace engine path (one shared trace scaled by a static ``share``)
can only express perfectly correlated load; these generators produce the
heterogeneous shapes the paper's self-managed system must react to
(Fig 7 trace diversity, Observation 4's peak-to-median dependence):

``from_pool_trace``
    Adapter reproducing today's behavior exactly — ``share[a] * trace[t]``,
    bit-identical to the engine's internal share scaling.
``diurnal``
    Per-arch diurnal cycles with independent phase and amplitude jitter
    (regions in different time zones: pool load flattens, arch load
    does not).
``flash_crowd``
    Flash crowds on a flat-ish base, in three correlation modes:
    ``correlated`` (an event hits a random subset of archs at once),
    ``anti`` (attention shifts — one arch spikes while the rest dip),
    and ``solo`` (one arch spikes, the others idle on).
``mmpp``
    Per-arch Markov-modulated bursts: each arch alternates quiet/burst
    sojourns (geometric durations) with Pareto-amplitude burst rates —
    the heavy-tailed structure of the WITS/Twitter twins, decorrelated
    across archs.
``hotswap``
    "Trending model" popularity shifts: pool demand rides a smooth
    diurnal, but its split over archs drifts — at each shift event one
    arch's weight logistic-ramps toward dominance while the rest
    renormalize (INFaaS-style variant churn).

Normalization: each row is scaled so arch ``a``'s mean rate is
``weights[a] * mean_rps`` (uniform weights by default), i.e. ``mean_rps``
is always the *pool* mean — scenarios are cost-comparable.  All
generators are seeded and deterministic.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.traces import get_trace


def _weights(n_archs: int, weights: Optional[Sequence[float]]) -> np.ndarray:
    if weights is None:
        return np.full(n_archs, 1.0 / n_archs)
    w = np.asarray(weights, dtype=np.float64)
    assert w.shape == (n_archs,) and (w >= 0).all()
    return w / max(w.sum(), 1e-12)


def _normalize_pool(mat: np.ndarray, mean_rps: float,
                    weights: np.ndarray) -> np.ndarray:
    """Scale each row to its share of the pool mean, clipping negatives."""
    mat = np.maximum(mat, 0.0)
    row_mean = np.maximum(mat.mean(axis=1), 1e-9)
    return mat * (mean_rps * weights / row_mean)[:, None]


# ---------------------------------------------------------------------------
# The adapter: today's shared-trace behavior as an arrival matrix.
# ---------------------------------------------------------------------------
def from_pool_trace(trace: np.ndarray, share: Sequence[float]) -> np.ndarray:
    """``arrivals[a, t] = share[a] * trace[t]`` — the exact fan-out the
    engine applies internally to a 1-D pool trace, exposed as a matrix so
    the per-arch path can reproduce shared-trace runs."""
    trace = np.asarray(trace, dtype=np.float64)
    share = np.asarray(share, dtype=np.float64)
    assert trace.ndim == 1 and share.ndim == 1
    return share[:, None] * trace[None, :]


# ---------------------------------------------------------------------------
# Heterogeneous generators.
# ---------------------------------------------------------------------------
def diurnal(n_archs: int, duration_s: int, mean_rps: float, seed: int, *,
            amplitude: float = 0.45, amp_jitter: float = 0.4,
            phase_jitter: float = 1.0, cycles: float = 1.0,
            noise_shape: float = 40.0,
            weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Per-arch diurnal with phase/amplitude jitter.

    ``phase_jitter`` in [0, 1] scales a uniform [-pi, pi] per-arch phase
    offset: 0 means every arch peaks together (the pool-trace limit), 1
    spreads the peaks around the full cycle.
    """
    rng = np.random.default_rng(seed)
    w = _weights(n_archs, weights)
    t = np.arange(duration_s)
    phase = phase_jitter * rng.uniform(-np.pi, np.pi, n_archs)
    amp = amplitude * (1.0 + amp_jitter * rng.uniform(-1.0, 1.0, n_archs))
    base = 1.0 + amp[:, None] * np.sin(
        2 * np.pi * cycles * t[None, :] / duration_s + phase[:, None]
    )
    noise = rng.gamma(noise_shape, 1.0 / noise_shape, (n_archs, duration_s))
    return _normalize_pool(base * noise, mean_rps, w)


def flash_crowd(n_archs: int, duration_s: int, mean_rps: float, seed: int, *,
                mode: str = "correlated", n_events: int = 2,
                amplitude: float = 3.0, tau_s: float = 150.0,
                dip: float = 0.6, noise_shape: float = 30.0,
                weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Flash crowds with controllable cross-arch correlation.

    ``correlated``  — each event hits a random half of the pool at once;
    ``anti``        — one arch spikes while every other arch dips by
                      ``dip`` x the (normalized) spike profile: attention
                      shifts rather than arrives;
    ``solo``        — one arch spikes, the rest never see the event.
    """
    assert mode in ("correlated", "anti", "solo"), mode
    rng = np.random.default_rng(seed)
    w = _weights(n_archs, weights)
    t = np.arange(duration_s, dtype=np.float64)
    mat = np.ones((n_archs, duration_s))
    for _ in range(n_events):
        start = float(rng.uniform(0.1, 0.8) * duration_s)
        amp = amplitude * (0.5 + rng.pareto(2.5))
        profile = np.exp(-np.maximum(t - start, 0.0) / tau_s) * (t >= start)
        if mode == "correlated":
            hit = rng.random(n_archs) < 0.5
            if not hit.any():
                hit[rng.integers(n_archs)] = True
            jitter = rng.uniform(0.6, 1.4, n_archs)
            mat += hit[:, None] * (amp * jitter)[:, None] * profile[None, :]
        else:
            a = int(rng.integers(n_archs))
            mat[a] += amp * profile
            if mode == "anti":
                others = np.arange(n_archs) != a
                mat[others] *= 1.0 - dip * profile[None, :]
    noise = rng.gamma(noise_shape, 1.0 / noise_shape, (n_archs, duration_s))
    return _normalize_pool(mat * noise, mean_rps, w)


def mmpp(n_archs: int, duration_s: int, mean_rps: float, seed: int, *,
         burst_mult: float = 4.0, pareto_alpha: float = 2.0,
         mean_quiet_s: float = 400.0, mean_burst_s: float = 60.0,
         noise_shape: float = 25.0,
         weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Markov-modulated bursts with Pareto amplitudes, per arch.

    Each arch alternates quiet (rate 1) and burst sojourns; burst rate is
    ``1 + burst_mult * Pareto(pareto_alpha)``, capped at ``6 * burst_mult``
    so one draw cannot dominate the normalized row.  Sojourn lengths are
    geometric, so the modulating chain is a true 2-state MMPP.
    """
    rng = np.random.default_rng(seed)
    w = _weights(n_archs, weights)
    mat = np.ones((n_archs, duration_s))
    for a in range(n_archs):
        pos, bursting = 0, bool(rng.random() < 0.2)
        while pos < duration_s:
            mean_len = mean_burst_s if bursting else mean_quiet_s
            length = 1 + int(rng.geometric(1.0 / mean_len))
            if bursting:
                amp = 1.0 + min(burst_mult * rng.pareto(pareto_alpha),
                                6.0 * burst_mult)
                mat[a, pos: pos + length] = amp
            pos += length
            bursting = not bursting
    noise = rng.gamma(noise_shape, 1.0 / noise_shape, (n_archs, duration_s))
    return _normalize_pool(mat * noise, mean_rps, w)


def hotswap(n_archs: int, duration_s: int, mean_rps: float, seed: int, *,
            n_shifts: int = 2, ramp_s: float = 300.0,
            boost: float = 4.0, pool_trace: str = "wiki",
            weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """"Trending model" popularity shifts over a smooth pool trace.

    Pool demand follows a :mod:`repro.core.traces` twin; its split over
    archs starts at ``weights`` and, at each of ``n_shifts`` events, one
    arch's weight logistic-ramps up by ``boost`` x while the rest
    renormalize — the variant-churn case INFaaS-style pools must absorb,
    which no static ``share`` can express.
    """
    rng = np.random.default_rng(seed)
    w0 = _weights(n_archs, weights)
    t = np.arange(duration_s, dtype=np.float64)
    logw = np.broadcast_to(np.log(np.maximum(w0, 1e-12))[:, None],
                           (n_archs, duration_s)).copy()
    for k in range(n_shifts):
        a = int(rng.integers(n_archs))
        t_k = (k + 1) / (n_shifts + 1) * duration_s * rng.uniform(0.8, 1.2)
        ramp = 1.0 / (1.0 + np.exp(-(t - t_k) / ramp_s))
        logw[a] += np.log(boost) * ramp
    wt = np.exp(logw)
    wt /= wt.sum(axis=0, keepdims=True)
    pool = get_trace(pool_trace, duration_s, mean_rps=mean_rps, seed=seed)
    return wt * pool[None, :]


def pool_trace(n_archs: int, duration_s: int, mean_rps: float, seed: int, *,
               trace: str = "berkeley",
               weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """One shared :mod:`repro.core.traces` twin fanned out by static
    share — the scenario form of today's engine behavior, via
    :func:`from_pool_trace` (bit-identical arrivals)."""
    share = _weights(n_archs, weights)
    tr = get_trace(trace, duration_s, mean_rps=mean_rps, seed=seed)
    return from_pool_trace(tr, share)


# ---------------------------------------------------------------------------
# Trace replay: a captured [A, T] matrix as a first-class scenario.
# ---------------------------------------------------------------------------
REPLAY_KEY = "arrivals"


def save_replay(path: str, arrivals: np.ndarray, *,
                key: str = REPLAY_KEY) -> str:
    """Capture an ``[A, T]`` arrival matrix for later replay.

    Writes a compressed ``.npz`` the ``replay`` generator (and therefore
    ``Scenario(kind="replay", params={"path": ...})``) loads back —
    the spec stays a small JSON-serializable record while the matrix
    itself lives on disk.  Returns the path actually written
    (``np.savez`` appends ``.npz`` when missing, so the returned path —
    not necessarily the argument — is what a replay spec must carry)."""
    arrivals = np.asarray(arrivals, dtype=np.float64)
    assert arrivals.ndim == 2, "replay captures [A, T] matrices"
    if not path.endswith(".npz"):
        path += ".npz"
    np.savez_compressed(path, **{key: arrivals})
    return path


def replay(n_archs: int, duration_s: int, mean_rps: float, seed: int, *,
           path: str, key: str = REPLAY_KEY,
           renormalize: bool = False) -> np.ndarray:
    """Replay a captured ``[A, T]`` arrival matrix from an ``.npz`` file.

    The matrix must have exactly ``n_archs`` rows and at least
    ``duration_s`` columns (longer captures are truncated — replay never
    invents data).  ``seed`` is ignored: a replay is literal, and
    re-rolling an episode (the RL env does per reset) replays the same
    capture.  With ``renormalize=True`` the matrix is rescaled so the
    pool mean is ``mean_rps`` (cost-comparable against generated
    scenarios); by default the captured rates are served verbatim.
    """
    with np.load(path) as z:
        assert key in z, f"{path!r} has no array {key!r} (has {sorted(z)})"
        mat = np.asarray(z[key], dtype=np.float64)
    assert mat.ndim == 2, f"replay needs an [A, T] matrix, got {mat.shape}"
    assert mat.shape[0] == n_archs, (
        f"capture has {mat.shape[0]} rows for a {n_archs}-arch pool"
    )
    assert mat.shape[1] >= duration_s, (
        f"capture holds {mat.shape[1]} ticks < duration_s={duration_s}"
    )
    out = mat[:, :duration_s].copy()
    if renormalize:
        pool_mean = max(float(out.sum(axis=0).mean()), 1e-12)
        out *= mean_rps / pool_mean
    return out


GENERATORS: Dict[str, object] = {
    "pool_trace": pool_trace,
    "diurnal": diurnal,
    "flash_crowd": flash_crowd,
    "mmpp": mmpp,
    "hotswap": hotswap,
    "replay": replay,
}
