"""Workload scenario subsystem: heterogeneous per-arch arrival matrices.

The engine's shared-trace path drives every arch with ``share x pool
trace`` — perfectly correlated load.  This package produces, composes,
and replays ``[A, T]`` *arrival matrices* instead, one row per arch, so
scenarios the paper cares about (per-app load diversity, Observation 4's
peak-to-median spread) become first-class:

  generators — seeded matrix generators: ``from_pool_trace`` (the exact
               shared-trace adapter), per-arch ``diurnal`` phase/amplitude
               jitter, ``flash_crowd`` (correlated / anti / solo),
               ``mmpp`` Pareto bursts, ``hotswap`` trending-model shifts
  scenario   — the declarative :class:`Scenario` spec (seeded, dict/JSON
               serializable) and the named :data:`SCENARIO_ZOO` presets

A matrix feeds straight into the engine —
``simulate(scenario.build(len(wl)), wl, policy)`` — which switches to a
streaming per-arch load monitor
(:class:`repro.core.load_monitor.PoolLoadMonitor`) so every arch's
EWMA / window-peak / peak-to-median statistics reflect its own stream.
"""
from repro.core.workloads.generators import (  # noqa: F401
    GENERATORS,
    diurnal,
    flash_crowd,
    from_pool_trace,
    hotswap,
    mmpp,
    pool_trace,
    replay,
    save_replay,
)
from repro.core.workloads.scenario import (  # noqa: F401
    SCENARIO_ZOO,
    Scenario,
    get_scenario,
)
