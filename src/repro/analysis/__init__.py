"""repro.analysis — the invariant linter for the sim core.

An AST-based static analyzer enforcing the cross-layer contracts the
runtime test suite can only probe pointwise: registry twinning,
jit-scope hygiene, seeded determinism, telemetry guarding and PoolObs
aliasing discipline.  Run it as::

    PYTHONPATH=src python -m repro.analysis src/

See docs/STATIC_ANALYSIS.md for the pass catalog and baseline policy.
"""
from repro.analysis.base import (
    AnalysisContext,
    Finding,
    LintPass,
    Module,
    PASS_REGISTRY,
    register_pass,
    run_passes,
)
from repro.analysis.baseline import (
    DEFAULT_BASELINE,
    BaselineEntry,
    BaselineError,
    apply_baseline,
    load_baseline,
)
import repro.analysis.passes  # noqa: F401  (import = pass registration)

__all__ = [
    "AnalysisContext",
    "Finding",
    "LintPass",
    "Module",
    "PASS_REGISTRY",
    "register_pass",
    "run_passes",
    "DEFAULT_BASELINE",
    "BaselineEntry",
    "BaselineError",
    "apply_baseline",
    "load_baseline",
]
