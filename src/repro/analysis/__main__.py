"""CLI entry point: ``python -m repro.analysis [paths...]``.

Exit status is 0 iff every finding is covered by the baseline file
(``analysis_baseline.txt`` at the repo root by default).  Stale
baseline entries — lines matching no current finding — are warned
about but do not fail the run, so a fix can land before its baseline
line is deleted.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis.base import AnalysisContext, PASS_REGISTRY, run_passes
from repro.analysis.baseline import (
    DEFAULT_BASELINE,
    BaselineError,
    apply_baseline,
    load_baseline,
)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based invariant linter for the sim core "
                    "(registry parity, jit hygiene, determinism, "
                    "telemetry guards, PoolObs aliasing).",
    )
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to analyze (default: src)")
    p.add_argument("--format", choices=("text", "github"), default="text",
                   help="text (default) or GitHub workflow-command "
                        "annotations for CI")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help=f"baseline file (default: <repo>/{DEFAULT_BASELINE}; "
                        "'none' disables baselining)")
    p.add_argument("--select", action="append", default=None,
                   metavar="PASS", help="run only these pass ids "
                   "(repeatable)")
    p.add_argument("--repo-root", default=None,
                   help="repo root for relative paths and the tests/ "
                        "cross-check tree (default: cwd)")
    p.add_argument("--list", action="store_true", dest="list_passes",
                   help="list registered passes and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)

    if args.list_passes:
        width = max(len(p) for p in PASS_REGISTRY)
        for lp in PASS_REGISTRY.values():
            print(f"{lp.id:<{width}}  {lp.description}")
        return 0

    repo_root = os.path.abspath(args.repo_root or os.getcwd())
    paths = args.paths or ["src"]
    missing = [p for p in paths if not os.path.exists(p)]
    if missing:
        print(f"error: no such path(s): {', '.join(missing)}",
              file=sys.stderr)
        return 2

    ctx = AnalysisContext(paths, repo_root=repo_root)
    try:
        findings = run_passes(ctx, select=args.select)
    except KeyError as e:
        print(f"error: {e.args[0]}", file=sys.stderr)
        return 2

    if args.baseline == "none":
        entries = {}
        baseline_path = None
    else:
        baseline_path = args.baseline or os.path.join(repo_root,
                                                      DEFAULT_BASELINE)
        try:
            entries = load_baseline(baseline_path)
        except BaselineError as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    new, baselined, stale = apply_baseline(findings, entries)

    for f in new:
        print(f.format_github() if args.format == "github"
              else f.format_text())
    for e in stale:
        print(f"warning: stale baseline entry "
              f"{baseline_path}:{e.line}: {e.key} "
              f"(matches no current finding — delete it)",
              file=sys.stderr)

    n_mod = len(ctx.modules)
    n_pass = len(args.select) if args.select else len(PASS_REGISTRY)
    summary = (f"{n_mod} modules, {n_pass} passes: "
               f"{len(new)} finding(s)")
    if baselined:
        summary += f", {len(baselined)} baselined"
    if stale:
        summary += f", {len(stale)} stale baseline entr"
        summary += "y" if len(stale) == 1 else "ies"
    print(summary, file=sys.stderr)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
