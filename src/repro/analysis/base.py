"""Core of the invariant linter: findings, passes, the pass registry,
and the analysis context passes share.

The framework is deliberately small: a pass is a callable over an
:class:`AnalysisContext` (every parsed module under the analyzed roots,
plus the repo's ``tests/`` tree for cross-checks) returning
:class:`Finding` records.  Findings carry a *stable key* — independent
of line numbers — so the checked-in baseline file survives unrelated
edits to the flagged file.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence


@dataclass(frozen=True)
class Finding:
    """One invariant violation.

    ``key`` identifies the finding independently of line numbers (used
    for baseline matching): ``<pass_id>:<relpath>:<slug>`` where the
    slug names the violated contract at the site (a symbol, registry
    name, or call signature) — re-ordering unrelated code must not
    invalidate a baseline entry.
    """

    pass_id: str
    path: str            # repo-relative path
    line: int
    message: str
    hint: str = ""       # one-line fix suggestion
    slug: str = ""       # stable site identifier within (pass, file)
    col: int = 0

    @property
    def key(self) -> str:
        return f"{self.pass_id}:{self.path}:{self.slug or self.line}"

    def format_text(self) -> str:
        loc = f"{self.path}:{self.line}"
        out = f"{loc}: [{self.pass_id}] {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def format_github(self) -> str:
        # GitHub workflow-command annotation (shows inline on the PR diff)
        msg = self.message.replace("%", "%25").replace("\n", "%0A")
        if self.hint:
            msg += f" (hint: {self.hint})"
        return (f"::error file={self.path},line={self.line},"
                f"col={self.col},title=repro.analysis {self.pass_id}::{msg}")


@dataclass
class Module:
    """One parsed source file."""

    path: str            # absolute
    relpath: str         # repo-relative (what findings report)
    source: str
    tree: ast.AST

    _parents: Optional[Dict[ast.AST, ast.AST]] = field(default=None, repr=False)

    @property
    def parents(self) -> Dict[ast.AST, ast.AST]:
        """child node -> parent node map, built lazily once per module."""
        if self._parents is None:
            p: Dict[ast.AST, ast.AST] = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    p[child] = node
            self._parents = p
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        par = self.parents
        cur = par.get(node)
        while cur is not None:
            yield cur
            cur = par.get(cur)


class AnalysisContext:
    """Parsed view of the analyzed tree.

    ``modules`` covers the requested roots (typically ``src/``);
    ``test_modules`` covers the repo's ``tests/`` directory when one
    exists next to the analysis root (passes use it for cross-checks —
    e.g. registry-parity against the parity-test parametrizations) and
    is NOT itself linted.
    """

    def __init__(self, roots: Sequence[str], repo_root: Optional[str] = None):
        self.repo_root = os.path.abspath(repo_root or os.getcwd())
        self.roots = [os.path.abspath(r) for r in roots]
        self.modules: List[Module] = []
        self.test_modules: List[Module] = []
        self.parse_errors: List[Finding] = []
        for root in self.roots:
            for path in _py_files(root):
                m = self._parse(path)
                if m is not None:
                    self.modules.append(m)
        tests_dir = os.path.join(self.repo_root, "tests")
        if os.path.isdir(tests_dir):
            analyzed = {m.path for m in self.modules}
            for path in _py_files(tests_dir):
                if path in analyzed:
                    continue
                m = self._parse(path)
                if m is not None:
                    self.test_modules.append(m)

    def _parse(self, path: str) -> Optional[Module]:
        with open(path, encoding="utf-8") as f:
            source = f.read()
        rel = os.path.relpath(path, self.repo_root)
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as e:
            self.parse_errors.append(Finding(
                pass_id="parse", path=rel, line=e.lineno or 0,
                message=f"syntax error: {e.msg}", slug="syntax-error",
            ))
            return None
        return Module(path=path, relpath=rel, source=source, tree=tree)

    def find_modules(self, suffix: str) -> List[Module]:
        """Modules whose repo-relative path ends with ``suffix``."""
        suffix = suffix.replace("\\", "/")
        return [m for m in self.modules
                if m.relpath.replace("\\", "/").endswith(suffix)]


def _py_files(root: str) -> List[str]:
    if os.path.isfile(root):
        return [root] if root.endswith(".py") else []
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames
                       if d not in ("__pycache__", ".git", ".ruff_cache")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                out.append(os.path.join(dirpath, fn))
    return sorted(out)


# ---------------------------------------------------------------------------
# Pass registry.
# ---------------------------------------------------------------------------
PassFn = Callable[[AnalysisContext], List[Finding]]


@dataclass(frozen=True)
class LintPass:
    id: str
    description: str
    run: PassFn


#: pass id -> LintPass, in registration order (the CLI runs them in order)
PASS_REGISTRY: Dict[str, LintPass] = {}


def register_pass(pass_id: str, description: str):
    """Decorator registering a pass function under ``pass_id``."""

    def deco(fn: PassFn) -> PassFn:
        if pass_id in PASS_REGISTRY:
            raise ValueError(f"duplicate pass id {pass_id!r}")
        PASS_REGISTRY[pass_id] = LintPass(pass_id, description, fn)
        return fn

    return deco


def run_passes(ctx: AnalysisContext,
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the selected (default: all) registered passes over ``ctx``."""
    ids = list(select) if select else list(PASS_REGISTRY)
    unknown = [i for i in ids if i not in PASS_REGISTRY]
    if unknown:
        raise KeyError(f"unknown pass id(s): {', '.join(unknown)}; "
                       f"known: {', '.join(PASS_REGISTRY)}")
    findings: List[Finding] = list(ctx.parse_errors)
    for pid in ids:
        findings.extend(PASS_REGISTRY[pid].run(ctx))
    findings.sort(key=lambda f: (f.path, f.line, f.pass_id, f.slug))
    return findings
