"""Built-in lint passes.

Importing this package registers every pass with
:data:`repro.analysis.base.PASS_REGISTRY`; add new passes by dropping a
module here and importing it below (registration order is run order).
"""
from repro.analysis.passes import (  # noqa: F401  (import = registration)
    registry_parity,
    jit_hygiene,
    determinism,
    telemetry_guard,
    soa_aliasing,
)

__all__ = [
    "registry_parity",
    "jit_hygiene",
    "determinism",
    "telemetry_guard",
    "soa_aliasing",
]
