"""jit-hygiene: no host syncs or Python control flow in traced scopes.

The batched engine jits one `lax.scan` over the whole horizon and vmaps
it across the grid; a single host sync (`.item()`, `float(...)`,
`np.*` on a traced array) inside that scope forces a device→host copy
per call, and a Python `if` on a traced array raises
`TracerBoolConversionError` at trace time — or worse, silently bakes
one branch in when the value is concrete under `vmap` debugging.

Scope discovery is a name-level call graph seeded from jit roots:

* functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)``;
* function names passed to ``jax.jit`` / ``vmap`` / ``pmap`` /
  ``lax.scan`` / ``lax.cond`` / ``lax.switch`` / ``lax.while_loop`` /
  ``lax.fori_loop`` / ``lax.associative_scan``;
* the apply function of every ``JaxPolicy(...)`` registration.

Reachability resolves *bare-name* calls and by-reference args only, and
only against the calling module's own defs plus its explicit
``from X import name`` imports — method calls (``st.add_arrivals(...)``)
are not followed (a name-level graph following attribute tails pulls in
every same-named method in the repo; the runtime differential fuzz
covers those edges instead).

Within a reachable function, *traced* names are the parameters without
defaults (minus ``static_argnames`` / ``self``) plus anything assigned
from them; parameters with defaults (``xp=np``, ``variants=False``) are
trace-time constants by repo convention.  ``.shape`` / ``.ndim`` /
``.dtype`` / ``.size`` reads and ``is (not)`` comparisons are static
and never flagged.

A third family: *unhashable static args* — a dict/list/set (literal or
comprehension) passed in a ``static_argnames`` position recompiles on
every call at best and raises ``TypeError: unhashable`` at first use.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import dotted_name, names_in, string_elts
from repro.analysis.base import AnalysisContext, Finding, Module, register_pass

#: jax combinators whose function-valued args enter traced scope
_TRACING_TAILS = {
    "jit", "vmap", "pmap", "scan", "associative_scan",
    "cond", "switch", "while_loop", "fori_loop", "checkpoint", "remat",
}
#: attribute reads that are static at trace time
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "aval"}
#: builtins that force a concrete value (host sync) on a traced array
_SYNC_BUILTINS = {"float", "int", "bool", "len"}
#: methods that force a device→host copy
_SYNC_METHODS = {"item", "tolist", "__array__"}

_UNHASHABLE = (ast.Dict, ast.List, ast.Set, ast.DictComp, ast.ListComp,
               ast.SetComp)


def _is_jax_combinator(func: ast.AST) -> Optional[str]:
    d = dotted_name(func)
    if d is None:
        return None
    tail = d.split(".")[-1]
    if tail not in _TRACING_TAILS:
        return None
    head = d.split(".")[0]
    if head in ("jax", "lax") or ".lax." in d or d == tail == "jit":
        return tail
    return None


def _static_argnames(call: ast.Call) -> Set[str]:
    out: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            if isinstance(kw.value, ast.Constant) and isinstance(
                    kw.value.value, str):
                out.add(kw.value.value)
            out.update(s for s, _ in string_elts(kw.value))
    return out


class _Root:
    __slots__ = ("name", "statics")

    def __init__(self, name: str, statics: Set[str]):
        self.name = name
        self.statics = statics


def _collect_roots(mod: Module) -> List[_Root]:
    roots: List[_Root] = []
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dotted_name(dec)
                if d is not None and d.split(".")[-1] == "jit" and (
                        d in ("jit", "jax.jit") or d.endswith(".jit")):
                    roots.append(_Root(node.name, set()))
                elif (isinstance(dec, ast.Call)
                        and dotted_name(dec.func) in ("partial",
                                                      "functools.partial")
                        and dec.args
                        and _is_jax_combinator(dec.args[0]) == "jit"):
                    roots.append(_Root(node.name, _static_argnames(dec)))
        elif isinstance(node, ast.Call):
            tail = _is_jax_combinator(node.func)
            if tail is not None:
                statics = _static_argnames(node) if tail == "jit" else set()
                for arg in node.args:
                    if isinstance(arg, ast.Name):
                        roots.append(_Root(arg.id, statics))
            elif (dotted_name(node.func) or "").split(".")[-1] == "JaxPolicy":
                if node.args and isinstance(node.args[0], ast.Name):
                    roots.append(_Root(node.args[0].id, set()))
    return roots


def _module_dotted(mod: Module) -> Tuple[str, ...]:
    """Package path of the module, e.g. ``('repro', 'core', 'sim')`` for
    ``src/repro/core/sim/jax_engine.py``."""
    rel = mod.relpath.replace("\\", "/")
    parts = [p for p in rel.split("/") if p]
    if parts and parts[0] in ("src", "lib"):
        parts = parts[1:]
    if parts and parts[-1].endswith(".py"):
        parts[-1] = parts[-1][:-3]
    if parts and parts[-1] == "__init__":
        return tuple(parts[:-1])       # package itself
    return tuple(parts[:-1])           # enclosing package


def _import_map(mod: Module) -> Dict[str, Tuple[Tuple[str, ...], str]]:
    """local name -> (source module path parts, original name) for every
    ``from X import y [as z]`` in the module."""
    pkg = _module_dotted(mod)
    out: Dict[str, Tuple[Tuple[str, ...], str]] = {}
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ImportFrom):
            continue
        if node.level:
            base = pkg[:len(pkg) - (node.level - 1)] if node.level > 1 \
                else pkg
        else:
            base = ()
        target = base + tuple((node.module or "").split("."))
        target = tuple(p for p in target if p)
        for a in node.names:
            if a.name != "*":
                out[a.asname or a.name] = (target, a.name)
    return out


class _Index:
    """Per-module function defs + module lookup by dotted path."""

    def __init__(self, ctx: AnalysisContext):
        self.defs: Dict[str, Dict[str, List[ast.AST]]] = {}
        self.by_dotted: Dict[Tuple[str, ...], Module] = {}
        self.imports: Dict[str, Dict[str, Tuple[Tuple[str, ...], str]]] = {}
        for mod in ctx.modules:
            local: Dict[str, List[ast.AST]] = {}
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    local.setdefault(node.name, []).append(node)
            self.defs[mod.relpath] = local
            self.imports[mod.relpath] = _import_map(mod)
            rel = mod.relpath.replace("\\", "/")
            parts = [p for p in rel.split("/") if p]
            if parts and parts[0] in ("src", "lib"):
                parts = parts[1:]
            if parts and parts[-1].endswith(".py"):
                parts[-1] = parts[-1][:-3]
            if parts and parts[-1] == "__init__":
                parts = parts[:-1]
            self.by_dotted[tuple(parts)] = mod

    def resolve(self, mod: Module, name: str):
        """(module, [fndefs]) the bare name refers to, or None."""
        local = self.defs[mod.relpath].get(name)
        if local:
            return mod, local
        imp = self.imports[mod.relpath].get(name)
        if imp is not None:
            target_mod = self.by_dotted.get(imp[0])
            if target_mod is not None:
                defs = self.defs[target_mod.relpath].get(imp[1])
                if defs:
                    return target_mod, defs
        return None


def _called_names(fn: ast.AST) -> Set[str]:
    """Bare names called or passed by reference inside ``fn`` — method
    calls are deliberately NOT followed (see module docstring)."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name):
                out.add(node.func.id)
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    out.add(arg.id)
            for kw in node.keywords:
                if isinstance(kw.value, ast.Name):
                    out.add(kw.value.id)
    return out


def _reachable(ctx: AnalysisContext):
    """jit-reachable ``(relpath, lineno) -> (module, fndef, statics)``."""
    idx = _Index(ctx)
    pending: List[Tuple[Module, _Root]] = []
    for mod in ctx.modules:
        for root in _collect_roots(mod):
            pending.append((mod, root))
    seen: Dict[Tuple[str, int], Tuple[Module, ast.AST, Set[str]]] = {}
    while pending:
        from_mod, root = pending.pop()
        resolved = idx.resolve(from_mod, root.name)
        if resolved is None:
            continue
        def_mod, fns = resolved
        for fn in fns:
            key = (def_mod.relpath, fn.lineno)
            if key in seen:
                seen[key][2].update(root.statics)
                continue
            seen[key] = (def_mod, fn, set(root.statics))
            for callee in _called_names(fn):
                if callee != root.name:
                    pending.append((def_mod, _Root(callee, set())))
    return seen


#: annotations marking a parameter as a trace-time constant — a Python
#: bool/str can never be a traced array (weak-typed flags are annotated
#: as arrays in this repo)
_STATIC_ANNOTATIONS = {"bool", "str"}


def _annotated_static(param: ast.arg) -> bool:
    ann = param.annotation
    if isinstance(ann, ast.Name):
        return ann.id in _STATIC_ANNOTATIONS
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value in _STATIC_ANNOTATIONS
    return False


def _traced_names(fn: ast.AST, statics: Set[str]) -> Set[str]:
    a = fn.args
    positional = list(a.posonlyargs) + list(a.args)
    n_defaults = len(a.defaults)
    required = positional[:len(positional) - n_defaults]
    traced = ({p.arg for p in required if not _annotated_static(p)}
              - statics - {"self", "cls"})
    # forward-propagate through assignments until fixpoint
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _refs_traced(node.value,
                                                            traced):
                for tgt in node.targets:
                    for name in names_in(tgt):
                        if name not in traced:
                            traced.add(name)
                            changed = True
    return traced


def _refs_traced(node: ast.AST, traced: Set[str]) -> bool:
    """Does the expression read a traced *value* (static .shape/.dtype
    reads don't count)?"""
    if isinstance(node, ast.Attribute) and node.attr in _STATIC_ATTRS:
        return False
    if isinstance(node, ast.Name):
        return node.id in traced
    if isinstance(node, ast.Call):
        d = dotted_name(node.func)
        if d is not None and d.split(".")[-1] in ("len", "isinstance"):
            return False
    return any(_refs_traced(c, traced) for c in ast.iter_child_nodes(node))


def _static_compare(test: ast.AST) -> bool:
    """`x is None` / `xp is np` style checks are trace-time static."""
    if isinstance(test, ast.Compare):
        return all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops)
    if isinstance(test, ast.Call):
        d = dotted_name(test.func)
        return d is not None and d.split(".")[-1] in ("isinstance",
                                                      "callable",
                                                      "hasattr")
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
        return _static_compare(test.operand)
    if isinstance(test, ast.BoolOp):
        return all(_static_compare(v) for v in test.values)
    return False


def _check_function(mod: Module, fn: ast.AST, statics: Set[str],
                    findings: List[Finding]) -> None:
    traced = _traced_names(fn, statics)
    if not traced:
        return

    def emit(node, slug, message, hint):
        findings.append(Finding(
            pass_id="jit-hygiene", path=mod.relpath, line=node.lineno,
            slug=f"{fn.name}-{slug}", message=message, hint=hint,
        ))

    for node in ast.walk(fn):
        # don't descend into nested defs twice — they're analyzed as
        # their own reachable functions with their own param sets
        if isinstance(node, ast.Call):
            func = node.func
            d = dotted_name(func)
            if (isinstance(func, ast.Attribute)
                    and func.attr in _SYNC_METHODS
                    and _refs_traced(func.value, traced)):
                emit(node, f"host-sync-{func.attr}",
                     f"`.{func.attr}()` on a traced array forces a "
                     "device→host sync inside the jitted scope",
                     "keep the value on-device (jnp ops) or move the "
                     "read outside the jitted scope")
            elif (d in _SYNC_BUILTINS and d != "len" and node.args
                    and _refs_traced(node.args[0], traced)):
                emit(node, f"host-sync-{d}",
                     f"`{d}(...)` on a traced value concretizes it — "
                     "host sync / TracerConversionError inside jit",
                     f"use jnp casts (e.g. `.astype`) instead of `{d}()`")
            elif (d is not None
                    and d.split(".")[0] in ("np", "numpy", "onp")
                    and len(d.split(".")) > 1
                    and any(_refs_traced(a, traced) for a in node.args)):
                emit(node, f"np-on-traced-{d.split('.')[-1]}",
                     f"`{d}(...)` applies host NumPy to a traced array — "
                     "silent device→host copy (and breaks grad/vmap)",
                     "use the jnp / xp backend equivalent")
        elif isinstance(node, (ast.If, ast.While)):
            if (_refs_traced(node.test, traced)
                    and not _static_compare(node.test)):
                kw = "while" if isinstance(node, ast.While) else "if"
                emit(node, f"python-{kw}-on-traced",
                     f"Python `{kw}` on a traced array — "
                     "TracerBoolConversionError at trace time",
                     "restructure with jnp.where / lax.cond / lax.select")
        elif isinstance(node, ast.IfExp):
            if (_refs_traced(node.test, traced)
                    and not _static_compare(node.test)):
                emit(node, "python-ifexp-on-traced",
                     "conditional expression on a traced array — "
                     "TracerBoolConversionError at trace time",
                     "use jnp.where(cond, a, b)")
        elif isinstance(node, ast.Assert):
            if _refs_traced(node.test, traced):
                emit(node, "assert-on-traced",
                     "assert on a traced array inside jit",
                     "use checkify or move the check outside the "
                     "jitted scope")


def _check_unhashable_statics(ctx: AnalysisContext,
                              findings: List[Finding]) -> None:
    # map jitted function name -> (static names, static positions)
    jitted: Dict[str, Tuple[Set[str], Dict[str, int]]] = {}
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                statics: Set[str] = set()
                for dec in node.decorator_list:
                    if (isinstance(dec, ast.Call)
                            and dotted_name(dec.func) in (
                                "partial", "functools.partial")
                            and dec.args
                            and _is_jax_combinator(dec.args[0]) == "jit"):
                        statics |= _static_argnames(dec)
                if statics:
                    pos = {p.arg: i for i, p in enumerate(node.args.args)}
                    jitted[node.name] = (statics, pos)
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in jitted):
                continue
            statics, pos = jitted[node.func.id]
            bad: List[Tuple[str, ast.AST]] = []
            for kw in node.keywords:
                if kw.arg in statics and isinstance(kw.value, _UNHASHABLE):
                    bad.append((kw.arg, kw.value))
            for name in statics:
                i = pos.get(name)
                if (i is not None and i < len(node.args)
                        and isinstance(node.args[i], _UNHASHABLE)):
                    bad.append((name, node.args[i]))
            for name, val in bad:
                findings.append(Finding(
                    pass_id="jit-hygiene", path=mod.relpath,
                    line=val.lineno,
                    slug=f"unhashable-static-{node.func.id}-{name}",
                    message=(f"unhashable {type(val).__name__.lower()} "
                             f"passed for static arg {name!r} of jitted "
                             f"{node.func.id}() — TypeError at the jit "
                             "cache lookup"),
                    hint="pass a hashable (tuple / frozen dataclass) or "
                         "drop it from static_argnames",
                ))


@register_pass(
    "jit-hygiene",
    "no host syncs (.item()/float()/np.* on traced), Python branches on "
    "traced arrays, or unhashable static args in jit/scan/vmap-reachable "
    "scopes",
)
def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for _key, (mod, fn, statics) in sorted(_reachable(ctx).items()):
        _check_function(mod, fn, statics, findings)
    _check_unhashable_statics(ctx, findings)
    return findings
