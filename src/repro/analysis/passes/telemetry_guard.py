"""telemetry-guard: the zero-cost-when-absent observability contract.

PR 7's telemetry subsystem is only zero-cost because every emission
site in the engine and the tiers is gated on ``<telemetry> is not
None`` (the disabled path is pinned bit-identical to the pre-telemetry
engine by goldens and a throughput ratchet).  Three contracts, all
mechanical:

1. **guarded emission sites** — every call on a telemetry receiver
   (``tel`` / ``telemetry`` / ``*.telemetry``) to an emitting method
   (``on_*`` / ``emit*`` / ``counter`` / ``end_tick`` / ``bind``) must
   sit under an ``is not None`` check of that same receiver (directly,
   via an ``and``-conjunct, on the non-None side of an if/else, or
   behind an early ``if <recv> is None: return``).  The module that
   *defines* ``class Telemetry`` is exempt (its internals gate on
   ``events_on`` / ``record_on`` instead).
2. **event-type vocabulary** — every ``EV_*`` constant and every string
   literal passed as an etype to ``emit`` / ``emit_flow`` /
   ``on_reclaim`` must be a key of ``EVENT_TYPES`` (docs/TELEMETRY.md
   is generated from it; the reconciliation scatter dispatches on it).
3. **summary-key docs** — every key ``SimResult.summary()`` can produce
   must appear in ``SUMMARY_KEY_DOCS`` (dynamic ``f"cost_{t}"`` keys
   match a ``cost_<tier>``-style documented placeholder).
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.astutil import (
    const_str,
    dict_str_keys,
    dotted_name,
    enclosing_function,
    module_str_constants,
)
from repro.analysis.base import AnalysisContext, Finding, Module, register_pass

_EMIT_METHODS = ("emit", "emit_flow", "counter", "end_tick", "bind")
#: emit/emit_flow/on_reclaim positional index of the etype argument
_ETYPE_ARG = {"emit": 1, "emit_flow": 1, "on_reclaim": 1}


def _telemetry_receiver(call: ast.Call) -> Optional[Tuple[str, str]]:
    """``(receiver_text, method)`` when the call emits telemetry."""
    func = call.func
    if not isinstance(func, ast.Attribute):
        return None
    method = func.attr
    if not (method.startswith("on_") or method in _EMIT_METHODS):
        return None
    recv = dotted_name(func.value)
    if recv is None:
        return None
    if recv in ("tel", "telemetry") or recv.endswith(".telemetry"):
        return recv, method
    return None


def _test_guards(test: ast.AST, recv: str, *, non_none: bool) -> bool:
    """Does ``test`` establish ``recv is (not) None``?"""
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        return any(_test_guards(v, recv, non_none=non_none)
                   for v in test.values)
    if (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None):
        want = ast.IsNot if non_none else ast.Is
        if isinstance(test.ops[0], want):
            return dotted_name(test.left) == recv
    return False


def _in_subtree(roots: List[ast.stmt], node: ast.AST) -> bool:
    return any(node is n for r in roots for n in ast.walk(r))


def _is_guarded(mod: Module, call: ast.Call, recv: str) -> bool:
    # (a) an ancestor `if` guards the receiver on the side we're on
    for anc in mod.ancestors(call):
        if isinstance(anc, ast.If):
            if (_in_subtree(anc.body, call)
                    and _test_guards(anc.test, recv, non_none=True)):
                return True
            if (_in_subtree(anc.orelse, call)
                    and _test_guards(anc.test, recv, non_none=False)):
                return True
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = anc
            break
    else:
        return False
    # (b) an earlier top-level `if recv is None: return` in the function
    for stmt in fn.body:
        if _in_subtree([stmt], call):
            break
        if (isinstance(stmt, ast.If)
                and _test_guards(stmt.test, recv, non_none=False)
                and stmt.body
                and isinstance(stmt.body[-1], (ast.Return, ast.Raise,
                                               ast.Continue))):
            return True
    return False


def _defines_class(mod: Module, name: str) -> bool:
    return any(isinstance(n, ast.ClassDef) and n.name == name
               for n in ast.walk(mod.tree))


# ---------------------------------------------------------------------------
# Event vocabulary helpers.
# ---------------------------------------------------------------------------
def _event_types(ctx: AnalysisContext):
    """(module, {etype: line}, {const_name: value}) for the module
    defining EVENT_TYPES, or (None, {}, {})."""
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "EVENT_TYPES"
                    and isinstance(node.value, ast.Dict)):
                consts = module_str_constants(mod.tree)
                keys = dict(
                    (k, ln)
                    for k, ln in dict_str_keys(node.value, resolve=consts))
                return mod, keys, consts
    return None, {}, {}


def _summary_keys(fn: ast.AST) -> List[Tuple[str, int, bool]]:
    """``(key, line, is_dynamic)`` for every key ``summary()`` produces."""
    out: List[Tuple[str, int, bool]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if k is None:
                    continue
                s = const_str(k)
                if s is not None:
                    out.append((s, k.lineno, False))
                elif isinstance(k, ast.JoinedStr):
                    prefix = ""
                    for part in k.values:
                        if isinstance(part, ast.Constant):
                            prefix += str(part.value)
                        else:
                            break
                    out.append((prefix, k.lineno, True))
        elif (isinstance(node, ast.Assign)
                and isinstance(node.targets[0], ast.Subscript)):
            s = const_str(node.targets[0].slice)
            if s is not None:
                out.append((s, node.lineno, False))
    return out


@register_pass(
    "telemetry-guard",
    "every telemetry emission is `is not None`-guarded, every etype is "
    "in EVENT_TYPES, every summary() key is in SUMMARY_KEY_DOCS",
)
def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []

    # -- 1. guarded emission sites --------------------------------------
    for mod in ctx.modules:
        if _defines_class(mod, "Telemetry"):
            continue             # the hook's own internals are exempt
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            rm = _telemetry_receiver(node)
            if rm is None:
                continue
            recv, method = rm
            if not _is_guarded(mod, node, recv):
                fn = enclosing_function(mod, node)
                where = fn.name if fn is not None else "<module>"
                findings.append(Finding(
                    pass_id="telemetry-guard", path=mod.relpath,
                    line=node.lineno,
                    slug=f"unguarded-{where}-{method}",
                    message=(f"telemetry emission {recv}.{method}(...) is "
                             f"not behind an `if {recv} is not None` guard "
                             "— breaks the zero-cost-when-disabled "
                             "contract (and crashes telemetry-less runs)"),
                    hint=f"wrap in `if {recv} is not None:`",
                ))

    # -- 2. event-type vocabulary ---------------------------------------
    ev_mod, event_types, consts = _event_types(ctx)
    if ev_mod is not None:
        # every EV_* constant in the defining module must be a key
        for name, value in sorted(consts.items()):
            if name.startswith("EV_") and value not in event_types:
                findings.append(Finding(
                    pass_id="telemetry-guard", path=ev_mod.relpath,
                    line=1, slug=f"etype-const-{name}-undocumented",
                    message=(f"{name} = {value!r} is not a key of "
                             "EVENT_TYPES — the event would dodge the "
                             "docs and the reconciliation vocabulary"),
                    hint=f"add {value!r} to EVENT_TYPES with a one-line "
                         "magnitude-semantics doc",
                ))
        for mod in ctx.modules:
            local_consts = module_str_constants(mod.tree)
            for node in ast.walk(mod.tree):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)):
                    continue
                idx = _ETYPE_ARG.get(node.func.attr)
                if idx is None or len(node.args) <= idx:
                    continue
                arg = node.args[idx]
                etype = const_str(arg)
                if etype is None and isinstance(arg, ast.Name):
                    etype = local_consts.get(arg.id, consts.get(arg.id))
                if etype is not None and etype not in event_types:
                    findings.append(Finding(
                        pass_id="telemetry-guard", path=mod.relpath,
                        line=node.lineno,
                        slug=f"etype-{etype}-unknown",
                        message=(f"emitted event type {etype!r} is not in "
                                 "EVENT_TYPES"),
                        hint="add it to EVENT_TYPES (and the "
                             "reconciliation scatter) or fix the typo",
                    ))

    # -- 3. summary keys are documented ---------------------------------
    for mod in ctx.modules:
        docs: Optional[Set[str]] = None
        docs_node = None
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "SUMMARY_KEY_DOCS"
                    and isinstance(node.value, ast.Dict)):
                docs = {k for k, _ in dict_str_keys(node.value)}
                docs_node = node
        if docs is None:
            continue
        placeholder_prefixes = [d.split("<", 1)[0] for d in docs if "<" in d]
        for node in ast.walk(mod.tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name == "summary"):
                for key, line, dynamic in _summary_keys(node):
                    if dynamic:
                        ok = any(key.startswith(p) or p.startswith(key)
                                 for p in placeholder_prefixes)
                    else:
                        ok = key in docs
                    if not ok:
                        findings.append(Finding(
                            pass_id="telemetry-guard", path=mod.relpath,
                            line=line, slug=f"summary-key-{key}-undocumented",
                            message=(f"summary() produces key "
                                     f"{key + ('…' if dynamic else '')!r} "
                                     "absent from SUMMARY_KEY_DOCS"),
                            hint=("document it in SUMMARY_KEY_DOCS at line "
                                  f"{docs_node.lineno} (docs/TELEMETRY.md "
                                  "is generated against it)"),
                        ))
    return findings
