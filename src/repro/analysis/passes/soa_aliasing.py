"""soa-aliasing: PoolObs field arrays must be copied before outliving
the tick.

``ServingSim.observe_pool()`` returns a :class:`PoolObs` whose field
arrays *alias engine-owned scratch buffers* — valid only until the next
``observe_pool()`` call (PR 9 made this explicit; the zero-copy view is
what keeps per-tick RL observation free).  A caller that stows a field
array on ``self`` without ``.copy()`` sees the buffer mutate under it
one tick later — the classic action-delta-is-always-zero bug.

Flagged shape::

    self._prev_rate = obs.rate          # aliases the scratch buffer

Compliant shapes (never flagged)::

    self._prev_rate = obs.rate.copy()   # materialized snapshot
    self._pobs = self.sim.observe_pool()  # whole-obs handle, refreshed
    rate = obs.rate                     # local, dies within the tick

Field names come from the ``PoolObs`` class definition in the analyzed
tree; obs receivers are recognized as variables assigned from an
``observe_pool()`` call in the same function, or names/attributes
containing ``obs`` (the repo-wide naming convention for observation
handles).  The pass is silent when no ``PoolObs`` class is in scope.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from repro.analysis.astutil import dotted_name, enclosing_function
from repro.analysis.base import AnalysisContext, Finding, register_pass


def _poolobs_fields(ctx: AnalysisContext) -> Set[str]:
    fields: Set[str] = set()
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and node.name == "PoolObs":
                for stmt in node.body:
                    if (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.target, ast.Name)):
                        fields.add(stmt.target.id)
                    elif isinstance(stmt, ast.Assign):
                        for t in stmt.targets:
                            if isinstance(t, ast.Name):
                                fields.add(t.id)
    fields.discard("copy")
    return fields


def _obs_locals(fn: Optional[ast.AST]) -> Set[str]:
    """Names bound from an ``observe_pool()`` call within ``fn``."""
    if fn is None:
        return set()
    out: Set[str] = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign)
                and isinstance(node.value, ast.Call)):
            d = dotted_name(node.value.func)
            if d is not None and d.split(".")[-1] == "observe_pool":
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out.add(tgt.id)
                    elif isinstance(tgt, ast.Attribute):
                        out.add(tgt.attr)
    return out


def _is_obs_receiver(base: ast.AST, obs_locals: Set[str]) -> bool:
    d = dotted_name(base)
    if d is None:
        return False
    leaf = d.split(".")[-1]
    if leaf in obs_locals:
        return True
    return "obs" in leaf.lower()


@register_pass(
    "soa-aliasing",
    "PoolObs field arrays stored on self across ticks must be .copy()ed "
    "(observe_pool() returns views of engine-owned scratch buffers)",
)
def run(ctx: AnalysisContext) -> List[Finding]:
    fields = _poolobs_fields(ctx)
    if not fields:
        return []
    findings: List[Finding] = []
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Assign):
                continue
            value = node.value
            if not (isinstance(value, ast.Attribute)
                    and value.attr in fields):
                continue
            attr_targets = [t for t in node.targets
                            if isinstance(t, ast.Attribute)]
            if not attr_targets:
                continue      # locals die within the tick — fine
            fn = enclosing_function(mod, node)
            if not _is_obs_receiver(value.value, _obs_locals(fn)):
                continue
            for tgt in attr_targets:
                where = fn.name if fn is not None else "<module>"
                findings.append(Finding(
                    pass_id="soa-aliasing", path=mod.relpath,
                    line=node.lineno,
                    slug=f"{where}-{tgt.attr}-aliases-{value.attr}",
                    message=(f"{dotted_name(tgt) or tgt.attr} stores "
                             f"PoolObs.{value.attr} without .copy() — the "
                             "array aliases an engine-owned scratch buffer "
                             "and mutates at the next observe_pool()"),
                    hint=f"store `...{value.attr}.copy()` (PoolObs fields "
                         "are views, valid only until the next tick)",
                ))
    return findings
