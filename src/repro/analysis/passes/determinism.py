"""determinism: no global-state or wall-clock randomness in src/.

Resume/replay, the differential fuzz against the NumPy oracle, and the
jitted scan's ``(seed, tick)`` lockstep (ROADMAP, "the batched engine")
all require every random draw in the sim core to flow from an explicit
seeded generator.  Three families of escape hatch are banned:

1. **module-singleton NumPy randomness** — ``np.random.seed`` /
   ``np.random.rand`` / ``np.random.normal`` / ... mutate or read the
   hidden global ``RandomState``; any library call can perturb the
   stream.  ``np.random.default_rng(seed)`` / ``Generator`` /
   ``SeedSequence`` / bit generators are the sanctioned forms.
2. **the stdlib ``random`` module** — same global-state problem, plus
   it seeds from the OS by default.
3. **wall-clock seeds** — ``time.time()`` / ``datetime.now()`` (and
   friends) flowing into anything seed-named makes runs unrepeatable
   by construction.  Wall-clock *timing* (``perf_counter`` for a
   duration) is fine and not flagged.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.astutil import dotted_name, enclosing_function
from repro.analysis.base import AnalysisContext, Finding, register_pass

#: np.random attributes that do NOT touch the global RandomState
_NP_RANDOM_OK = {
    "default_rng", "Generator", "SeedSequence", "BitGenerator",
    "PCG64", "PCG64DXSM", "Philox", "MT19937", "SFC64", "RandomState",
}

#: wall-clock sources that must never feed a seed
_CLOCK_CALLS = {
    "time.time", "time.time_ns", "datetime.now", "datetime.utcnow",
    "datetime.datetime.now", "datetime.datetime.utcnow",
}


def _flags_np_random(call_target: str) -> bool:
    parts = call_target.split(".")
    if len(parts) >= 3 and parts[-3] == "np" and parts[-2] == "random":
        return parts[-1] not in _NP_RANDOM_OK
    if len(parts) >= 3 and parts[-3] == "numpy" and parts[-2] == "random":
        return parts[-1] not in _NP_RANDOM_OK
    return False


def _stdlib_random_alias(mod_tree: ast.AST) -> set:
    """Names under which the stdlib ``random`` module is visible here."""
    out = set()
    for node in ast.walk(mod_tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "random":
                    out.add(a.asname or "random")
        elif isinstance(node, ast.ImportFrom):
            if node.module == "random" and node.level == 0:
                for a in node.names:
                    out.add(a.asname or a.name)
    return out


def _seed_context(mod, node: ast.AST) -> bool:
    """Is ``node`` (a clock call) flowing into something seed-named?
    Matches ``seed=<...clock...>`` kwargs and ``*seed* = <...clock...>``
    assignments anywhere up the ancestor chain."""
    prev = node
    for anc in mod.ancestors(node):
        if isinstance(anc, ast.Call):
            for kw in anc.keywords:
                if kw.arg and "seed" in kw.arg.lower() and _contains(kw.value, prev):
                    return True
        if isinstance(anc, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (anc.targets if isinstance(anc, ast.Assign)
                       else [anc.target])
            for t in targets:
                name = t.id if isinstance(t, ast.Name) else getattr(t, "attr", "")
                if name and "seed" in name.lower():
                    return True
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            break
        prev = anc
    return False


def _contains(tree: ast.AST, node: ast.AST) -> bool:
    return any(n is node for n in ast.walk(tree))


@register_pass(
    "determinism",
    "ban global-state np.random.* / stdlib random / wall-clock seeds "
    "(resume, replay and the scan's (seed, tick) lockstep depend on "
    "explicit seeded generators)",
)
def run(ctx: AnalysisContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.modules:
        random_aliases = _stdlib_random_alias(mod.tree)
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.Call, ast.Attribute)):
                continue
            target = dotted_name(node.func if isinstance(node, ast.Call)
                                 else node)
            if target is None:
                continue
            fn = enclosing_function(mod, node)
            where = f"{fn.name}-" if fn is not None else ""
            if isinstance(node, ast.Call) and _flags_np_random(target):
                findings.append(Finding(
                    pass_id="determinism", path=mod.relpath, line=node.lineno,
                    slug=f"{where}np-random-{target.split('.')[-1]}",
                    message=(f"{target}() draws from NumPy's global "
                             "RandomState — unseedable from the engine's "
                             "(seed, tick) streams"),
                    hint="thread an np.random.default_rng(seed) Generator "
                         "through instead",
                ))
            elif (isinstance(node, ast.Call)
                  and target.split(".")[0] in random_aliases
                  and "." in target):
                findings.append(Finding(
                    pass_id="determinism", path=mod.relpath, line=node.lineno,
                    slug=f"{where}stdlib-random-{target.split('.')[-1]}",
                    message=(f"{target}() uses the stdlib random module's "
                             "global state"),
                    hint="use a seeded np.random.default_rng Generator",
                ))
            elif (isinstance(node, ast.Call) and target in _CLOCK_CALLS
                  and _seed_context(mod, node)):
                findings.append(Finding(
                    pass_id="determinism", path=mod.relpath, line=node.lineno,
                    slug=f"{where}clock-seed",
                    message=(f"{target}() feeds a seed — runs become "
                             "unrepeatable by construction"),
                    hint="take the seed as a parameter (callers own "
                         "entropy policy)",
                ))
    # `from random import X` makes bare calls like shuffle() global-state
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if (isinstance(node, ast.ImportFrom) and node.module == "random"
                    and node.level == 0):
                findings.append(Finding(
                    pass_id="determinism", path=mod.relpath, line=node.lineno,
                    slug="from-random-import",
                    message="`from random import ...` pulls global-state "
                            "randomness into scope",
                    hint="use a seeded np.random.default_rng Generator",
                ))
    return findings
