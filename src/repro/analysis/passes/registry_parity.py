"""registry-parity: the three scheduler/policy registries stay twinned.

The sim core keeps THREE registries of procurement policies that must
stay in lockstep (ROADMAP "Architecture" sections; the runtime parity
tests fuzz the pairs to 1e-6, this pass catches a missing twin before
any simulation runs):

* ``SCHEDULERS`` — legacy per-arch dict policies (the semantic spec);
* ``VECTOR_SCHEDULERS`` — structure-of-arrays twins the engine's hot
  loop and every benchmark grid dispatch;
* ``JAX_POLICIES`` — in-scan twins compiled into the jitted engine.

Contracts enforced statically:

1. every ``VECTOR_SCHEDULERS`` name has a dict-form ``SCHEDULERS`` twin
   (the dict form is the oracle the parity tests compare against);
2. every ``JAX_POLICIES`` name has a ``VECTOR_SCHEDULERS`` twin (the
   scan twin is pinned to the host vector form by differential fuzz);
3. every policy name a test parametrizes over
   (``@pytest.mark.parametrize(..., ["reactive", ...])``) still exists
   in some registry — a renamed/removed policy must take its test
   parametrizations with it, otherwise the parity coverage silently
   shrinks to the surviving names.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.analysis.astutil import assigned_names
from repro.analysis.base import AnalysisContext, Finding, register_pass

REGISTRY_NAMES = ("SCHEDULERS", "VECTOR_SCHEDULERS", "JAX_POLICIES")

#: parametrize argument names that carry policy/scheduler names
_POLICY_ARGNAMES = ("policy", "scheduler", "policy_name", "scheme")


def _collect_registries(ctx: AnalysisContext):
    """``registry -> {name: (relpath, lineno)}`` over the analyzed tree."""
    out: Dict[str, Dict[str, tuple]] = {r: {} for r in REGISTRY_NAMES}
    for mod in ctx.modules:
        for reg in REGISTRY_NAMES:
            for name, nodes in assigned_names(mod.tree, reg).items():
                out[reg].setdefault(name, (mod.relpath, nodes[0].lineno))
    return out


def _parametrized_policy_names(ctx: AnalysisContext) -> List[tuple]:
    """(name, relpath, lineno) for every string a policy-parametrized
    test enumerates."""
    out: List[tuple] = []
    for mod in ctx.test_modules:
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "parametrize"
                    and node.args):
                continue
            argnames = node.args[0]
            if not (isinstance(argnames, ast.Constant)
                    and isinstance(argnames.value, str)
                    and argnames.value in _POLICY_ARGNAMES):
                continue
            if len(node.args) < 2:
                continue
            values = node.args[1]
            if isinstance(values, (ast.List, ast.Tuple)):
                for e in values.elts:
                    if isinstance(e, ast.Constant) and isinstance(e.value, str):
                        out.append((e.value, mod.relpath, e.lineno))
            # computed parametrizations (sorted(set(A) & set(B))) are
            # evaluated at collection time and cannot go stale — skip
    return out


@register_pass(
    "registry-parity",
    "every VECTOR_SCHEDULERS name has a SCHEDULERS dict twin, every "
    "JAX_POLICIES name has a vector twin, and test parametrizations "
    "only name registered policies",
)
def run(ctx: AnalysisContext) -> List[Finding]:
    regs = _collect_registries(ctx)
    findings: List[Finding] = []
    sched, vec, jaxp = (regs[r] for r in REGISTRY_NAMES)
    if not (sched or vec or jaxp):
        return findings          # tree doesn't define the registries

    for name, (path, line) in sorted(vec.items()):
        if sched and name not in sched:
            findings.append(Finding(
                pass_id="registry-parity", path=path, line=line,
                slug=f"vector-{name}-missing-dict-twin",
                message=(f"VECTOR_SCHEDULERS[{name!r}] has no dict-form "
                         f"SCHEDULERS twin — the dict form is the oracle "
                         f"the dict/vector parity test compares against"),
                hint=(f"add SCHEDULERS[{name!r}] (or baseline this if the "
                      "policy is natively vectorized)"),
            ))
    for name, (path, line) in sorted(jaxp.items()):
        if vec and name not in vec:
            findings.append(Finding(
                pass_id="registry-parity", path=path, line=line,
                slug=f"jax-{name}-missing-vector-twin",
                message=(f"JAX_POLICIES[{name!r}] has no VECTOR_SCHEDULERS "
                         f"twin — the in-scan policy is pinned to its host "
                         f"vector form by the differential fuzz"),
                hint=(f"register a vectorized twin as "
                      f"VECTOR_SCHEDULERS[{name!r}] (or baseline a "
                      "deliberate scan-only deployment mode)"),
            ))

    known: Set[str] = set(sched) | set(vec) | set(jaxp)
    if known:
        for name, path, line in _parametrized_policy_names(ctx):
            if name not in known:
                findings.append(Finding(
                    pass_id="registry-parity", path=path, line=line,
                    slug=f"test-param-{name}-unregistered",
                    message=(f"test parametrizes policy {name!r} which is "
                             f"in none of {', '.join(REGISTRY_NAMES)} — "
                             "stale parity coverage"),
                    hint="rename/remove the parametrization entry",
                ))
    return findings
