"""The checked-in baseline of deliberate exceptions.

One line per accepted finding::

    <pass_id>:<relpath>:<slug>    # why this exception is deliberate

The key matches :attr:`Finding.key` (stable across unrelated edits —
slugs name the violated contract, not a line number).  Every entry
MUST carry a justification comment: a baseline line without one is
itself an error, so exceptions cannot silently accrete.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

from repro.analysis.base import Finding

#: default baseline location, relative to the repo root
DEFAULT_BASELINE = "analysis_baseline.txt"


@dataclass(frozen=True)
class BaselineEntry:
    key: str
    justification: str
    line: int


class BaselineError(ValueError):
    pass


def load_baseline(path: str) -> Dict[str, BaselineEntry]:
    """Parse the baseline file; raises :class:`BaselineError` on an
    entry without a justification comment."""
    entries: Dict[str, BaselineEntry] = {}
    if not os.path.exists(path):
        return entries
    with open(path, encoding="utf-8") as f:
        for lineno, raw in enumerate(f, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            key, sep, why = line.partition("#")
            key = key.strip()
            why = why.strip()
            if not sep or not why:
                raise BaselineError(
                    f"{path}:{lineno}: baseline entry {key!r} has no "
                    "justification comment (append `# why this is "
                    "deliberate`)")
            if key.count(":") < 2:
                raise BaselineError(
                    f"{path}:{lineno}: malformed key {key!r} "
                    "(want <pass_id>:<relpath>:<slug>)")
            if key in entries:
                raise BaselineError(
                    f"{path}:{lineno}: duplicate baseline entry {key!r}")
            entries[key] = BaselineEntry(key, why, lineno)
    return entries


def apply_baseline(
    findings: Sequence[Finding], entries: Dict[str, BaselineEntry],
) -> Tuple[List[Finding], List[Finding], List[BaselineEntry]]:
    """Split findings into (new, baselined) and report stale entries
    (baseline lines matching no current finding — candidates for
    deletion)."""
    new: List[Finding] = []
    baselined: List[Finding] = []
    seen = set()
    for f in findings:
        if f.key in entries:
            baselined.append(f)
            seen.add(f.key)
        else:
            new.append(f)
    stale = [e for k, e in entries.items() if k not in seen]
    return new, baselined, stale
