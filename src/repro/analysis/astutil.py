"""Shared AST helpers for the lint passes."""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name / nested Attribute chain, else None."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def string_elts(node: ast.AST) -> List[Tuple[str, int]]:
    """String literals (with line numbers) in a list/tuple/set literal."""
    out: List[Tuple[str, int]] = []
    if isinstance(node, (ast.List, ast.Tuple, ast.Set)):
        for e in node.elts:
            s = const_str(e)
            if s is not None:
                out.append((s, e.lineno))
    return out


def dict_str_keys(node: ast.Dict,
                  resolve: Optional[Dict[str, str]] = None
                  ) -> List[Tuple[str, int]]:
    """String keys of a dict literal; ``resolve`` maps Name keys (e.g.
    ``EV_ARRIVAL``) to their constant values."""
    out: List[Tuple[str, int]] = []
    for k in node.keys:
        if k is None:          # **expansion
            continue
        s = const_str(k)
        if s is None and resolve is not None and isinstance(k, ast.Name):
            s = resolve.get(k.id)
        if s is not None:
            out.append((s, k.lineno))
    return out


def module_str_constants(tree: ast.AST) -> Dict[str, str]:
    """``NAME = "literal"`` assignments at any level of the module."""
    out: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            val = const_str(node.value)
            if isinstance(tgt, ast.Name) and val is not None:
                out[tgt.id] = val
    return out


def names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def assigned_names(tree: ast.AST, target: str) -> Dict[str, List[ast.AST]]:
    """Collect registry-style names bound to ``target``.

    Returns ``{name: [node, ...]}`` for both forms the codebase uses::

        TARGET = { "name": ..., ... }        # dict-literal keys
        TARGET["name"] = ...                 # later registration
    """
    out: Dict[str, List[ast.AST]] = {}

    def add(name: str, node: ast.AST) -> None:
        out.setdefault(name, []).append(node)

    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]    # NAME: Dict[...] = {...}
        else:
            continue
        for tgt in targets:
            if (isinstance(tgt, ast.Name) and tgt.id == target
                    and isinstance(node.value, ast.Dict)):
                for k in node.value.keys:
                    s = const_str(k) if k is not None else None
                    if s is not None:
                        add(s, k)      # key node → precise lineno
            elif (isinstance(tgt, ast.Subscript)
                    and isinstance(tgt.value, ast.Name)
                    and tgt.value.id == target):
                s = const_str(tgt.slice)
                if s is not None:
                    add(s, node)
    return out


def func_defs(tree: ast.AST) -> List[ast.AST]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def call_names(fn: ast.AST) -> Set[str]:
    """Bare names called (directly or as ``mod.name``-style tails) inside
    a function body — the edges of the name-level call graph."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            d = dotted_name(node.func)
            if d is not None:
                out.add(d)
                out.add(d.split(".")[-1])
            # functions passed by reference (lax.scan(f, ...), vmap(f))
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    out.add(arg.id)
    return out


def literal_default(node: Optional[ast.AST]) -> bool:
    """True when a default value is a static Python literal (bool / int /
    float / str / None) — the convention for trace-time-constant
    keyword parameters in jitted scopes."""
    return isinstance(node, ast.Constant)


def is_name_ref(node: ast.AST, names: Set[str]) -> bool:
    """Does ``node``'s expression tree reference any of ``names``?"""
    return bool(names_in(node) & names)


def enclosing_function(mod, node: ast.AST) -> Optional[ast.AST]:
    for anc in mod.ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def iter_calls(tree: ast.AST) -> Iterable[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node
