from repro.serving.engine import Engine, EngineConfig, Request  # noqa: F401
from repro.serving.batching import ContinuousBatcher  # noqa: F401
