"""Inference engine: slot-based continuous batching over the model zoo.

The engine owns a fixed batch of ``slots`` decode lanes sharing one cache
pytree (the per-sequence ``t`` vector makes ragged lockstep decode safe).
A new request is prefilled at batch 1 and scattered into a free slot; every
``step()`` decodes one token for all live slots.  This is the execution
layer underneath the paper's serving system: a reserved slice runs exactly
this engine, and ``max_concurrency`` from the profile is its slot count.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ModelConfig
from repro.models import model as model_lib


@dataclass(frozen=True)
class EngineConfig:
    slots: int = 8                  # concurrent decode lanes
    cache_len: int = 512            # per-slot KV capacity
    window: int = 0                 # sliding-window mode (long-context)
    max_new_tokens: int = 64
    temperature: float = 0.0        # 0 = greedy
    dtype: Any = jnp.float32


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32 tokens
    max_new_tokens: int = 64
    # filled by the engine:
    output: List[int] = field(default_factory=list)
    prefill_done: bool = False
    finished: bool = False
    enqueued_at: float = 0.0
    finished_at: float = 0.0


def _scatter_slot(cache_tree, sub_tree, slot: int):
    """Write a batch-1 cache into batch slot ``slot`` of the shared cache.

    Cache layout (see model.init_cache): leaves under ``blocks``/``cross``
    are layer-stacked -> batch axis 1; ``tail`` entries and the per-seq
    ``t`` counter are unstacked -> batch axis 0."""
    flat_full = jax.tree_util.tree_flatten_with_path(cache_tree)
    flat_one = jax.tree.leaves(sub_tree)
    out = []
    for (path, full), one in zip(flat_full[0], flat_one):
        keys = [p.key for p in path if hasattr(p, "key")]
        batch_axis = 1 if keys and keys[0] in ("blocks", "cross") else 0
        idx = (slice(None),) * batch_axis + (slot,)
        src = jnp.take(one, 0, axis=batch_axis)
        out.append(full.at[idx].set(src.astype(full.dtype)))
    return jax.tree.unflatten(flat_full[1], out)


class Engine:
    """Continuous-batching engine for one model."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig = EngineConfig()):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.cache = model_lib.init_cache(
            cfg, ecfg.slots, ecfg.cache_len, window=ecfg.window, dtype=ecfg.dtype
        )
        self.slot_req: List[Optional[Request]] = [None] * ecfg.slots
        self.slot_remaining = np.zeros(ecfg.slots, np.int32)
        self.next_token = np.zeros(ecfg.slots, np.int32)
        self.steps = 0

        # jitted single-request prefill (batch 1) and batched decode
        @jax.jit
        def _prefill_one(params, tokens, cache1):
            return model_lib.prefill(
                cfg, params, tokens, cache1, window=ecfg.window
            )

        @jax.jit
        def _decode(params, tokens, cache):
            return model_lib.decode_step(
                cfg, params, tokens, cache, window=ecfg.window
            )

        self._prefill_one = _prefill_one
        self._decode = _decode

    # ------------------------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    @property
    def live(self) -> int:
        return sum(r is not None for r in self.slot_req)

    # ------------------------------------------------------------------
    def insert(self, req: Request, slot: Optional[int] = None) -> int:
        """Prefill ``req`` and install it in a free slot."""
        free = self.free_slots()
        assert free, "no free slot"
        slot = free[0] if slot is None else slot
        cache1 = model_lib.init_cache(
            self.cfg, 1, self.ecfg.cache_len,
            window=self.ecfg.window, dtype=self.ecfg.dtype,
        )
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        logits, cache1 = self._prefill_one(self.params, tokens, cache1)
        first = int(jnp.argmax(logits[0]))

        self.cache = _scatter_slot(self.cache, cache1, slot)
        self.slot_req[slot] = req
        self.slot_remaining[slot] = req.max_new_tokens
        self.next_token[slot] = first
        req.prefill_done = True
        req.output.append(first)
        return slot

    # ------------------------------------------------------------------
    def step(self) -> List[Request]:
        """Decode one token for every live slot; return finished requests."""
        if self.live == 0:
            return []
        tokens = jnp.asarray(self.next_token)
        logits, self.cache = self._decode(self.params, tokens, self.cache)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.steps += 1

        finished = []
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            self.next_token[i] = nxt[i]
            req.output.append(int(nxt[i]))
            self.slot_remaining[i] -= 1
            if self.slot_remaining[i] <= 0:
                req.finished = True
                finished.append(req)
                self.slot_req[i] = None
        return finished
