"""Continuous batching: a request queue feeding the engine's slots.

Implements the serving loop a reserved slice runs: admit waiting requests
into free decode lanes (prefill-on-insert), decode all lanes in lockstep,
retire finished requests, repeat.  Tracks per-request latency so the
serving examples can report SLO attainment like the simulator predicts.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List

import numpy as np

from repro.serving.engine import Engine, Request


@dataclass
class BatchStats:
    admitted: int = 0
    finished: int = 0
    decode_steps: int = 0
    latencies: List[float] = field(default_factory=list)

    def summary(self) -> dict:
        lat = np.array(self.latencies) if self.latencies else np.zeros(1)
        return {
            "admitted": self.admitted,
            "finished": self.finished,
            "decode_steps": self.decode_steps,
            "latency_mean_s": float(lat.mean()),
            "latency_p99_s": float(np.quantile(lat, 0.99)),
        }


class ContinuousBatcher:
    """Drives an :class:`Engine` from a FIFO request queue."""

    def __init__(self, engine: Engine, *, clock=time.perf_counter):
        self.engine = engine
        self.queue: Deque[Request] = deque()
        self.stats = BatchStats()
        self.clock = clock

    def submit(self, req: Request) -> None:
        req.enqueued_at = self.clock()
        self.queue.append(req)

    @property
    def idle(self) -> bool:
        return not self.queue and self.engine.live == 0

    def run_step(self) -> List[Request]:
        """One scheduler iteration: admit -> decode -> retire."""
        # admit as many waiting requests as there are free slots
        for slot in self.engine.free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            self.engine.insert(req, slot)
            self.stats.admitted += 1
        finished = self.engine.step()
        self.stats.decode_steps += 1
        now = self.clock()
        for req in finished:
            req.finished_at = now
            self.stats.latencies.append(now - req.enqueued_at)
            self.stats.finished += 1
        return finished

    def run_until_idle(self, max_steps: int = 100_000) -> BatchStats:
        steps = 0
        while not self.idle and steps < max_steps:
            self.run_step()
            steps += 1
        return self.stats
