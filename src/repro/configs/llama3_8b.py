"""Llama-3-8B — dense GQA reference with 128k vocab.

[arXiv:2407.21783] — 32 layers, d_model 4096, 32 heads (GQA kv=8),
d_ff 14336, vocab 128256.
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("llama3-8b")
def llama3_8b() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b",
        family="dense",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=128256,
        block_pattern=(ATTN,),
        rope_theta=500_000.0,
        mlp="swiglu",
        norm="rmsnorm",
        quality=0.663,          # paper MMLU (8B base)
        source="arXiv:2407.21783",
    )
