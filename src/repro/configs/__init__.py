"""Architecture configs. ``get_config(name)`` is the public entry point."""
from repro.configs.registry import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    get_config,
    list_architectures,
)

_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        phi35_moe,
        rwkv6_1b6,
        llava_next_mistral_7b,
        minicpm_2b,
        qwen2_72b,
        qwen15_0b5,
        recurrentgemma_9b,
        whisper_small,
        kimi_k2,
        llama3_8b,
    )
