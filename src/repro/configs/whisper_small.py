"""Whisper-small — encoder-decoder speech backbone; conv frontend STUBBED.

[arXiv:2212.04356] — 12 encoder + 12 decoder layers, d_model 768,
12 heads (MHA), d_ff 3072, vocab 51865.  ``input_specs`` supplies
precomputed mel+conv frame embeddings (B, 1500, 768) for the encoder.
Decoder context in the real model is <=448 tokens; the assigned decode
shapes are exercised structurally (backbone supports them).
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("whisper-small")
def whisper_small() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        block_pattern=(ATTN,),
        is_encoder_decoder=True,
        encoder_layers=12,
        encoder_seq=1500,
        frontend="audio",
        mlp="gelu",
        norm="layernorm",
        rope_theta=0.0,         # whisper uses learned/sinusoidal abs positions
        quality=0.35,           # capability normalized vs the LM pool
        # (speech specialist; raw 1-WER ~0.91 is not comparable to MMLU)
        source="arXiv:2212.04356",
    )
