"""Qwen1.5-0.5B — small dense with QKV bias (MHA: kv == heads).

[hf:Qwen/Qwen1.5-0.5B] — 24 layers, d_model 1024, 16 heads (kv=16),
d_ff 2816, vocab 151936.
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("qwen1.5-0.5b")
def qwen15_0b5() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b",
        family="dense",
        num_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        qkv_bias=True,
        block_pattern=(ATTN,),
        mlp="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
        quality=0.393,          # model-card MMLU
        source="hf:Qwen/Qwen1.5-0.5B",
    )
