"""Phi-3.5-MoE-instruct (42B total / 6.6B active).

[hf:microsoft/Phi-3.5-MoE-instruct] — 32 layers, d_model 4096, 32 heads
(GQA kv=8), per-expert FFN 6400, vocab 32064, 16 experts top-2.
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6400,
        expert_d_ff=6400,
        vocab_size=32064,
        num_experts=16,
        num_experts_per_tok=2,
        block_pattern=(ATTN,),
        mlp="swiglu",
        norm="rmsnorm",
        quality=0.788,  # model-card MMLU
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )
