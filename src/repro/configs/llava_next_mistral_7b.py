"""LLaVA-NeXT (v1.6) Mistral-7B backbone — anyres tiling VLM.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] — language backbone: 32 layers,
d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 32000.  The vision
tower (CLIP ViT-L + anyres tiling + projector) is a frontend STUB:
``input_specs`` supplies precomputed patch+text embeddings (B, S, d_model).
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("llava-next-mistral-7b")
def llava_next() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=32000,
        block_pattern=(ATTN,),
        frontend="vision",
        mlp="swiglu",
        norm="rmsnorm",
        quality=0.625,          # mistral-7b base MMLU (pool-comparable scale)
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )
