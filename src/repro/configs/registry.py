"""Architecture registry.

Every assigned architecture is a frozen :class:`ModelConfig`.  Configs carry
(1) the exact published hyper-parameters (cited in ``source``), and
(2) the serving metadata the paper's scheduler needs (total/active params,
a published quality score used as the "accuracy" axis of the paper's
model-selection experiments, and memory footprints for the cost model).

``reduced()`` derives the CPU-smoke variant of the same family
(<=2 layers, d_model<=512, <=4 experts) used by tests and examples.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Tuple

# ---------------------------------------------------------------------------
# Block kinds understood by the model builder.
# ---------------------------------------------------------------------------
ATTN = "attn"          # global (full / causal) attention block
LOCAL_ATTN = "local"   # sliding-window attention block
RGLRU = "rglru"        # RG-LRU recurrent block (RecurrentGemma / Griffin)
RWKV = "rwkv"          # RWKV6 time-mix block (Finch)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    expert_d_ff: int = 0             # per-expert hidden dim (kimi style)
    moe_capacity_factor: float = 1.25

    # --- attention ----------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # layer pattern: repeating tuple of block kinds + optional tail
    block_pattern: Tuple[str, ...] = (ATTN,)
    tail_blocks: Tuple[str, ...] = ()
    local_window: int = 0            # window for LOCAL_ATTN blocks
    # sub-quadratic variant used ONLY for the long_500k shape on dense archs
    long_context_window: int = 4096

    # --- recurrent families ---------------------------------------------------
    rwkv_head_dim: int = 64
    rglru_width: int = 0             # 0 -> d_model (RG-LRU state width)

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30 s of audio -> 1500 frames

    # --- modality frontend stub ----------------------------------------------
    frontend: str = "none"           # none | vision | audio
    # vlm: inputs are precomputed patch+text embeddings (B, S, d_model)
    # audio: encoder input is precomputed frame embeddings (B, enc_seq, d)

    # --- activation / norm flavour -------------------------------------------
    mlp: str = "swiglu"              # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False

    # --- serving metadata (paper's model pool) -------------------------------
    quality: float = 0.0             # published aggregate quality (accuracy axis)
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        kinds = set(self.block_pattern) | set(self.tail_blocks)
        return ATTN not in kinds and LOCAL_ATTN not in kinds

    @property
    def subquadratic(self) -> bool:
        """True if decode state is O(1) or O(window) in sequence length."""
        kinds = set(self.block_pattern) | set(self.tail_blocks)
        return ATTN not in kinds

    def layer_kinds(self) -> Tuple[str, ...]:
        """Concrete per-layer block kinds, length == num_layers."""
        kinds = []
        while len(kinds) + len(self.tail_blocks) < self.num_layers:
            kinds.extend(self.block_pattern)
        kinds = kinds[: self.num_layers - len(self.tail_blocks)]
        kinds.extend(self.tail_blocks)
        assert len(kinds) == self.num_layers, (len(kinds), self.num_layers)
        return tuple(kinds)

    # --- parameter counting (analytical; checked against init in tests) ----
    def param_counts(self) -> Dict[str, int]:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        counts: Dict[str, int] = {}
        counts["embed"] = v * d
        counts["lm_head"] = 0 if self.tie_embeddings else v * d

        def attn_params() -> int:
            p = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            if self.qkv_bias:
                p += nq * hd + 2 * nkv * hd
            return p

        def mlp_params(hidden: int) -> int:
            if self.mlp == "swiglu":
                return 3 * d * hidden
            return 2 * d * hidden

        def rwkv_params() -> int:
            # time-mix: r,k,v,g,o projections + data-dependent decay lora
            # + channel-mix (k,v,r) — matches RWKV6 structure.
            tm = 5 * d * d + 2 * d * 96  # decay lora rank ~96
            cm = 2 * d * ff_cm + d * d
            return tm + cm

        ff_cm = ff  # rwkv channel-mix hidden
        per_layer = 0
        total = counts["embed"] + counts["lm_head"]
        for kind in self.layer_kinds():
            if kind in (ATTN, LOCAL_ATTN):
                per_layer = attn_params()
                if self.num_experts:
                    e_ff = self.expert_d_ff or ff
                    per_layer += (
                        self.num_experts * mlp_params(e_ff)
                        + d * self.num_experts  # router
                    )
                else:
                    per_layer += mlp_params(ff)
            elif kind == RGLRU:
                w = self.rglru_width or d
                # conv1d(4) + gates + in/out proj + mlp
                per_layer = 2 * d * w + w * d + 4 * w + 2 * w * w // 8 + mlp_params(ff)
            elif kind == RWKV:
                per_layer = rwkv_params()
            total += per_layer + 2 * d  # two norms
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder already counted; add
            # cross-attention for decoder layers.
            enc = self.encoder_layers * (attn_params() + mlp_params(ff) + 2 * d)
            xattn = self.num_layers * attn_params()
            total += enc + xattn
        counts["total"] = total
        return counts

    @property
    def params_total(self) -> int:
        """Exact parameter count from the abstract init (no allocation)."""
        try:
            from repro.models.model import param_count

            return param_count(self)
        except Exception:  # pragma: no cover — pre-model fallback
            return self.param_counts()["total"]

    @property
    def params_active(self) -> int:
        """Active params per token (MoE uses top-k experts only)."""
        if not self.num_experts:
            return self.params_total
        d = self.d_model
        e_ff = self.expert_d_ff or self.d_ff
        per_expert = 3 * d * e_ff if self.mlp == "swiglu" else 2 * d * e_ff
        inactive = (self.num_experts - self.num_experts_per_tok) * per_expert
        return self.params_total - self.num_layers * inactive

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """CPU smoke variant of the same family (2 layers, d<=512, <=4 experts)."""
        d = min(self.d_model, 256)
        nq = min(self.num_heads, 4)
        nkv = max(1, min(self.num_kv_heads, nq))
        # preserve the GQA flavour: if original had grouped kv, keep ratio 2.
        if self.num_kv_heads < self.num_heads:
            nkv = max(1, nq // 2)
        pattern = self.block_pattern
        tail = ()
        n_layers = max(2, len(pattern))
        if self.is_encoder_decoder:
            n_layers = 2
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d,
            num_heads=nq,
            num_kv_heads=nkv,
            head_dim=d // nq,
            d_ff=min(self.d_ff, 512),
            expert_d_ff=min(self.expert_d_ff, 256) if self.expert_d_ff else 0,
            vocab_size=min(self.vocab_size, 1024),
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2)
            if self.num_experts_per_tok
            else 0,
            tail_blocks=tail,
            encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq=16 if self.is_encoder_decoder else self.encoder_seq,
            local_window=min(self.local_window, 8) if self.local_window else 0,
            long_context_window=64,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in _REGISTRY:
        # late import so ``registry`` has no import-time jax dependency
        from repro.configs import _load_all  # noqa: F401

        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_architectures():
    from repro.configs import _load_all

    _load_all()
    return sorted(_REGISTRY)
