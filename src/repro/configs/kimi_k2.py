"""Kimi K2 — trillion-parameter MoE (1T total / 32B active), paper-table config.

[arXiv:2501.kimi2] — 61 layers, d_model 7168, 64 heads (GQA kv=8),
per-expert FFN 2048, vocab 163840, 384 experts top-8.
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("kimi-k2-1t-a32b")
def kimi_k2() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        head_dim=112,
        d_ff=2048,
        expert_d_ff=2048,
        vocab_size=163840,
        num_experts=384,
        num_experts_per_tok=8,
        block_pattern=(ATTN,),
        mlp="swiglu",
        norm="rmsnorm",
        quality=0.875,          # paper-table MMLU
        source="arXiv:2501.kimi2",
    )
