"""RWKV-6 "Finch" 1.6B — attention-free, data-dependent decay.

[arXiv:2404.05892] — 24 layers, d_model 2048, channel-mix hidden 7168,
vocab 65536, head_dim 64 (32 heads of the matrix-valued WKV state).
"""
from repro.configs.registry import RWKV, ModelConfig, register


@register("rwkv6-1.6b")
def rwkv6() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,           # wkv heads = d_model / rwkv_head_dim
        num_kv_heads=32,
        d_ff=7168,              # channel-mix hidden
        vocab_size=65536,
        block_pattern=(RWKV,),
        rwkv_head_dim=64,
        mlp="gelu",             # channel-mix uses squared-relu-ish; gelu stand-in
        norm="layernorm",
        quality=0.46,           # paper avg benchmark (1.6B scale)
        source="arXiv:2404.05892",
    )
