"""Qwen2-72B — large dense, GQA with QKV bias.

[arXiv:2407.10671] — 80 layers, d_model 8192, 64 heads (GQA kv=8),
d_ff 29568, vocab 152064, QKV bias.
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("qwen2-72b")
def qwen2_72b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b",
        family="dense",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        qkv_bias=True,
        block_pattern=(ATTN,),
        rope_theta=1_000_000.0,
        mlp="swiglu",
        norm="rmsnorm",
        quality=0.842,          # paper MMLU
        source="arXiv:2407.10671",
    )
