"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 2 recurrent : 1 local.

[arXiv:2402.19427] — 38 layers, d_model 4096, 16 heads (GQA kv=1 => MQA),
d_ff 12288, vocab 256000, local attention window 2048.

Pattern: (rglru, rglru, local) repeating; 38 = 12*3 + 2 -> tail (rglru, rglru).
"""
from repro.configs.registry import LOCAL_ATTN, RGLRU, ModelConfig, register


@register("recurrentgemma-9b")
def recurrentgemma() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
        tail_blocks=(RGLRU, RGLRU),
        local_window=2048,
        rglru_width=4096,
        mlp="gelu",             # gated gelu in the paper
        norm="rmsnorm",
        quality=0.607,          # paper MMLU (9B IT)
        source="arXiv:2402.19427",
    )
