"""MiniCPM-2B — llama-like dense model trained with the WSD schedule.

[arXiv:2404.06395] — 40 layers, d_model 2304, 36 heads (kv=36, i.e. MHA),
d_ff 5760, vocab 122753.  The WSD (warmup-stable-decay) schedule is
implemented in ``repro.training.schedule`` and exercised by the training
example.
"""
from repro.configs.registry import ATTN, ModelConfig, register


@register("minicpm-2b")
def minicpm() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b",
        family="dense",
        num_layers=40,
        d_model=2304,
        num_heads=36,
        num_kv_heads=36,
        d_ff=5760,
        vocab_size=122753,
        block_pattern=(ATTN,),
        mlp="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,    # MiniCPM ties embeddings
        quality=0.536,          # paper MMLU
        source="arXiv:2404.06395",
    )
