"""Training step + loop."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.registry import ModelConfig
from repro.models import model as model_lib
from repro.training.optimizer import OptimizerConfig, adamw_init, adamw_update
from repro.training.schedule import ScheduleConfig, make_schedule


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    schedule: ScheduleConfig = ScheduleConfig()
    window: int = 0                  # sliding-window attention (0 = full)
    moe_path: str = "local"          # local | ep_a2a | dense
    remat: object = True      # False | True | 'dots'
    aux_weight: float = 0.01


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Pure function of its arguments — safe to jit/lower with ShapeDtypeStructs.
    """
    sched = make_schedule(tcfg.schedule)

    def train_step(params, opt_state, batch):
        def loss_of(p):
            return model_lib.loss_fn(
                cfg, p, batch,
                window=tcfg.window, moe_path=tcfg.moe_path,
                remat=tcfg.remat, aux_weight=tcfg.aux_weight,
            )

        (loss, parts), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
        lr = sched(opt_state["step"])
        params, opt_state, om = adamw_update(
            params, grads, opt_state, tcfg.optimizer, lr=lr
        )
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def train(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    data_iter,
    num_steps: int,
    *,
    seed: int = 0,
    param_dtype=jnp.float32,
    log_every: int = 10,
    callback: Optional[Callable[[int, Dict[str, Any]], None]] = None,
):
    """Single-host training loop (CPU-runnable on reduced configs)."""
    key = jax.random.key(seed)
    params = model_lib.init_params(cfg, key, dtype=param_dtype)
    opt_state = adamw_init(params, tcfg.optimizer)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    history = []
    t0 = time.perf_counter()
    for step in range(num_steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(step, m)
    return params, opt_state, history
