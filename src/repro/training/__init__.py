from repro.training.optimizer import adamw_init, adamw_update, OptimizerConfig  # noqa: F401
from repro.training.schedule import make_schedule, ScheduleConfig  # noqa: F401
