"""LR schedules — WSD (warmup-stable-decay, MiniCPM arXiv:2404.06395),
cosine, and linear."""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class ScheduleConfig:
    kind: str = "wsd"            # wsd | cosine | linear | constant
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    # WSD: decay starts at ``decay_start`` fraction of total (MiniCPM: ~0.9)
    decay_start_frac: float = 0.9
    min_lr_frac: float = 0.1


def make_schedule(cfg: ScheduleConfig):
    def sched(step):
        s = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.kind == "constant":
            frac = 1.0
        elif cfg.kind == "linear":
            frac = 1.0 - jnp.clip(
                (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
                0.0, 1.0,
            ) * (1.0 - cfg.min_lr_frac)
        elif cfg.kind == "cosine":
            prog = jnp.clip(
                (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
                0.0, 1.0,
            )
            frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * prog)
            )
        elif cfg.kind == "wsd":
            decay_start = cfg.decay_start_frac * cfg.total_steps
            # stable at 1.0 until decay_start, then exponential-ish decay to min
            prog = jnp.clip(
                (s - decay_start) / max(cfg.total_steps - decay_start, 1), 0.0, 1.0
            )
            frac = jnp.where(
                s < decay_start, 1.0, cfg.min_lr_frac ** prog
            )
        else:
            raise ValueError(cfg.kind)
        return cfg.peak_lr * warm * frac

    return sched
