"""Pure-JAX AdamW with global-norm clipping.

``state_dtype=bfloat16`` halves optimizer memory (m, v in bf16) — used by the
1T-parameter Kimi-K2 training config, where fp32 states would not fit the
single-pod HBM budget (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


def adamw_init(params, opt_cfg: OptimizerConfig):
    zeros = lambda p: jnp.zeros(p.shape, dtype=opt_cfg.state_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    params,
    grads,
    opt_state,
    opt_cfg: OptimizerConfig,
    lr: Optional[jax.Array] = None,
) -> Tuple[Any, Any, dict]:
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = jnp.asarray(opt_cfg.lr if lr is None else lr, jnp.float32)

    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, opt_cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = opt_cfg.b1, opt_cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + opt_cfg.eps)
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (delta + opt_cfg.weight_decay * p32)
        return (
            new_p.astype(p.dtype),
            m32.astype(opt_cfg.state_dtype),
            v32.astype(opt_cfg.state_dtype),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
