"""Synthetic tokenized LM data pipeline.

Deterministic, seeded, and cheap: a Zipfian token stream with short-range
structure (Markov-ish bigram mixing) so a model actually has something to
learn in the training examples — loss decreases measurably within a few
hundred steps, unlike uniform-random tokens.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np


class SyntheticLM:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch_size: int,
        *,
        seed: int = 0,
        embed_dim: Optional[int] = None,   # if set, yields embeddings (VLM stub)
        enc_seq: Optional[int] = None,     # if set, adds encoder frames (audio stub)
        d_model: Optional[int] = None,
    ):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch_size
        self.rng = np.random.default_rng(seed)
        self.embed_dim = embed_dim
        self.enc_seq = enc_seq
        self.d_model = d_model
        # Zipf weights over a capped support for speed
        support = min(vocab_size, 50_000)
        w = 1.0 / np.arange(1, support + 1) ** 1.1
        self.probs = w / w.sum()
        self.support = support
        # bigram successor table: token t prefers (t*7+3)%support
        self.succ = (np.arange(support) * 7 + 3) % support

    def _sample_seq(self) -> np.ndarray:
        out = np.empty(self.seq + 1, dtype=np.int32)
        out[0] = self.rng.choice(self.support, p=self.probs)
        noise = self.rng.random(self.seq)
        fresh = self.rng.choice(self.support, p=self.probs, size=self.seq)
        for i in range(1, self.seq + 1):
            out[i] = self.succ[out[i - 1]] if noise[i - 1] < 0.7 else fresh[i - 1]
        return out

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        seqs = np.stack([self._sample_seq() for _ in range(self.batch)])
        batch = {
            "inputs": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
        }
        if self.embed_dim is not None:
            batch["inputs"] = self.rng.standard_normal(
                (self.batch, self.seq, self.embed_dim), dtype=np.float32
            )
        if self.enc_seq is not None:
            batch["enc_inputs"] = self.rng.standard_normal(
                (self.batch, self.enc_seq, self.d_model), dtype=np.float32
            )
        return batch
