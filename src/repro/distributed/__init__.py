from repro.distributed.sharding import (  # noqa: F401
    AxisRules,
    axis_rules,
    current_rules,
    logical_to_spec,
    shard,
    spec_for_axes,
)
