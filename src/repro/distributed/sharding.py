"""Logical-axis sharding.

Model code names tensor dimensions with *logical* axes ("batch", "heads",
"ff", ...).  A mesh-specific :class:`AxisRules` maps logical axes to mesh
axes; ``shard(x, *axes)`` applies ``with_sharding_constraint`` only when
rules are active, so the exact same model code runs on 1 CPU device (tests)
and on the 512-chip production mesh (dry-run) unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


def device_mesh(axis: str = "grid", devices=None) -> Optional[Mesh]:
    """A 1-D mesh over all local devices, or ``None`` on a single
    device (callers fall back to their unsharded path).  ``axis`` names
    the mesh axis data-parallel batch dimensions shard over."""
    devices = list(jax.devices()) if devices is None else list(devices)
    if len(devices) <= 1:
        return None
    return Mesh(np.array(devices), (axis,))


@dataclass
class AxisRules:
    mesh: Mesh
    rules: Dict[str, MeshAxes] = field(default_factory=dict)

    def mesh_axes(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        return self.rules.get(logical)


def current_rules() -> Optional[AxisRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def axis_rules(rules: Optional[AxisRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def logical_to_spec(axes: Sequence[Optional[str]], rules: Optional[AxisRules] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec under ``rules``.

    Mesh axes already consumed by an earlier dimension are dropped (a mesh
    axis may shard at most one dimension of a tensor).
    """
    rules = rules or current_rules()
    if rules is None:
        return P()
    used = set()
    parts = []
    for ax in axes:
        m = rules.mesh_axes(ax)
        if m is None:
            parts.append(None)
            continue
        m_tuple = (m,) if isinstance(m, str) else tuple(m)
        m_tuple = tuple(a for a in m_tuple if a not in used and a in rules.mesh.axis_names)
        if not m_tuple:
            parts.append(None)
            continue
        used.update(m_tuple)
        parts.append(m_tuple[0] if len(m_tuple) == 1 else m_tuple)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def spec_for_axes(axes: Sequence[Optional[str]], rules: Optional[AxisRules] = None):
    """NamedSharding for a logical-axes tuple (for in_shardings)."""
    rules = rules or current_rules()
    if rules is None:
        return None
    return NamedSharding(rules.mesh, logical_to_spec(axes, rules))


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes (no-op without rules)."""
    rules = current_rules()
    if rules is None:
        return x
    assert x.ndim == len(axes), (x.shape, axes)
    spec = logical_to_spec(axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))
