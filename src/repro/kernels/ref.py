"""Pure-jnp oracles.

These functions are simultaneously (1) the XLA execution path used by the
models on CPU and in the dry-run, and (2) the reference oracles that every
Pallas kernel is validated against (``tests/test_kernels.py``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _group_heads(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, nq, hd) -> (B, S, nkv, group, hd)."""
    b, s, nq, hd = q.shape
    assert nq % num_kv == 0, (nq, num_kv)
    return q.reshape(b, s, num_kv, nq // num_kv, hd)


def mha_reference(
    q: jax.Array,                    # (B, Sq, nq, hd)
    k: jax.Array,                    # (B, Sk, nkv, hd)
    v: jax.Array,                    # (B, Sk, nkv, hd)
    *,
    causal: bool = True,
    window: int = 0,                 # 0 = unlimited; else sliding window
    q_offset: int = 0,               # absolute position of q[0] relative to k[0]
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """Quadratic attention with fp32 softmax. Returns (B, Sq, nq, hd)."""
    b, sq, nq, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    qg = _group_heads(q, nkv)                          # (B,Sq,nkv,g,hd)
    scale = hd ** -0.5
    # f32 ACCUMULATION without materializing f32 copies of K/V — converting
    # a 32k-token cache to f32 per layer dominated decode memory traffic
    # (EXPERIMENTS.md §Perf, llama3-8b decode_32k iteration 2)
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32
    ) * scale                                          # (B,nkv,g,Sq,Sk)
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if bias is not None:
        logits = logits + bias.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, sq, nq, hd).astype(q.dtype)


def decode_attention_reference(
    q: jax.Array,                    # (B, nq, hd) — a single new token per seq
    k_cache: jax.Array,              # (B, S, nkv, hd)
    v_cache: jax.Array,              # (B, S, nkv, hd)
    valid: jax.Array,                # (B, S) bool — which cache slots attend
) -> jax.Array:
    """Single-token flash-decode oracle. Returns (B, nq, hd)."""
    b, nq, hd = q.shape
    nkv = k_cache.shape[2]
    qg = q.reshape(b, nkv, nq // nkv, hd)
    scale = hd ** -0.5
    # f32 accumulation, bf16 cache reads (no materialized f32 cache copy)
    logits = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgs,bskh->bkgh", probs.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, nq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# RWKV6 ("Finch") chunkwise linear-attention recurrence.
#
# Per head with state S in R^{hd x hd}:
#   S_t = diag(w_t) S_{t-1} + k_t v_t^T
#   o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t        (bonus term u on current)
# w_t in (0,1) is the data-dependent decay.
# ---------------------------------------------------------------------------
def rwkv6_reference(
    r: jax.Array,                    # (B, T, H, hd)
    k: jax.Array,                    # (B, T, H, hd)
    v: jax.Array,                    # (B, T, H, hd)
    w: jax.Array,                    # (B, T, H, hd) decay in (0,1)
    u: jax.Array,                    # (H, hd) per-head bonus
    state: Optional[jax.Array] = None,  # (B, H, hd, hd)
):
    """Sequential oracle. Returns (out (B,T,H,hd), final_state)."""
    b, t, h, d = r.shape
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((b, h, d, d), dtype=f32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                     # each (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]   # (B,H,hd,hd)
        o = jnp.einsum(
            "bhij,bhi->bhj", S + u[None, :, :, None] * kv, r_t
        )
        S = w_t[..., :, None] * S + kv
        return S, o

    xs = tuple(
        jnp.moveaxis(a.astype(f32), 1, 0) for a in (r, k, v, w)
    )
    state, out = jax.lax.scan(step, state.astype(f32), xs)
    out = jnp.moveaxis(out, 0, 1)                    # (B,T,H,hd)
    return out.astype(r.dtype), state


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) elementwise gated linear recurrence.
#   h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
# with a_t = exp(-c * softplus(Lambda) * sigmoid(r_t)).
# ---------------------------------------------------------------------------
def rglru_reference(
    x: jax.Array,                    # (B, T, D) gated input
    a: jax.Array,                    # (B, T, D) decay in (0,1)
    h0: Optional[jax.Array] = None,  # (B, D)
):
    b, t, d = x.shape
    f32 = jnp.float32
    if h0 is None:
        h0 = jnp.zeros((b, d), dtype=f32)

    def step(h, inp):
        x_t, a_t = inp
        h = a_t * h + jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 0.0)) * x_t
        return h, h

    xs = (jnp.moveaxis(x.astype(f32), 1, 0), jnp.moveaxis(a.astype(f32), 1, 0))
    hT, out = jax.lax.scan(step, h0.astype(f32), xs)
    return jnp.moveaxis(out, 0, 1).astype(x.dtype), hT


# ---------------------------------------------------------------------------
# Blocked sliding-window attention (XLA path).
#
# ``mha_reference`` with a window only MASKS the (Sq, Sk) logits — the
# quadratic compute/traffic remains (EXPERIMENTS.md §Perf, whisper
# prefill_32k iteration 1, refuted).  This computes the same function
# block-locally: queries in block i attend keys in blocks {i-1, i}, exact
# for window <= block size.  O(S * 2W) logits instead of O(S^2).
# ---------------------------------------------------------------------------
def local_attention_blocked(
    q: jax.Array,                    # (B, S, nq, hd)
    k: jax.Array,                    # (B, S, nkv, hd)
    v: jax.Array,                    # (B, S, nkv, hd)
    *,
    window: int,
    q_offset: int = 0,
) -> jax.Array:
    b, s, nq, hd = q.shape
    nkv = k.shape[2]
    assert window > 0
    assert q_offset == 0, "blocked path assumes q/k aligned at position 0"
    blk = window
    s_p = -(-s // blk) * blk
    if s_p != s:
        pad = ((0, 0), (0, s_p - s), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    nb = s_p // blk

    qb = q.reshape(b, nb, blk, nq, hd)
    kb = k.reshape(b, nb, blk, nkv, hd)
    vb = v.reshape(b, nb, blk, nkv, hd)
    # keys for block i: [block i-1 ; block i]   (first block: zeros, masked)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)         # (B, nb, 2W, nkv, hd)
    v2 = jnp.concatenate([v_prev, vb], axis=2)

    qg = qb.reshape(b, nb, blk, nkv, nq // nkv, hd)
    scale = hd ** -0.5
    logits = jnp.einsum(
        "bnqkgh,bnskh->bnkgqs", qg, k2, preferred_element_type=jnp.float32
    ) * scale                                          # (B,nb,nkv,g,W,2W)

    ib = jnp.arange(nb)[:, None, None]
    qpos = q_offset + ib * blk + jnp.arange(blk)[None, :, None]   # (nb, W, 1)
    kpos = (ib - 1) * blk + jnp.arange(2 * blk)[None, None, :]    # (nb, 1, 2W)
    mask = (kpos >= 0) & (kpos <= qpos) & (kpos > qpos - window)
    logits = jnp.where(mask[None, :, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bnkgqs,bnskh->bnqkgh", probs.astype(v2.dtype), v2,
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, s_p, nq, hd)
    return out[:, :s].astype(q.dtype)
