"""Jit'd dispatch wrappers around the compute hot-spots.

``impl`` selects the execution path:
  * ``"xla"``               — pure-jnp (ref.py), the default; used by CPU
                               tests and by the dry-run lowering.
  * ``"pallas"``            — the Pallas TPU kernel (TARGET hardware).
  * ``"pallas_interpret"``  — the same kernel body interpreted on CPU
                               (correctness validation in this container).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("xla", "pallas", "pallas_interpret"), impl
    _DEFAULT_IMPL = impl


def default_impl() -> str:
    return _DEFAULT_IMPL


def _resolve(impl: Optional[str]) -> str:
    return impl or _DEFAULT_IMPL


# ---------------------------------------------------------------------------
def flash_attention(
    q, k, v, *, causal=True, window=0, q_offset=0, impl=None
):
    """Full-sequence attention (B,Sq,nq,hd)x(B,Sk,nkv,hd)->(B,Sq,nq,hd)."""
    impl = _resolve(impl)
    if impl == "xla":
        if (
            causal and window > 0 and q.shape[1] == k.shape[1]
            and q.shape[1] > 2 * window and q_offset == 0
        ):
            # sliding window pays for itself only computed block-locally:
            # O(S*2W) logits instead of masked O(S^2) (§Perf iteration)
            return ref.local_attention_blocked(
                q, k, v, window=window, q_offset=q_offset
            )
        return ref.mha_reference(q, k, v, causal=causal, window=window, q_offset=q_offset)
    from repro.kernels import flash_attention as fa

    return fa.flash_attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        interpret=(impl == "pallas_interpret"),
    )


def decode_attention(q, k_cache, v_cache, valid, *, impl=None):
    """Single-token decode attention (B,nq,hd) vs (B,S,nkv,hd)."""
    impl = _resolve(impl)
    if impl == "xla":
        return ref.decode_attention_reference(q, k_cache, v_cache, valid)
    from repro.kernels import decode_attention as da

    return da.decode_attention(
        q, k_cache, v_cache, valid, interpret=(impl == "pallas_interpret")
    )


def rwkv6(r, k, v, w, u, state=None, *, impl=None):
    impl = _resolve(impl)
    if impl == "xla":
        return ref.rwkv6_reference(r, k, v, w, u, state)
    from repro.kernels import rwkv6_scan

    return rwkv6_scan.rwkv6_chunked(
        r, k, v, w, u, state, interpret=(impl == "pallas_interpret")
    )


def rglru(x, a, h0=None, *, impl=None):
    impl = _resolve(impl)
    # RG-LRU is elementwise; the XLA associative_scan path is already
    # TPU-optimal (log-depth, no matmul) — used for every impl. Kept as an
    # ops entry point so the serving engine has a single dispatch surface.
    del impl
    return _rglru_assoc(x, a, h0)


def _rglru_assoc(x, a, h0=None):
    """Associative-scan RG-LRU: h_t = a_t h_{t-1} + b_t with log-depth scan."""
    f32 = jnp.float32
    b_term = jnp.sqrt(jnp.maximum(1.0 - a.astype(f32) ** 2, 0.0)) * x.astype(f32)
    a32 = a.astype(f32)
    if h0 is not None:
        # fold the carried state into the first step's additive term
        b_term = b_term.at[:, 0].add(a32[:, 0] * h0.astype(f32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a32, b_term), axis=1)
    return hh.astype(x.dtype), hh[:, -1]
