"""FlashAttention-2 prefill kernel (Pallas, TPU target).

Tiling: grid = (batch, q_heads, Sq/BQ, Sk/BK); the KV axis is the
innermost (sequential on TPU) grid dimension, so the online-softmax
running statistics (m, l) and the f32 accumulator live in VMEM scratch
carried across KV steps.  Blocks are MXU-aligned (128x128 by default).
GQA is handled in the index maps (query head h reads KV head h // group);
causal and sliding-window masks are applied from block-relative position
arithmetic, so no (Sq, Sk) mask tensor ever materializes.

Validated on CPU with ``interpret=True`` against ``ref.mha_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    causal: bool,
    window: int,
    q_offset: int,
    bq: int,
    bk: int,
    sk_actual: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)        # (BQ, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)        # (BK, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)        # (BK, hd)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                         # (BQ, BK)

    qpos = q_offset + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk_actual
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,                    # (B, Sq, nq, hd)
    k: jax.Array,                    # (B, Sk, nkv, hd)
    v: jax.Array,                    # (B, Sk, nkv, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, sq, nq, hd = q.shape
    sk, nkv = k.shape[1], k.shape[2]
    assert nq % nkv == 0, (nq, nkv)
    group = nq // nkv
    scale = hd ** -0.5

    bq = min(block_q, _ceil_to(sq, 8))
    bk = min(block_k, _ceil_to(sk, 8))
    sq_p, sk_p = _ceil_to(sq, bq), _ceil_to(sk, bk)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))

    grid = (b, nq, sq_p // bq, sk_p // bk)

    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            scale=scale, causal=causal, window=window,
            q_offset=q_offset, bq=bq, bk=bk, sk_actual=sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b_, h, iq, ik: (b_, iq, h, 0)),
            pl.BlockSpec(
                (1, bk, 1, hd), lambda b_, h, iq, ik, g=group: (b_, ik, h // g, 0)
            ),
            pl.BlockSpec(
                (1, bk, 1, hd), lambda b_, h, iq, ik, g=group: (b_, ik, h // g, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b_, h, iq, ik: (b_, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq_p, nq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # acc
            pltpu.VMEM((bq,), jnp.float32),      # m (running max)
            pltpu.VMEM((bq,), jnp.float32),      # l (running sum)
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
