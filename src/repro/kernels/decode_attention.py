"""Flash-decode kernel (Pallas, TPU target).

The decode hot loop: ONE query token per sequence attending to a long KV
cache.  Grid = (batch, q_heads, S/BK) with the KV axis innermost
(sequential), so the running softmax statistics live in VMEM scratch and
the cache streams HBM->VMEM in (BK, hd) tiles — this kernel is pure
memory traffic, which is exactly what the ``decode_32k`` / ``long_500k``
roofline says dominates.

Invalid cache slots (ring-buffer holes, beyond-horizon positions) are
masked via the ``valid`` (B, S) boolean the engine derives from
``slot_pos``.  Validated with ``interpret=True`` against
``ref.decode_attention_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    q_ref, k_ref, v_ref, valid_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :].astype(jnp.float32)            # (hd,)
    k = k_ref[0, :, 0, :].astype(jnp.float32)         # (BK, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)         # (BK, hd)
    valid = valid_ref[0, :]                           # (BK,) bool

    s = jnp.einsum("h,kh->k", q, k) * scale           # (BK,)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)     # (BK,)

    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.einsum("k,kh->h", p, v)[None]
    m_ref[0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[0]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0, :] = (acc_ref[0] / safe).astype(o_ref.dtype)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,                    # (B, nq, hd) — one token per sequence
    k_cache: jax.Array,              # (B, S, nkv, hd)
    v_cache: jax.Array,              # (B, S, nkv, hd)
    valid: jax.Array,                # (B, S) bool
    *,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, nq, hd = q.shape
    s, nkv = k_cache.shape[1], k_cache.shape[2]
    assert nq % nkv == 0
    group = nq // nkv
    scale = hd ** -0.5

    bk = min(block_k, _ceil_to(s, 8))
    s_p = _ceil_to(s, bk)
    if s_p != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_p - s), (0, 0), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, s_p - s)))

    grid = (b, nq, s_p // bk)

    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b_, h, ik: (b_, h, 0)),
            pl.BlockSpec(
                (1, bk, 1, hd), lambda b_, h, ik, g=group: (b_, ik, h // g, 0)
            ),
            pl.BlockSpec(
                (1, bk, 1, hd), lambda b_, h, ik, g=group: (b_, ik, h // g, 0)
            ),
            pl.BlockSpec((1, bk), lambda b_, h, ik: (b_, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda b_, h, ik: (b_, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, valid)
