"""RWKV-6 chunkwise recurrence kernel (Pallas, TPU target).

The sequential oracle is O(T) steps of rank-1 state updates — hopeless on
a systolic machine.  This kernel processes the sequence in chunks of C
tokens per grid step with the (hd, hd) state carried in VMEM scratch:

  within a chunk (log-space cumulative decay  la_t = sum_{s<=t} log w_s):
    o_t  = (r_t * exp(la_{t-1})) . S0            (carry-in state term)
         + sum_{s<t} [ sum_i r_ti k_si e^{la_{t-1,i}-la_{s,i}} ] v_s
         + ((r_t * u) . k_t) v_t                 (bonus diagonal)
    S_C  = diag(e^{la_C}) S0 + sum_s (k_s * e^{la_C - la_s}) v_s^T

The intra-chunk pair term keeps the decay ratio INSIDE the reduction over
the head dim (a (C, C, hd) broadcast) rather than factorizing it into
k / a_s — the factorized form overflows when the data-dependent decay is
strong (exp(+la) with la ~ -50/token), the broadcast form is always
bounded by 1.  That trades MXU matmuls for VPU work on a (C, C, hd) tile;
with C = 32, hd = 64 the tile is 256 KB in VMEM — the TPU-native sweet
spot for this recurrence (DESIGN.md 'hardware adaptation').

Validated with ``interpret=True`` against ``ref.rwkv6_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(
    r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
    o_ref, sT_ref,
    state_ref,
    *,
    chunk: int,
):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)         # (C, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)               # (hd,)
    S = state_ref[...]                                # (hd, hd) f32

    logw = jnp.log(jnp.maximum(w, 1e-38))             # (C, hd) <= 0
    la = jnp.cumsum(logw, axis=0)                     # la_t = sum_{s<=t}
    la_prev = la - logw                               # la_{t-1} (la_0 = 0)

    # carry-in state term: (r_t * e^{la_{t-1}}) @ S
    r_dec = r * jnp.exp(la_prev)                      # (C, hd)
    o_state = jax.lax.dot_general(
        r_dec, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                 # (C, hd_v)

    # intra-chunk pair scores: A[t, s] = sum_i r_ti k_si e^{la_{t-1,i}-la_{s,i}}
    ratio = jnp.exp(la_prev[:, None, :] - la[None, :, :])   # (C, C, hd) <= 1 for s<t
    A = jnp.sum(r[:, None, :] * k[None, :, :] * ratio, axis=-1)  # (C, C)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(s_idx < t_idx, A, 0.0)              # strictly lower
    o_intra = jax.lax.dot_general(
        A, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # bonus diagonal: ((r_t * u) . k_t) v_t
    bonus = jnp.sum(r * u[None, :] * k, axis=-1)      # (C,)
    o = o_state + o_intra + bonus[:, None] * v
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)

    # state update: S_C = diag(e^{la_C}) S + sum_s (k_s e^{la_C - la_s}) v_s^T
    la_C = la[-1]                                     # (hd,)
    k_dec = k * jnp.exp(la_C[None, :] - la)           # (C, hd), bounded
    S_new = jnp.exp(la_C)[:, None] * S + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_ref[...] = S_new

    @pl.when(ic == nc - 1)
    def _finalize():
        sT_ref[0, 0] = S_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked(
    r: jax.Array,                    # (B, T, H, hd)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,                    # decay in (0, 1)
    u: jax.Array,                    # (H, hd)
    state=None,                      # (B, H, hd, hd) f32
    *,
    chunk: int = 32,
    interpret: bool = False,
):
    """Returns (out (B,T,H,hd), final_state (B,H,hd,hd) f32)."""
    b, t, h, hd = r.shape
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)

    c = min(chunk, t)
    t_p = -(-t // c) * c
    if t_p != t:
        pad = ((0, 0), (0, t_p - t), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, pad), jnp.pad(k, pad), jnp.pad(v, pad)
        w = jnp.pad(w, pad, constant_values=1.0)   # decay 1 = no-op steps

    grid = (b, h, t_p // c)
    seq_spec = pl.BlockSpec((1, c, 1, hd), lambda b_, h_, ic: (b_, ic, h_, 0))

    out, s_final = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=c),
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hd), lambda b_, h_, ic: (h_, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda b_, h_, ic: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, t_p, h, hd), r.dtype),
            jax.ShapeDtypeStruct((b, h, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return out[:, :t], s_final
