"""Training driver.

Trains any registered architecture on the synthetic LM pipeline.  On this
CPU container use ``--reduced`` or explicit size overrides; on real
hardware the same ``make_train_step`` lowers under the production mesh
(see ``launch/dryrun.py`` for the sharded step).

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
      --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json


from repro.configs import get_config
from repro.training.data import SyntheticLM
from repro.training.optimizer import OptimizerConfig
from repro.training.schedule import ScheduleConfig
from repro.training.train_loop import TrainConfig, train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="wsd",
                    choices=("wsd", "cosine", "linear", "constant"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name}: params={cfg.params_total / 1e6:.1f}M "
          f"schedule={args.schedule}", flush=True)

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(lr=args.lr),
        schedule=ScheduleConfig(
            kind=args.schedule, peak_lr=args.lr,
            warmup_steps=max(10, args.steps // 10), total_steps=args.steps,
        ),
    )
    data = SyntheticLM(
        cfg.vocab_size, args.seq, args.batch, seed=args.seed,
        enc_seq=cfg.encoder_seq if cfg.is_encoder_decoder else None,
        d_model=cfg.d_model if cfg.is_encoder_decoder else None,
    )

    def log(step, m):
        print(f"[train] step={step:4d} loss={m['loss']:.4f} "
              f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.3f} "
              f"wall={m['wall_s']:.1f}s", flush=True)

    params, opt_state, history = train(
        cfg, tcfg, iter(data), args.steps,
        seed=args.seed, log_every=args.log_every, callback=log,
    )
    first, last = history[0]["loss"], history[-1]["loss"]
    print(json.dumps({
        "arch": cfg.name, "steps": args.steps,
        "loss_first": round(first, 4), "loss_last": round(last, 4),
        "improved": bool(last < first),
    }))


if __name__ == "__main__":
    main()
