import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# NOTE on cost accounting: XLA's cost model counts a while-loop (lax.scan)
# body ONCE — it does not multiply by the trip count — so a naive
# cost_analysis() of the scanned layer stack under-reports FLOPs/bytes/
# collectives by ~num_layers x.  run_one() therefore compiles THREE
# programs per combo:
#   1. the FULL config with lax.scan  -> lowering proof + memory_analysis
#   2. two UNROLLED probes at K=2 and K=4 pattern-repeats -> per-layer
#      costs by affine extrapolation (exact: layer costs are affine in
#      the repeat count; embed/unembed/optimizer are the intercept).
# See model._scan / REPRO_UNROLL_SCANS.

# ruff: noqa: E402  — the two lines above MUST precede any jax import.
"""Multi-pod dry-run.

For every (architecture x input-shape x mesh) combination:
  lower the step (train_step / prefill / serve_step) with production
  shardings, compile it, and record memory_analysis / cost_analysis /
  per-collective byte counts into a JSON artifact that §Roofline and the
  benchmarks read.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all            # every combo, both meshes
"""
import argparse
import json
import re
import time
import traceback
from typing import Dict

import jax

from repro.configs import INPUT_SHAPES, get_config, list_architectures
from repro.distributed.sharding import axis_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _type_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by every collective, from optimized HLO.

    Optimized HLO does not annotate operand types inline, so we read the
    RESULT type (left of ``= <type> <opcode>(``) and convert it to moved
    bytes with the standard ring-algorithm factors:

      all-gather          ~ result * (S-1)/S          (result is gathered)
      all-reduce          ~ 2 * result * (S-1)/S      (RS + AG phases)
      reduce-scatter      ~ result * (S-1)            (input is S x result)
      all-to-all          ~ result * (S-1)/S
      collective-permute  ~ result

    S (shard-group size) parsed from ``replica_groups=[G,S]``; S=1 when a
    collective has no cross-device group (cost 0).
    """
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq == -1:
            continue
        rhs = s[eq + 3:]
        for c in _COLLECTIVES:
            idx = rhs.find(f" {c}(")
            if idx == -1:
                continue
            if f"{c}-start" in rhs:
                continue  # async start; its -done carries the final type
            result_seg = rhs[:idx]
            nbytes = sum(_type_bytes(m) for m in _SHAPE_RE.finditer(result_seg))
            m = _GROUPS_RE.search(rhs)
            group = int(m.group(2)) if m else 1
            if group <= 1:
                factor = 0.0
            elif c == "all-reduce":
                factor = 2.0 * (group - 1) / group
            elif c == "reduce-scatter":
                factor = float(group - 1)
            elif c == "collective-permute":
                factor = 1.0
            else:  # all-gather, all-to-all
                factor = (group - 1) / group
            out[c] += int(nbytes * factor)
            out["count"] += 1
            break
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def _probe_cfg(cfg, n_rep: int):
    """Same family, ``n_rep`` pattern repeats (+ original tail blocks)."""
    import dataclasses as _dc

    kw = dict(num_layers=n_rep * len(cfg.block_pattern) + len(cfg.tail_blocks))
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = n_rep
    return _dc.replace(cfg, **kw)


def _compile_metrics(cfg, shape, mesh, *, unroll: bool,
                     moe_path=None, donate: bool = False,
                     window_override=None, remat=True):
    """Lower+compile one step; return (compiled-metrics dict, rules)."""
    prev = os.environ.get("REPRO_UNROLL_SCANS")
    os.environ["REPRO_UNROLL_SCANS"] = "1" if unroll else "0"
    try:
        t0 = time.perf_counter()
        step, args, in_shardings, rules, dn = build_step(
            cfg, shape, mesh, moe_path=moe_path,
            window_override=window_override, remat=remat,
        )
        with mesh, axis_rules(rules):
            jitted = jax.jit(
                step, in_shardings=in_shardings,
                donate_argnums=dn if donate else (),
            )
            lowered = jitted.lower(*args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
    finally:
        if prev is None:
            os.environ.pop("REPRO_UNROLL_SCANS", None)
        else:
            os.environ["REPRO_UNROLL_SCANS"] = prev

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    return {
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            k: int(getattr(mem, k, 0) or 0)
            for k in (
                "temp_size_in_bytes",
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            )
        },
        "flops": float(cost.get("flops", -1.0)),
        "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
        "collectives": coll,
        "hlo_ops": len(hlo.splitlines()),
    }, rules


_PROBE_REPS = (2, 4)


def _extrapolate(m_lo: dict, m_hi: dict, n_lo: int, n_hi: int, n_full: int) -> dict:
    """Affine per-repeat extrapolation of every cost metric."""
    def ext(a, b):
        slope = (b - a) / (n_hi - n_lo)
        return max(b + slope * (n_full - n_hi), 0.0)

    coll = {
        k: int(ext(m_lo["collectives"][k], m_hi["collectives"][k]))
        for k in m_lo["collectives"]
    }
    coll["total"] = sum(coll[c] for c in _COLLECTIVES)
    return {
        "flops": ext(m_lo["flops"], m_hi["flops"]),
        "bytes_accessed": ext(m_lo["bytes_accessed"], m_hi["bytes_accessed"]),
        "collectives": coll,
    }


def run_one(arch: str, shape_name: str, *, multi_pod: bool, out_dir: str,
            probes: bool = True, variant: str = "", moe_path=None,
            donate: bool = False, window_override=None,
            remat=True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
    if variant:
        tag += f"__{variant}"
    opts = dict(moe_path=moe_path, donate=donate,
                window_override=window_override, remat=remat)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "n_devices": mesh.devices.size, "ok": False,
        "variant": variant or "baseline", **{k: str(v) for k, v in opts.items()},
    }
    try:
        # 1. full config, lax.scan: the lowering proof + memory analysis
        full, rules = _compile_metrics(cfg, shape, mesh, unroll=False, **opts)
        rec.update(
            ok=True,
            lower_s=full["lower_s"],
            compile_s=full["compile_s"],
            rules={k: (list(v) if isinstance(v, tuple) else v)
                   for k, v in rules.rules.items()},
            memory=full["memory"],
            flops_scan_body=full["flops"],
            params_total=cfg.params_total,
            params_active=cfg.params_active,
            hlo_ops=full["hlo_ops"],
        )

        # 2. unrolled probes -> true per-layer costs by extrapolation
        if probes:
            n_lo, n_hi = _PROBE_REPS
            m_lo, _ = _compile_metrics(_probe_cfg(cfg, n_lo), shape, mesh,
                                       unroll=True, **opts)
            m_hi, _ = _compile_metrics(_probe_cfg(cfg, n_hi), shape, mesh,
                                       unroll=True, **opts)
            n_full = (cfg.num_layers - len(cfg.tail_blocks)) // len(cfg.block_pattern)
            est = _extrapolate(m_lo, m_hi, n_lo, n_hi, n_full)
            rec.update(
                flops=est["flops"],
                bytes_accessed=est["bytes_accessed"],
                collectives=est["collectives"],
                probe_reps=[n_lo, n_hi, n_full],
                probe_flops=[m_lo["flops"], m_hi["flops"]],
            )
        else:
            rec.update(flops=full["flops"], bytes_accessed=full["bytes_accessed"],
                       collectives=full["collectives"])

        print(f"[dryrun] OK  {tag}  flops={rec['flops']:.3e} "
              f"coll={rec['collectives']['total']:.3e}B "
              f"compile={rec['compile_s']:.1f}s", flush=True)
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash --all
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[dryrun] FAIL {tag}: {rec['error'][:200]}", flush=True)

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--all-shapes", action="store_true",
                    help="every (shape x mesh) for --arch")
    # §Perf hillclimb knobs — write <tag>__<variant>.json artifacts
    ap.add_argument("--variant", default="",
                    help="artifact suffix for an optimized configuration")
    ap.add_argument("--moe-path", default=None, choices=("local", "ep_a2a"))
    ap.add_argument("--donate", action="store_true",
                    help="donate state buffers (cache / params+opt)")
    ap.add_argument("--window", type=int, default=None,
                    help="override the attention window for this lowering")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing (train steps)")
    ap.add_argument("--remat-policy", default=None, choices=("full", "dots"),
                    help="checkpoint policy for train steps")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    if args.all:
        failures = 0
        for arch in list_architectures():
            for shape in INPUT_SHAPES:
                for mp in (False, True):
                    # cost probes feed the single-pod roofline table; the
                    # multi-pod pass proves the "pod" axis lowers
                    rec = run_one(arch, shape, multi_pod=mp,
                                  out_dir=args.out, probes=not mp)
                    failures += 0 if rec["ok"] else 1
        raise SystemExit(1 if failures else 0)

    if args.all_shapes:
        assert args.arch
        failures = 0
        for shape in INPUT_SHAPES:
            for mp in (False, True):
                rec = run_one(args.arch, shape, multi_pod=mp,
                              out_dir=args.out, probes=not mp)
                failures += 0 if rec["ok"] else 1
        raise SystemExit(1 if failures else 0)

    assert args.arch and args.shape, "--arch and --shape (or --all) required"
    rec = run_one(
        args.arch, args.shape, multi_pod=args.multi_pod, out_dir=args.out,
        variant=args.variant, moe_path=args.moe_path, donate=args.donate,
        window_override=args.window,
        remat=False if args.no_remat else (args.remat_policy or True),
    )
    if rec["ok"]:
        print(json.dumps({k: rec[k] for k in ("memory", "flops", "collectives")}, indent=1))
    raise SystemExit(0 if rec["ok"] else 1)


if __name__ == "__main__":
    main()
