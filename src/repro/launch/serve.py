"""Serving driver.

Runs the continuous-batching engine for any registered architecture.
On this CPU container use ``--reduced`` (the smoke variant); on real
hardware the same driver serves the full config under the production
mesh shardings from ``launch/specs.py``.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --requests 16 --slots 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as model_lib
from repro.serving import ContinuousBatcher, Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", default=False)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend == "vision":
        raise SystemExit(
            "vision archs serve via embeddings; see examples/quickstart.py"
        )

    print(f"[serve] {cfg.name}: L={cfg.num_layers} d={cfg.d_model} "
          f"params={cfg.params_total/1e6:.1f}M", flush=True)
    params = model_lib.init_params(cfg, jax.random.key(args.seed))
    engine = Engine(cfg, params, EngineConfig(
        slots=args.slots, cache_len=args.cache_len, max_new_tokens=args.max_new
    ))
    batcher = ContinuousBatcher(engine)

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(max(2, args.prompt_len // 2), args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        batcher.submit(Request(rid=i, prompt=prompt, max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    stats = batcher.run_until_idle()
    wall = time.perf_counter() - t0
    s = stats.summary()
    toks = s["finished"] * args.max_new
    print(f"[serve] {s}")
    print(f"[serve] {toks} tokens in {wall:.2f}s = {toks / wall:.1f} tok/s "
          f"({s['decode_steps']} decode steps)")


if __name__ == "__main__":
    main()
