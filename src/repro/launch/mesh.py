"""Production meshes and logical-axis rules.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.

Target fleet: TPU v5e.  Single pod = 16x16 = 256 chips
(``data`` x ``model``); multi-pod = 2 pods = 512 chips
(``pod`` x ``data`` x ``model``).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.configs.registry import ModelConfig
from repro.distributed.sharding import AxisRules


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (1, 1), axes=("data", "model")):
    return jax.make_mesh(shape, axes)


def make_rules(
    cfg: ModelConfig,
    mesh,
    mode: str,                    # train | prefill | decode
    *,
    batch_size: int,
    cache_len: int = 0,
) -> AxisRules:
    """Logical-axis -> mesh-axis mapping for one (arch, shape, mesh).

    Divisibility-checked: an axis maps to ``model`` only when every tensor
    dimension carrying that logical axis divides the mesh axis size;
    otherwise it stays replicated (recorded honestly in the roofline —
    e.g. minicpm's 36 heads and whisper's 51865 vocab don't divide 16).
    """
    names = mesh.axis_names
    n_model = mesh.shape["model"]
    data_axes = tuple(a for a in names if a != "model")
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]

    def fits_model(*dims: int) -> bool:
        return all(d > 0 and d % n_model == 0 for d in dims)

    rules = {}
    # --- activations ------------------------------------------------------
    rules["batch"] = data_axes if batch_size % n_data == 0 else None
    rules["seq_act"] = None
    # --- weights ----------------------------------------------------------
    ff_dims = [cfg.d_ff]
    if cfg.num_experts:
        ff_dims.append(cfg.expert_d_ff or cfg.d_ff)
    if "rglru" in str(cfg.block_pattern):
        ff_dims.append(cfg.rglru_width or cfg.d_model)
    rules["ff"] = "model" if fits_model(*ff_dims) else None
    rules["heads"] = "model" if fits_model(cfg.num_heads) else None
    rules["kv_heads"] = "model" if fits_model(cfg.num_kv_heads) else None
    rules["heads_flat"] = "model" if fits_model(cfg.d_model) else None
    rules["vocab"] = "model" if fits_model(cfg.vocab_size) else None
    rules["experts"] = "model" if fits_model(cfg.num_experts) else None
    rules["rwkv_heads"] = (
        "model"
        if cfg.rwkv_head_dim and fits_model(cfg.d_model // cfg.rwkv_head_dim)
        else None
    )
    rules["layers"] = None
    rules["embed_out"] = None
    if mode == "train":
        # FSDP-style 2nd weight axis: shard the d_model (embed) dim over ALL
        # data-like axes (pod + data on the multi-pod mesh) — sharding over
        # `data` only left the pod axis replicating optimizer state, which
        # is exactly what keeps a 1T-param model from fitting (kimi-k2:
        # 24.8 GB/chip on 512 chips without `pod` in the FSDP axes,
        # 12.4 GB with — see EXPERIMENTS.md §Dry-run).
        if cfg.d_model % n_data == 0:
            rules["embed"] = data_axes
        elif cfg.d_model % mesh.shape[data_axes[-1]] == 0:
            rules["embed"] = (data_axes[-1],)
        else:
            rules["embed"] = None
        rules["kv_seq"] = None
    else:
        rules["embed"] = None
        if mode == "decode" and cache_len and cache_len % n_model == 0:
            # flash-decode split-K: KV cache sequence-sharded across the
            # model axis (splits the HBM reads of the decode hot loop)
            rules["kv_seq"] = "model"
        else:
            rules["kv_seq"] = None
    return AxisRules(mesh=mesh, rules=rules)
