"""ShapeDtypeStruct input specs + sharding trees for every
(architecture x input-shape x mesh) combination.

Everything here is abstract (eval_shape / ShapeDtypeStruct): the 72B and 1T
parameter sets are never allocated.  The dry-run lowers
``jax.jit(step, in_shardings=...)`` against these specs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import InputShape, ModelConfig
from repro.distributed.sharding import AxisRules, logical_to_spec
from repro.launch.mesh import make_rules
from repro.models import model as model_lib
from repro.training.optimizer import OptimizerConfig, adamw_init
from repro.training.train_loop import TrainConfig, make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding window used for global-attn layers at this shape (0 = full)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return cfg.long_context_window
    return 0


def batch_spec(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Training / forward batch as ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision":
        inputs = sds((b, s, cfg.d_model), dtype)
    else:
        inputs = sds((b, s), jnp.int32)
    out = {"inputs": inputs, "labels": sds((b, s), jnp.int32)}
    if cfg.is_encoder_decoder:
        out["enc_inputs"] = sds((b, cfg.encoder_seq, cfg.d_model), dtype)
    return out


def batch_axes(cfg: ModelConfig, batch: Dict[str, Any]) -> Dict[str, Tuple]:
    axes = {}
    for k, v in batch.items():
        if v.ndim == 2:
            axes[k] = ("batch", "seq_act")
        else:
            axes[k] = ("batch", "seq_act", None)
    return axes


def cache_spec(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    """Abstract decode/prefill cache for this shape."""
    window = decode_window(cfg, shape)
    b = shape.global_batch

    def build():
        cache = model_lib.init_cache(
            cfg, b, shape.seq_len, window=window, dtype=dtype
        )
        if cfg.is_encoder_decoder:
            # cross-attn KV: (L, B, enc_seq, nkv, hd)
            hd = cfg.resolved_head_dim
            cache["cross"] = {
                "k": jnp.zeros((cfg.num_layers, b, cfg.encoder_seq, cfg.num_kv_heads, hd), dtype),
                "v": jnp.zeros((cfg.num_layers, b, cfg.encoder_seq, cfg.num_kv_heads, hd), dtype),
            }
        return cache

    return jax.eval_shape(build)


_LEAF_AXES = {
    "k": ("layers", "batch", "kv_seq", "kv_heads", None),
    "v": ("layers", "batch", "kv_seq", "kv_heads", None),
    "slot_pos": ("layers", "batch", "kv_seq"),
    "wkv": ("layers", "batch", "rwkv_heads", None, None),
    "shift_tm": ("layers", "batch", None),
    "shift_cm": ("layers", "batch", None),
    "conv": ("layers", "batch", None, "ff"),
    "h": ("layers", "batch", "ff"),
    "t": ("batch",),
}


def cache_axes(cache_tree) -> Any:
    """Logical axes tree for a cache (matched by leaf dict key)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    out = []
    for path, leaf in flat:
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1] if keys else None
        if "cross" in keys:
            # whisper cross-attn KV: encoder seq (1500) stays unsharded
            axes = ("layers", "batch", None, "kv_heads", None)
        else:
            axes = _LEAF_AXES.get(name)
        if axes is None:
            axes = (None,) * leaf.ndim
        # tail (unstacked) cache entries and per-batch 't' have no layer dim
        if len(axes) == leaf.ndim + 1 and axes[0] == "layers":
            axes = axes[1:]
        assert len(axes) == leaf.ndim, (path, leaf.shape, axes)
        out.append(tuple(axes))
    return jax.tree.unflatten(treedef, out)


def shardings_of(axes_tree, rules: AxisRules):
    return jax.tree.map(
        lambda axes: NamedSharding(rules.mesh, logical_to_spec(axes, rules)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        ),
    )


# ---------------------------------------------------------------------------
# Step builders for the dry-run (and the launchers).
# ---------------------------------------------------------------------------
def build_train(cfg: ModelConfig, shape: InputShape, rules: AxisRules,
                *, moe_path: str = "local", param_dtype=jnp.bfloat16,
                opt_state_dtype=None, remat=True):
    """(step_fn, arg_specs, in_shardings) for a full train step."""
    # 1T-class models get bf16 optimizer states by default (HBM budget)
    if opt_state_dtype is None:
        opt_state_dtype = (
            jnp.bfloat16 if cfg.params_total > 200_000_000_000 else jnp.float32
        )
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(state_dtype=opt_state_dtype),
        moe_path=moe_path,
        window=decode_window(cfg, shape),
        remat=remat,
    )
    step = make_train_step(cfg, tcfg)

    params = model_lib.abstract_params(cfg, param_dtype)
    opt = jax.eval_shape(lambda p: adamw_init(p, tcfg.optimizer), params)
    batch = batch_spec(cfg, shape, param_dtype)

    p_axes = model_lib.param_axes(cfg, param_dtype)
    p_shard = shardings_of(p_axes, rules)
    opt_shard = {
        "step": NamedSharding(rules.mesh, P()),
        "m": p_shard,
        "v": p_shard,
    }
    b_shard = shardings_of(batch_axes(cfg, batch), rules)
    args = (params, opt, batch)
    in_shardings = (p_shard, opt_shard, b_shard)
    return step, args, in_shardings


def build_prefill(cfg: ModelConfig, shape: InputShape, rules: AxisRules,
                  *, moe_path: str = "local", param_dtype=jnp.bfloat16,
                  window_override: Optional[int] = None):
    window = decode_window(cfg, shape) if window_override is None else window_override

    def step(params, inputs, cache, enc_inputs=None):
        return model_lib.prefill(
            cfg, params, inputs, cache,
            enc_inputs=enc_inputs, window=window, moe_path=moe_path,
        )

    params = model_lib.abstract_params(cfg, param_dtype)
    batch = batch_spec(cfg, shape, param_dtype)
    cache = cache_spec(cfg, shape, param_dtype)

    p_shard = shardings_of(model_lib.param_axes(cfg, param_dtype), rules)
    b_ax = batch_axes(cfg, batch)
    c_shard = shardings_of(cache_axes(cache), rules)
    i_shard = shardings_of({"inputs": b_ax["inputs"]}, rules)["inputs"]
    args = [params, batch["inputs"], cache]
    in_shardings = [p_shard, i_shard, c_shard]
    if cfg.is_encoder_decoder:
        args.append(batch["enc_inputs"])
        in_shardings.append(shardings_of({"e": b_ax["enc_inputs"]}, rules)["e"])
    return step, tuple(args), tuple(in_shardings)


def build_decode(cfg: ModelConfig, shape: InputShape, rules: AxisRules,
                 *, param_dtype=jnp.bfloat16):
    window = decode_window(cfg, shape)

    def step(params, tokens, cache):
        return model_lib.decode_step(cfg, params, tokens, cache, window=window)

    params = model_lib.abstract_params(cfg, param_dtype)
    cache = cache_spec(cfg, shape, param_dtype)
    tokens = sds((shape.global_batch,), jnp.int32)

    p_shard = shardings_of(model_lib.param_axes(cfg, param_dtype), rules)
    c_shard = shardings_of(cache_axes(cache), rules)
    t_shard = shardings_of({"t": ("batch",)}, rules)["t"]
    return step, (params, tokens, cache), (p_shard, t_shard, c_shard)


def build_step(cfg: ModelConfig, shape: InputShape, mesh, *,
               moe_path: Optional[str] = None, param_dtype=jnp.bfloat16,
               window_override: Optional[int] = None, remat=True):
    """Dispatch on the shape kind. Returns (step, args, in_shardings, rules,
    donate) where ``donate`` is the donate_argnums a production launcher
    uses (state-carrying buffers: cache for serving, params+opt for
    training)."""
    mode = {"train": "train", "prefill": "prefill", "decode": "decode"}[shape.kind]
    cache_len = 0
    if mode == "decode":
        w = decode_window(cfg, shape) if window_override is None else window_override
        cache_len = min(w, shape.seq_len) if w else shape.seq_len
    rules = make_rules(
        cfg, mesh, mode, batch_size=shape.global_batch, cache_len=cache_len
    )
    if moe_path is None:
        # ep_a2a (explicit expert-parallel all_to_all) is the optimized
        # default — it cut kimi-k2's collective term 93x (§Perf target 1)
        # and falls back to the sort-based path wherever the mesh/shape
        # doesn't support it (e.g. single-token decode).
        moe_path = "ep_a2a" if cfg.num_experts else "local"
    if mode == "train":
        s, a, sh = build_train(cfg, shape, rules, moe_path=moe_path,
                               param_dtype=param_dtype, remat=remat)
        donate = (0, 1)          # params + optimizer state
    elif mode == "prefill":
        s, a, sh = build_prefill(cfg, shape, rules, moe_path=moe_path,
                                 param_dtype=param_dtype,
                                 window_override=window_override)
        donate = (2,)            # the cache being populated
    else:
        s, a, sh = build_decode(cfg, shape, rules, param_dtype=param_dtype)
        donate = (2,)            # the decode cache
    return s, a, sh, rules, donate
