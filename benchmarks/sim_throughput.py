"""BENCH: simulation-engine throughput (ticks/sec) vs the seed loop.

Pool sizes 4/16/64 over a full 86 400-tick (24 h) berkeley trace with the
vectorized engine, against the seed per-arch Python loop (kept as
``repro.core.sim.reference``) measured on a shorter slice of the same
trace and reported as ticks/sec.  Tracks the perf trajectory of the
engine from PR 1 onward; artifact: ``BENCH_sim_throughput.json``.

Also microbenchmarks the streaming per-arch load monitor at A=256: the
banded incremental order-statistic structure
(:class:`repro.core.load_monitor.PoolLoadMonitor`) vs the naive per-tick
window median/max recompute it replaced.

Claims: a 64-arch pool over a 24 h trace runs >= 10x faster than the
seed per-arch loop; the incremental monitor is >= 1.5x the naive
recompute at a 256-arch pool.

PR 6 adds the ``jax_engine`` section: the jitted ``lax.scan`` tick
pipeline (:mod:`repro.core.sim.jax_engine`) against the NumPy engine's
Python tick loop on the same scenario/policy — single-scenario scan
throughput at A=64/256 (claim: >= 4x at A=64 on the scan path, compile
reported separately), and a 64-cell vmapped (scenario x seed) grid
dispatched in ONE call against serial NumPy runs (claim: >= 20x;
the serial side is extrapolated from a timed sample of cells).

PR 7 adds the ``telemetry_overhead`` section: the engine with telemetry
*disabled* (the default) must stay within 3% of the committed
pre-telemetry pool-64 throughput — the zero-cost-when-off guarantee of
the observability subsystem — and the fully-enabled recorder+event-log
overhead is recorded informationally.  The disabled-vs-committed claim
is enforced on full runs only (CI machines vary too much for an
absolute-throughput gate under BENCH_SMALL).

PR 8 adds the ``fleet_scale`` section: the optimized scan construction
(accumulated totals, donated carry, lazy sliding-window-min rings)
against the pre-PR ``"legacy"`` runner flavor — the same build the
engine shipped with before the optimization, kept alive precisely so
this A/B runs in one process on one machine and is immune to
cross-box jitter.  Shapes A=256 and A=1024 at the full 3600-tick
scan; claim: >= 1.5x at both (measured ~2.9x / ~3.1x on the reference
box), with the two flavors' ledger totals asserted equivalent.  The
telemetry-overhead section also grows an A=256 pool so the
zero-cost-when-off ratchet holds at fleet scale, and ``--fleet-only``
runs just the fleet A/B for the ``fleet-scale-smoke`` CI step (no
artifact write).  Multi-device grid sharding rides the existing grid
rows transparently (``run_grid`` auto-shards when the host exposes
more than one device); exact sharded-vs-unsharded parity is pinned by
``tests/test_jax_engine.py`` under a forced multi-device host.
"""
from __future__ import annotations

import json
import os
import time
from typing import List, Optional

import numpy as np

from benchmarks.common import (
    ARTIFACTS,
    BENCH_SMALL,
    Row,
    SERVING_POOL,
    print_rows,
    write_artifact,
)
from repro.core.load_monitor import PoolLoadMonitor
from repro.core.schedulers import SCHEDULERS, VECTOR_SCHEDULERS
from repro.core.sim import replicate_pool, simulate, simulate_reference
from repro.core.traces import get_trace

POOL_SIZES = (4, 16, 64)
DAY_TICKS = 7_200 if BENCH_SMALL else 86_400
BASELINE_TICKS = 300 if BENCH_SMALL else 1_000
MEAN_RPS = 400.0
STRICT_FRAC = 0.25
MONITOR_ARCHS = 256
MONITOR_TICKS = 1_000 if BENCH_SMALL else 3_000
# jax_engine section: scan shapes, and the vmapped-grid shape.  The
# scan rows keep their full length even under BENCH_SMALL — a short
# scan under-amortizes the fixed dispatch overhead and misstates the
# steady-state throughput the claim is about; only the (much more
# expensive) grid shrinks.
JAX_SCAN_ARCHS = (64, 256)
SCAN_TICKS = 3_600
JAX_TICKS = 1_200 if BENCH_SMALL else 3_600
GRID_CELLS = 64
GRID_ARCHS = 16
GRID_SCENARIOS = ("shared_berkeley", "diurnal_phases", "mmpp_bursts",
                  "flash_correlated")
GRID_NUMPY_SAMPLE = 4 if BENCH_SMALL else 8
# fleet_scale section: opt-vs-legacy flavor A/B at fleet shapes.  Full
# scan length always (same rationale as the scan rows above); under
# BENCH_SMALL only A=256 runs — A=1024 compiles two flavors and is the
# single most expensive cell of the whole benchmark.
FLEET_ARCHS = (256,) if BENCH_SMALL else (256, 1024)
FLEET_REPEATS = 2 if BENCH_SMALL else 3
FLEET_SPEEDUP_FLOOR = 1.5


def _monitor_bench() -> dict:
    """Steady-state monitor ticks/s at A=256: incremental vs naive."""
    rng = np.random.default_rng(0)
    out = {"archs": MONITOR_ARCHS, "ticks": MONITOR_TICKS}
    for name, flag in (("incremental", True), ("naive", False)):
        mon = PoolLoadMonitor(MONITOR_ARCHS, incremental=flag)
        stream = rng.gamma(2.0, 50.0, (MONITOR_TICKS + mon.window_s, MONITOR_ARCHS))
        for t in range(mon.window_s):                 # fill outside the clock
            mon.observe(stream[t])
        t0 = time.perf_counter()
        for t in range(mon.window_s, mon.window_s + MONITOR_TICKS):
            mon.observe(stream[t])
            mon.stats()
        wall = time.perf_counter() - t0
        out[name] = {"wall_s": wall, "ticks_per_s": MONITOR_TICKS / wall}
    out["speedup"] = (
        out["incremental"]["ticks_per_s"] / out["naive"]["ticks_per_s"]
    )
    return out


def _numpy_portfolio_run(arrivals, wl, seed: int = 0):
    """The NumPy engine's full observe/apply tick loop (the comparator
    the differential tests pin the jitted scan against)."""
    from repro.core.sim import ServingSim

    sim = ServingSim(arrivals, wl, seed=seed)
    pol = VECTOR_SCHEDULERS["portfolio"]()
    while not sim.done:
        sim.apply_pool(pol(sim.tick, sim.observe_pool()))
    return sim.res


def _jax_bench() -> dict:
    """Jitted-scan vs NumPy-loop throughput, plus the vmapped grid."""
    import jax

    from repro.core.sim import jax_engine as je
    from repro.core.workloads import SCENARIO_ZOO

    out = {"scan_ticks": SCAN_TICKS, "grid_ticks": JAX_TICKS,
           "scan": {}, "grid": {}}

    # -- single-scenario scan at A = 64 / 256.  Zoo-default load: the
    # same configuration the differential-fuzz tests pin (high-rps
    # pools also lengthen the data-dependent binomial walk inside the
    # scan, which is a separate axis from tick throughput) ------------
    for A in JAX_SCAN_ARCHS:
        wl = replicate_pool(SERVING_POOL, A, strict_frac=STRICT_FRAC)
        arr = SCENARIO_ZOO["shared_berkeley"].build(A, duration_s=SCAN_TICKS)
        # min over repeats on both sides: a single-core box jitters
        # +-50%, and one noisy sample would mislabel the claim
        np_wall = float("inf")
        for _ in range(2):
            t = time.perf_counter()
            res_np = _numpy_portfolio_run(arr, wl)
            np_wall = min(np_wall, time.perf_counter() - t)

        t = time.perf_counter()
        res_jx = je.run_scenario(arr, wl, "portfolio")
        first_wall = time.perf_counter() - t
        # warm scan: same shape -> no retrace; host build excluded so
        # the row isolates the scan dispatch itself
        pol = je.JAX_POLICIES["portfolio"]
        statics, state0, xs = je.build_sim_inputs(
            arr, wl, needs_stats=pol.needs_stats
        )
        statics["policy"] = pol.default_params()
        runner = je._get_runner("portfolio")
        from jax.experimental import enable_x64
        with enable_x64():
            scan_wall = float("inf")
            for _ in range(3):
                t = time.perf_counter()
                jax.block_until_ready(runner(statics, state0, xs))
                scan_wall = min(scan_wall, time.perf_counter() - t)
        assert abs(
            res_jx["summary"]["cost_total"] - res_np.cost_total
        ) <= 1e-2 * max(abs(res_np.cost_total), 1.0), "engines drifted"
        out["scan"][str(A)] = {
            "numpy_wall_s": np_wall,
            "numpy_ticks_per_s": SCAN_TICKS / np_wall,
            "jax_first_s": first_wall,       # compile + host build + run
            "jax_scan_s": scan_wall,
            "jax_ticks_per_s": SCAN_TICKS / scan_wall,
            "speedup_scan": np_wall / scan_wall,
        }

    # -- 64-cell vmapped grid in one dispatch -------------------------
    wl = replicate_pool(SERVING_POOL, GRID_ARCHS, strict_frac=STRICT_FRAC)
    arrs = np.stack([
        SCENARIO_ZOO[GRID_SCENARIOS[i % len(GRID_SCENARIOS)]].build(
            GRID_ARCHS, duration_s=JAX_TICKS, mean_rps=MEAN_RPS,
            seed=100 + i // len(GRID_SCENARIOS),
        )
        for i in range(GRID_CELLS)
    ])
    seeds = [i // len(GRID_SCENARIOS) for i in range(GRID_CELLS)]

    t = time.perf_counter()
    je.run_grid(arrs, wl, "portfolio", seeds=seeds)
    grid_first = time.perf_counter() - t
    t = time.perf_counter()
    je.run_grid(arrs, wl, "portfolio", seeds=seeds)
    grid_warm = time.perf_counter() - t

    # serial NumPy side, extrapolated from a timed sample of cells
    t = time.perf_counter()
    for i in range(GRID_NUMPY_SAMPLE):
        _numpy_portfolio_run(arrs[i], wl, seed=seeds[i])
    np_serial = (time.perf_counter() - t) * GRID_CELLS / GRID_NUMPY_SAMPLE
    out["grid"] = {
        "cells": GRID_CELLS,
        "archs": GRID_ARCHS,
        "numpy_serial_est_s": np_serial,
        "numpy_sampled_cells": GRID_NUMPY_SAMPLE,
        "jax_first_s": grid_first,
        "jax_warm_s": grid_warm,
        "speedup_grid": np_serial / grid_warm,
    }
    return out


def _fleet_pair(A: int, repeats: int) -> dict:
    """One opt-vs-legacy scan A/B at pool size ``A`` (portfolio policy,
    shared_berkeley, full scan length).  Both flavors run warm in the
    same process with min-over-repeats, so the ratio is immune to the
    cross-box absolute-throughput jitter that keeps the NumPy-vs-JAX
    rows report-only; the two ledgers are asserted equivalent first."""
    import jax
    from jax.experimental import enable_x64

    from repro.core.sim import jax_engine as je
    from repro.core.workloads import SCENARIO_ZOO

    wl = replicate_pool(SERVING_POOL, A, strict_frac=STRICT_FRAC)
    arr = SCENARIO_ZOO["shared_berkeley"].build(A, duration_s=SCAN_TICKS)
    pol = je.JAX_POLICIES["portfolio"]
    cell: dict = {"archs": A}
    totals = {}
    for flavor in ("legacy", "opt"):
        # each flavor gets its own build: the lazy rings change the
        # carry layout, legacy feeds the EWMA from the host, and the
        # opt runner donates its state0
        statics, state0, xs = je.build_sim_inputs(
            arr, wl, needs_stats=pol.needs_stats,
            lazy_rings=(flavor == "opt"),
            ewma_in_scan=None if flavor == "opt" else False,
        )
        statics["policy"] = pol.default_params()
        runner = je._get_runner("portfolio", flavor=flavor)
        with enable_x64():
            t = time.perf_counter()
            out = jax.block_until_ready(runner(statics, state0, xs))
            first = time.perf_counter() - t
            wall = float("inf")
            for _ in range(repeats):
                t = time.perf_counter()
                out = jax.block_until_ready(runner(statics, state0, xs))
                wall = min(wall, time.perf_counter() - t)
        totals[flavor] = jax.tree.map(np.asarray, out["totals"])
        cell[flavor] = {
            "first_s": first,                # compile + run
            "wall_s": wall,
            "ticks_per_s": SCAN_TICKS / wall,
        }
    # the optimization is a pure reformulation: identical ledgers (the
    # liveness flags fold to booleans on the opt path, tick counts on
    # the stacked legacy path — only truthiness is ever consumed)
    for k, v in totals["legacy"].items():
        w = totals["opt"][k]
        if k in je._LIVE_KEYS:
            assert bool(v) == bool(w), f"flavor liveness drift: {k}"
        else:
            np.testing.assert_allclose(
                np.asarray(w), np.asarray(v), rtol=1e-9, atol=1e-9,
                err_msg=f"flavor ledger drift: {k}",
            )
    cell["speedup_opt"] = cell["legacy"]["wall_s"] / cell["opt"]["wall_s"]
    return cell


def _fleet_scale_bench() -> dict:
    """Fleet-shape scan A/B (A=256 / A=1024) + device inventory."""
    import jax

    out = {
        "ticks": SCAN_TICKS,
        "repeats": FLEET_REPEATS,
        "policy": "portfolio",
        "scenario": "shared_berkeley",
        "devices": jax.device_count(),
        "a1024_skipped_small": 1024 not in FLEET_ARCHS,
        "scan": {},
    }
    for A in FLEET_ARCHS:
        out["scan"][str(A)] = _fleet_pair(A, FLEET_REPEATS)
    return out


def _fleet_rows(fleet: dict) -> List[Row]:
    rows: List[Row] = []
    for A in FLEET_ARCHS:
        sc = fleet["scan"][str(A)]
        rows.append((
            f"fleet_opt_ticks_per_s_a{A}", sc["opt"]["ticks_per_s"],
            f"optimized scan, A={A}, {SCAN_TICKS} ticks", True,
        ))
        rows.append((
            f"fleet_opt_speedup_a{A}", sc["speedup_opt"],
            f"optimized scan >= {FLEET_SPEEDUP_FLOOR}x the pre-PR "
            "(legacy-flavor) scan, same run / same machine",
            sc["speedup_opt"] >= FLEET_SPEEDUP_FLOOR,
        ))
    return rows


def run_fleet_only() -> bool:
    """The ``fleet-scale-smoke`` CI entry: just the flavor A/B (with
    its embedded ledger-parity asserts), no artifact write."""
    t0 = time.perf_counter()
    fleet = _fleet_scale_bench()
    return print_rows("sim_throughput[fleet]", _fleet_rows(fleet), t0)


OVERHEAD_TICKS = 2_400 if BENCH_SMALL else 7_200
OVERHEAD_ARCHS = 64
OVERHEAD_FLEET_ARCHS = 256


def _prev_committed(*keys) -> Optional[float]:
    """A float from the *committed* artifact, read before this run
    overwrites it — e.g. the pre-telemetry pool-64 baseline, or the
    last full run's fleet-pool disabled throughput.  Always reads the
    full-run (non-``_small``) file; ``None`` when absent."""
    path = os.path.join(os.path.abspath(ARTIFACTS), "BENCH_sim_throughput.json")
    try:
        with open(path) as f:
            node = json.load(f)
        for k in keys:
            node = node[k]
        return float(node)
    except Exception:
        return None


def _telemetry_overhead_pool(A: int) -> dict:
    """Disabled-vs-enabled telemetry throughput on one trace/pool."""
    from repro.core.sim import Telemetry

    wl = replicate_pool(SERVING_POOL, A, strict_frac=STRICT_FRAC)
    trace = get_trace("berkeley", OVERHEAD_TICKS, mean_rps=MEAN_RPS)
    out = {"archs": A, "ticks": OVERHEAD_TICKS}
    # min over repeats on both sides — single-core boxes jitter
    for name, make_tel in (
        ("disabled", lambda: None),
        ("enabled", lambda: Telemetry(events=True, record=True)),
    ):
        wall = float("inf")
        n_events = 0
        for _ in range(2):
            tel = make_tel()
            t = time.perf_counter()
            simulate(trace, wl, VECTOR_SCHEDULERS["paragon"](), telemetry=tel)
            wall = min(wall, time.perf_counter() - t)
            if tel is not None:
                n_events = len(tel.events)
        out[name] = {"wall_s": wall, "ticks_per_s": OVERHEAD_TICKS / wall}
        if name == "enabled":
            out[name]["events"] = n_events
    out["enabled_overhead_pct"] = 100.0 * (
        out["disabled"]["ticks_per_s"] / out["enabled"]["ticks_per_s"] - 1.0
    )
    return out


def _telemetry_overhead_bench() -> dict:
    """The PR 7 pool-64 section plus the PR 8 fleet pool (A=256): the
    zero-cost-when-off guarantee must not erode as the pool widens.
    The A=64 pool ratchets against the committed *pre-telemetry*
    pool-64 day-run throughput; the A=256 pool ratchets against its own
    previous committed measurement (same-shape, same-trace)."""
    out = _telemetry_overhead_pool(OVERHEAD_ARCHS)
    out["a256"] = _telemetry_overhead_pool(OVERHEAD_FLEET_ARCHS)
    prev_256 = _prev_committed(
        "telemetry_overhead", "a256", "disabled", "ticks_per_s"
    )
    out["a256"]["prev_committed_ticks_per_s"] = prev_256
    out["a256"]["disabled_vs_committed_ratio"] = (
        out["a256"]["disabled"]["ticks_per_s"] / prev_256
        if prev_256 else None
    )
    return out


def run() -> bool:
    t0 = time.perf_counter()
    prev_tps = _prev_committed("pool_sizes", "64", "ticks_per_s")
    trace = get_trace("berkeley", DAY_TICKS, mean_rps=MEAN_RPS)
    payload = {"pool_sizes": {}, "baseline": {}}

    for n in POOL_SIZES:
        wl = replicate_pool(SERVING_POOL, n, strict_frac=STRICT_FRAC)
        t = time.perf_counter()
        res = simulate(trace, wl, VECTOR_SCHEDULERS["paragon"]())
        wall = time.perf_counter() - t
        payload["pool_sizes"][str(n)] = {
            "ticks": DAY_TICKS,
            "wall_s": wall,
            "ticks_per_s": DAY_TICKS / wall,
            "violation_rate": res.violation_rate,
            "cost_total": res.cost_total,
        }

    # seed baseline: the per-arch loop at the largest pool, short slice
    n = POOL_SIZES[-1]
    wl = replicate_pool(SERVING_POOL, n, strict_frac=STRICT_FRAC)
    t = time.perf_counter()
    simulate_reference(trace[:BASELINE_TICKS], wl, SCHEDULERS["paragon"]())
    wall = time.perf_counter() - t
    baseline_tps = BASELINE_TICKS / wall
    payload["baseline"] = {
        "pool_size": n,
        "ticks": BASELINE_TICKS,
        "wall_s": wall,
        "ticks_per_s": baseline_tps,
    }

    engine_tps = payload["pool_sizes"][str(n)]["ticks_per_s"]
    speedup = engine_tps / baseline_tps
    payload["speedup_64arch"] = speedup
    payload["monitor_a256"] = mon = _monitor_bench()
    payload["jax_engine"] = jx = _jax_bench()
    payload["fleet_scale"] = fleet = _fleet_scale_bench()
    payload["telemetry_overhead"] = ov = _telemetry_overhead_bench()
    # best observed disabled measurement vs the committed pre-telemetry
    # number; the day run above IS a telemetry-disabled run of the new
    # engine, so take whichever sample is cleaner
    off_tps = max(engine_tps, ov["disabled"]["ticks_per_s"])
    ov["prev_committed_pool64_ticks_per_s"] = prev_tps
    ov["disabled_vs_committed_ratio"] = (
        off_tps / prev_tps if prev_tps else None
    )

    rows: List[Row] = [
        (
            f"engine_ticks_per_s_{n}", payload["pool_sizes"][str(n)]["ticks_per_s"],
            f"vectorized engine, {DAY_TICKS}-tick trace", True,
        )
        for n in POOL_SIZES
    ]
    rows.append((
        "seed_loop_ticks_per_s_64", baseline_tps, "seed per-arch loop", True,
    ))
    rows.append((
        "speedup_64arch_day", speedup,
        f"64-arch {DAY_TICKS}-tick pool >= 10x faster than the seed loop",
        speedup >= 10.0,
    ))
    rows.append((
        "monitor_speedup_a256", mon["speedup"],
        "incremental banded monitor >= 1.5x naive window recompute at A=256",
        mon["speedup"] >= 1.5,
    ))
    for A in JAX_SCAN_ARCHS:
        sc = jx["scan"][str(A)]
        # the NumPy comparator's absolute speed swings tens of percent
        # across boxes, which moves a marginal ratio without either
        # engine changing (jax_ticks_per_s is the stable signal) — the
        # floor is 4x, and report-only under BENCH_SMALL
        rows.append((
            f"jax_scan_speedup_a{A}", sc["speedup_scan"],
            f"jitted scan >= 4x the NumPy tick loop at A=64 "
            f"({SCAN_TICKS} ticks; report-only under BENCH_SMALL)" if A == 64
            else f"jitted scan vs NumPy tick loop at A={A}",
            (BENCH_SMALL or sc["speedup_scan"] >= 4.0) if A == 64 else True,
        ))
    rows.append((
        "jax_grid_speedup_64cell", jx["grid"]["speedup_grid"],
        f"{GRID_CELLS}-cell vmapped grid >= 20x {GRID_CELLS} serial "
        "NumPy runs, one dispatch",
        jx["grid"]["speedup_grid"] >= 20.0,
    ))
    rows.extend(_fleet_rows(fleet))
    ratio = ov["disabled_vs_committed_ratio"]
    rows.append((
        "telemetry_disabled_ratio", ratio if ratio is not None else 0.0,
        "telemetry-disabled engine within 3% of committed pre-telemetry "
        "pool-64 throughput (report-only under BENCH_SMALL)",
        True if (BENCH_SMALL or ratio is None) else ratio >= 0.97,
    ))
    ratio256 = ov["a256"]["disabled_vs_committed_ratio"]
    rows.append((
        "telemetry_disabled_ratio_a256", ratio256 if ratio256 is not None else 0.0,
        "telemetry-disabled A=256 pool within 3% of its committed "
        "measurement (report-only under BENCH_SMALL)",
        True if (BENCH_SMALL or ratio256 is None) else ratio256 >= 0.97,
    ))
    rows.append((
        "telemetry_enabled_overhead_pct", ov["enabled_overhead_pct"],
        "recorder+event-log overhead when fully enabled (informational)",
        True,
    ))
    rows.append((
        "telemetry_enabled_overhead_pct_a256", ov["a256"]["enabled_overhead_pct"],
        "fully-enabled overhead at the A=256 fleet pool (informational)",
        True,
    ))

    write_artifact("BENCH_sim_throughput", payload, t0)
    return print_rows("sim_throughput", rows, t0)


if __name__ == "__main__":
    import sys

    if "--fleet-only" in sys.argv[1:]:
        raise SystemExit(0 if run_fleet_only() else 1)
    raise SystemExit(0 if run() else 1)
