"""BENCH: simulation-engine throughput (ticks/sec) vs the seed loop.

Pool sizes 4/16/64 over a full 86 400-tick (24 h) berkeley trace with the
vectorized engine, against the seed per-arch Python loop (kept as
``repro.core.sim.reference``) measured on a shorter slice of the same
trace and reported as ticks/sec.  Tracks the perf trajectory of the
engine from PR 1 onward; artifact: ``BENCH_sim_throughput.json``.

Also microbenchmarks the streaming per-arch load monitor at A=256: the
banded incremental order-statistic structure
(:class:`repro.core.load_monitor.PoolLoadMonitor`) vs the naive per-tick
window median/max recompute it replaced.

Claims: a 64-arch pool over a 24 h trace runs >= 10x faster than the
seed per-arch loop; the incremental monitor is >= 1.5x the naive
recompute at a 256-arch pool.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import (
    BENCH_SMALL,
    Row,
    SERVING_POOL,
    print_rows,
    write_artifact,
)
from repro.core.load_monitor import PoolLoadMonitor
from repro.core.schedulers import SCHEDULERS, VECTOR_SCHEDULERS
from repro.core.sim import replicate_pool, simulate, simulate_reference
from repro.core.traces import get_trace

POOL_SIZES = (4, 16, 64)
DAY_TICKS = 7_200 if BENCH_SMALL else 86_400
BASELINE_TICKS = 300 if BENCH_SMALL else 1_000
MEAN_RPS = 400.0
STRICT_FRAC = 0.25
MONITOR_ARCHS = 256
MONITOR_TICKS = 1_000 if BENCH_SMALL else 3_000


def _monitor_bench() -> dict:
    """Steady-state monitor ticks/s at A=256: incremental vs naive."""
    rng = np.random.default_rng(0)
    out = {"archs": MONITOR_ARCHS, "ticks": MONITOR_TICKS}
    for name, flag in (("incremental", True), ("naive", False)):
        mon = PoolLoadMonitor(MONITOR_ARCHS, incremental=flag)
        stream = rng.gamma(2.0, 50.0, (MONITOR_TICKS + mon.window_s, MONITOR_ARCHS))
        for t in range(mon.window_s):                 # fill outside the clock
            mon.observe(stream[t])
        t0 = time.perf_counter()
        for t in range(mon.window_s, mon.window_s + MONITOR_TICKS):
            mon.observe(stream[t])
            mon.stats()
        wall = time.perf_counter() - t0
        out[name] = {"wall_s": wall, "ticks_per_s": MONITOR_TICKS / wall}
    out["speedup"] = (
        out["incremental"]["ticks_per_s"] / out["naive"]["ticks_per_s"]
    )
    return out


def run() -> bool:
    t0 = time.perf_counter()
    trace = get_trace("berkeley", DAY_TICKS, mean_rps=MEAN_RPS)
    payload = {"pool_sizes": {}, "baseline": {}}

    for n in POOL_SIZES:
        wl = replicate_pool(SERVING_POOL, n, strict_frac=STRICT_FRAC)
        t = time.perf_counter()
        res = simulate(trace, wl, VECTOR_SCHEDULERS["paragon"]())
        wall = time.perf_counter() - t
        payload["pool_sizes"][str(n)] = {
            "ticks": DAY_TICKS,
            "wall_s": wall,
            "ticks_per_s": DAY_TICKS / wall,
            "violation_rate": res.violation_rate,
            "cost_total": res.cost_total,
        }

    # seed baseline: the per-arch loop at the largest pool, short slice
    n = POOL_SIZES[-1]
    wl = replicate_pool(SERVING_POOL, n, strict_frac=STRICT_FRAC)
    t = time.perf_counter()
    simulate_reference(trace[:BASELINE_TICKS], wl, SCHEDULERS["paragon"]())
    wall = time.perf_counter() - t
    baseline_tps = BASELINE_TICKS / wall
    payload["baseline"] = {
        "pool_size": n,
        "ticks": BASELINE_TICKS,
        "wall_s": wall,
        "ticks_per_s": baseline_tps,
    }

    engine_tps = payload["pool_sizes"][str(n)]["ticks_per_s"]
    speedup = engine_tps / baseline_tps
    payload["speedup_64arch"] = speedup
    payload["monitor_a256"] = mon = _monitor_bench()

    rows: List[Row] = [
        (
            f"engine_ticks_per_s_{n}", payload["pool_sizes"][str(n)]["ticks_per_s"],
            f"vectorized engine, {DAY_TICKS}-tick trace", True,
        )
        for n in POOL_SIZES
    ]
    rows.append((
        "seed_loop_ticks_per_s_64", baseline_tps, "seed per-arch loop", True,
    ))
    rows.append((
        "speedup_64arch_day", speedup,
        f"64-arch {DAY_TICKS}-tick pool >= 10x faster than the seed loop",
        speedup >= 10.0,
    ))
    rows.append((
        "monitor_speedup_a256", mon["speedup"],
        "incremental banded monitor >= 1.5x naive window recompute at A=256",
        mon["speedup"] >= 1.5,
    ))

    write_artifact("BENCH_sim_throughput", payload)
    return print_rows("sim_throughput", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
