"""Fig 4: reserved slices vs burst pool under CONSTANT request rates.

The paper's claim (VMs always cheaper at 10/50/100/200 req/s) holds in
its utilization regime — its CNN VMs served ~10 req/s each.  Our slices
serve 10-400 req/s, so we evaluate at per-slice-throughput multiples AND
at the paper's absolute rates, reporting the under-utilization crossover
the paper's scale never exposes (EXPERIMENTS.md §Paper-claims, delta D1).
"""
from __future__ import annotations

import math
import time
from typing import List

from benchmarks.common import Row, print_rows, write_artifact
from repro.core.hardware import PRICING
from repro.core.profiles import model_pool


def run() -> bool:
    t0 = time.perf_counter()
    pool = model_pool()
    rows: List[Row] = []
    table = {}

    # paper regime: constant load that keeps slices utilized
    ok_util = True
    worst = 0.0
    for mult in (1.0, 2.0, 4.0, 8.0):
        for arch, e in pool.items():
            rate = mult * e["throughput_rps"]
            n = math.ceil(rate / e["throughput_rps"])
            vm = n * e["chips"] * PRICING.reserved_chip_hour
            burst = rate * 3600 * e["burst_cost_per_req"]
            table[f"{arch}@{mult}x"] = {"vm": vm, "burst": burst}
            ok_util &= vm < burst
            worst = max(worst, vm / burst)
    rows.append((
        "vm_cheaper_when_utilized", worst,
        "VM/burst cost ratio < 1 at all utilized constant rates",
        ok_util,
    ))

    # the paper's absolute rates, for the record (crossover visible)
    crossover = 0
    for rate in (10, 50, 100, 200):
        for arch, e in pool.items():
            n = math.ceil(rate / e["throughput_rps"])
            vm = n * e["chips"] * PRICING.reserved_chip_hour
            burst = rate * 3600 * e["burst_cost_per_req"]
            table[f"{arch}@{rate}rps"] = {"vm": vm, "burst": burst}
            if burst < vm:
                crossover += 1
    rows.append((
        "underutilized_crossovers", crossover,
        "burst wins exist only at deep under-utilization (delta D1)",
        crossover > 0,
    ))

    write_artifact("fig4_constant_load", table)
    return print_rows("fig4", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
