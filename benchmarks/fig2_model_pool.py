"""Fig 2 + Fig 3: the model pool's accuracy/latency frontier and the
ISO-latency / ISO-accuracy candidate sets."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row, print_rows, write_artifact
from repro.core.profiles import iso_accuracy_set, iso_latency_set, model_pool


def run() -> bool:
    t0 = time.perf_counter()
    pool = model_pool()
    rows: List[Row] = []

    # Fig 2: a non-degenerate accuracy<->latency/cost trade-off must exist:
    # the cheapest model is not the most accurate, and picking more
    # accuracy costs more (over the pareto set).
    by_cost = sorted(pool.values(), key=lambda e: e["cost_per_1k"])
    pareto = []
    best_acc = -1.0
    for e in by_cost:
        if e["accuracy"] > best_acc:
            pareto.append(e)
            best_acc = e["accuracy"]
    rows.append(("pareto_size", len(pareto), "frontier has >=4 rungs", len(pareto) >= 4))
    accs = [e["accuracy"] for e in pareto]
    costs = [e["cost_per_1k"] for e in pareto]
    rows.append((
        "frontier_monotone", 1.0,
        "cost rises with accuracy along the frontier",
        all(a < b for a, b in zip(accs, accs[1:]))
        and all(a < b for a, b in zip(costs, costs[1:])),
    ))

    # Fig 3a: ISO-latency 500 ms — multiple models, different accuracies
    iso_lat = iso_latency_set(0.5)
    accs_iso = sorted(e["accuracy"] for e in iso_lat.values())
    rows.append((
        "iso_latency_candidates", len(iso_lat),
        ">=3 models satisfy a 500 ms bound with spread accuracy",
        len(iso_lat) >= 3 and accs_iso[-1] - accs_iso[0] > 0.2,
    ))

    # Fig 3b: ISO-accuracy 60% — multiple models, different latencies
    iso_acc = iso_accuracy_set(0.6)
    lats_iso = sorted(e["latency_s"] for e in iso_acc.values())
    rows.append((
        "iso_accuracy_candidates", len(iso_acc),
        ">=3 models reach 60% acc with spread latency",
        len(iso_acc) >= 3 and lats_iso[-1] / lats_iso[0] > 2.0,
    ))

    write_artifact("fig2_model_pool", {"pool": pool, "pareto": pareto})
    return print_rows("fig2", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
