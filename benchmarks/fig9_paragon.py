"""Fig 9: the Paragon scheme.

(a)/(b) Variable-SLO workload on Berkeley + WITS: Paragon vs reactive /
        util_aware / exascale / mixed — ~10% cheaper than mixed at
        comparable SLO attainment.
(c)     Variable-constraint workload: Paragon least-cost model selection
        vs the naive constraints-unaware policy — >= 20% cheaper.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import (
    DURATION_S,
    MEAN_RPS,
    PRICING_X,
    Row,
    SERVING_POOL,
    STRICT_FRAC,
    print_rows,
    write_artifact,
)
from repro.core.model_selection import (
    Constraint,
    feasible_set,
    selection_cost,
    selection_workload,
)
from repro.core.schedulers import SCHEDULERS
from repro.core.sim import simulate, uniform_pool_workload
from repro.core.traces import get_trace


def run() -> bool:
    t0 = time.perf_counter()
    rows: List[Row] = []
    payload = {}

    # ---------------------------------------------------------- fig 9a/b
    wl = uniform_pool_workload(SERVING_POOL, strict_frac=STRICT_FRAC)
    for trace_name in ("berkeley", "wits"):
        trace = get_trace(trace_name, DURATION_S, mean_rps=MEAN_RPS)
        res = {
            n: simulate(trace, wl, cls(), pricing=PRICING_X)
            for n, cls in SCHEDULERS.items()
        }
        payload[trace_name] = {n: r.summary() for n, r in res.items()}
        saving = 1 - res["paragon"].cost_total / res["mixed"].cost_total
        rows.append((
            f"9a_{trace_name}_paragon_vs_mixed", saving,
            "paper: Paragon ~10% cheaper than mixed (>= 5%)",
            saving >= 0.05,
        ))
        # Paragon's contract is class-aware: strict queries are offloaded
        # before they can violate, relaxed ones trade a little SLO for the
        # burst premium they never pay.
        strict_rate = res["paragon"].violations_strict / max(
            res["paragon"].total_requests * STRICT_FRAC, 1e-9
        )
        rows.append((
            f"9a_{trace_name}_paragon_strict_viol", strict_rate,
            "Paragon strict-class violations ~0 (its contract)",
            strict_rate < 0.005,
        ))
        rows.append((
            f"9a_{trace_name}_paragon_total_viol", res["paragon"].violation_rate,
            "Paragon total violations well below reactive",
            res["paragon"].violation_rate
            < 0.75 * res["reactive"].violation_rate,
        ))

    # ------------------------------------------------------------ fig 9c
    rng = np.random.default_rng(0)
    cons = [
        Constraint(float(rng.uniform(0.3, 0.85)), float(rng.uniform(0.3, 2.0)))
        for _ in range(500)
    ]
    cons = [c for c in cons if feasible_set(c)]
    naive = selection_cost(cons, "naive")
    paragon = selection_cost(cons, "paragon")
    saving = 1 - paragon["cost"] / naive["cost"]
    payload["fig9c"] = {"naive": naive, "paragon": paragon, "saving": saving}
    rows.append((
        "9c_selection_saving", saving,
        "paper: >= 20% cheaper than naive selection (ours larger: "
        "LLM-pool cost spread >> CNN pool, see EXPERIMENTS.md D2)",
        saving >= 0.20,
    ))
    rows.append((
        "9c_delivered_accuracy", paragon["mean_accuracy"],
        "paragon still meets the accuracy constraints",
        paragon["mean_accuracy"] > 0.55,
    ))

    # 9c DYNAMIC: route the same constraint stream through each selector
    # into per-arch traffic shares and run the FLEET simulation — integer
    # slice counts moderate the raw pool spread, landing the saving right
    # in the paper's "up to 20%" band.
    trace = get_trace("berkeley", DURATION_S, mean_rps=MEAN_RPS)
    fleet = {}
    for sel in ("naive", "paragon"):
        wl, skipped = selection_workload(cons, sel, strict_frac=STRICT_FRAC)
        r = simulate(trace, wl, SCHEDULERS["paragon"](), pricing=PRICING_X)
        fleet[sel] = {"cost": r.cost_total, "archs": len(wl),
                      "violation_rate": r.violation_rate, "skipped": skipped}
    dyn_saving = 1 - fleet["paragon"]["cost"] / fleet["naive"]["cost"]
    payload["fig9c_dynamic"] = {**fleet, "saving": dyn_saving}
    rows.append((
        "9c_dynamic_fleet_saving", dyn_saving,
        "paper: up to 20% cheaper — fleet simulation of the routed "
        "workload (10-25% band)",
        0.10 <= dyn_saving <= 0.25,
    ))

    write_artifact("fig9_paragon", payload)
    return print_rows("fig9", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
