"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig9

Output: CSV lines ``bench,metric,value,claim,OK|FAIL``; exit status 1 if
any paper claim fails.  Artifacts land in artifacts/benchmarks/.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (
    fig2_model_pool,
    fig4_constant_load,
    fig5_fig6_schedulers,
    fig7_traces,
    fig8_burst_sizing,
    fig9_paragon,
    rl_vs_schemes,
    roofline,
    scenario_grid,
    sim_throughput,
    spot_tier,
    tier_portfolio,
    variant_grid,
)

BENCHES = {
    "fig2": fig2_model_pool.run,
    "fig4": fig4_constant_load.run,
    "fig5_fig6": fig5_fig6_schedulers.run,
    "fig7": fig7_traces.run,
    "fig8": fig8_burst_sizing.run,
    "fig9": fig9_paragon.run,
    "rl": rl_vs_schemes.run,
    "spot": spot_tier.run,
    "roofline": roofline.run,
    "scenario_grid": scenario_grid.run,
    "sim_throughput": sim_throughput.run,
    "tier_portfolio": tier_portfolio.run,
    "variant_grid": variant_grid.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args()

    t0 = time.perf_counter()
    print("bench,metric,value,claim,status")
    ok = True
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        try:
            ok &= fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},_error,0,{type(e).__name__}: {e},FAIL")
            ok = False
    print(f"all,_total_wall_s,{time.perf_counter() - t0:.1f},,"
          f"{'OK' if ok else 'FAIL'}")
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
