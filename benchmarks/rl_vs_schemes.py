"""Beyond-paper (§V implemented): PPO controller vs the hand-built schemes.

Trains on the twitter trace, evaluates on a held-out berkeley seed; the
blended objective is cost + lambda * violations (the paper's
multi-objective reward)."""
from __future__ import annotations

import time
from typing import List

from benchmarks.common import Row, print_rows, write_artifact
from repro.core.rl.env import EnvConfig, ServingEnv
from repro.core.rl.ppo import PPOConfig, evaluate_policy, train_ppo
from repro.core.schedulers import SCHEDULERS
from repro.core.sim import ArchLoad, simulate
from repro.core.traces import get_trace

PENALTY = 0.02
ARCH = "llama3-8b"


def run(iterations: int = 50) -> bool:
    t0 = time.perf_counter()
    envcfg = EnvConfig(arch=ARCH, duration_s=1200, mean_rps=60,
                       violation_penalty=PENALTY)
    train_trace = get_trace("twitter", 1200, mean_rps=60)
    eval_trace = get_trace("berkeley", 1200, mean_rps=60, seed=7)

    state = train_ppo(ServingEnv(envcfg, train_trace),
                      PPOConfig(iterations=iterations))

    obj = lambda r: r.cost_total + PENALTY * r.violations  # noqa: E731
    wl = [ArchLoad(ARCH, 1.0, 0.25)]
    table = {}
    for name, cls in SCHEDULERS.items():
        r = simulate(eval_trace, wl, cls())
        table[name] = {**r.summary(), "objective": obj(r)}
    r = evaluate_policy(ServingEnv(envcfg, eval_trace), state.params, seed=11)
    table["ppo"] = {**r.summary(), "objective": obj(r)}
    table["_train"] = {"best_rollout_reward": state.best_reward,
                       "iterations": iterations}

    rows: List[Row] = []
    rows.append((
        "ppo_objective", table["ppo"]["objective"],
        "PPO beats reactive on the blended objective",
        table["ppo"]["objective"] < table["reactive"]["objective"],
    ))
    rows.append((
        "ppo_vs_best_hand_policy",
        table["ppo"]["objective"]
        / min(table[n]["objective"] for n in SCHEDULERS),
        "PPO within 1.5x of the best hand-built scheme (held-out trace)",
        table["ppo"]["objective"]
        <= 1.5 * min(table[n]["objective"] for n in SCHEDULERS),
    ))
    write_artifact("rl_vs_schemes", table)
    return print_rows("rl", rows, t0)


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)
